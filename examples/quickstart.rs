//! Quickstart: the 60-second tour of the LoRIF pipeline.
//!
//! Generates a tiny topic corpus, trains the base TinyLM, builds the
//! rank-1 factored gradient index + truncated-SVD curvature, and answers
//! a handful of attribution queries, printing the top proponents with
//! their (ground-truth) topics and judge relevance.
//!
//! Run:  cargo run --release --example quickstart
//! (requires `make artifacts` first)

use lorif::app::{build_store_scorer, Method};
use lorif::config::Config;
use lorif::eval::judge;
use lorif::index::{Pipeline, Stage1Options};
use lorif::query::QueryEngine;

fn main() -> anyhow::Result<()> {
    lorif::util::logging::init();
    let mut cfg = Config::default();
    cfg.n_train = 512;
    cfg.n_query = 8;
    cfg.train_steps = 150;
    cfg.r = 64;
    cfg.work_dir = "work/quickstart".into();

    println!("== LoRIF quickstart (tier={}, f={}, c={}, r={}) ==", cfg.tier.name(), cfg.f, cfg.c, cfg.r);

    // 1. corpus + base model
    let p = Pipeline::new(cfg)?;
    let (train, queries) = p.corpus()?;
    println!("corpus: {} train / {} query examples", train.len(), queries.len());
    let params = p.base_params(&train)?;
    let lit = p.params_literal(&params)?;

    // 2. stage 1: factored gradient index (+ embeddings for RepSim)
    let rep = p.stage1(
        &lit,
        &train,
        Stage1Options { write_dense: false, ..Default::default() },
    )?;
    println!("stage 1 (extract + rank-1 factorize + store): {:.1}s", rep.wall.as_secs_f64());

    // 3. stage 2: streaming randomized SVD -> Woodbury curvature
    let (_, t2) = p.stage2_lorif()?;
    println!("stage 2 (truncated-SVD curvature, r={}): {:.1}s", p.cfg.r, t2.as_secs_f64());

    // 4. query
    let scorer = build_store_scorer(&p, Method::Lorif)?;
    let qg = p.query_grads(&lit, &queries)?;
    let res = QueryEngine::new(scorer, 5).run(&qg)?;
    println!(
        "query: {} queries vs {} examples in {:.3}s (load {:.0}%, compute {:.0}%)",
        queries.len(),
        train.len(),
        res.latency.total_s,
        100.0 * res.latency.io_fraction(),
        100.0 * res.latency.compute_s / res.latency.total_s.max(1e-9),
    );

    // 5. inspect
    let tm = p.topic_model();
    let mut hits = 0;
    for q in 0..queries.len() {
        let top = &res.topk[q];
        let rel = judge::relevance(&tm, &queries, &train, q, top[0]);
        if queries.topics[q] == train.topics[top[0]] {
            hits += 1;
        }
        println!(
            "  query {q} topic {} -> top-1 train #{} topic {} (judge {}/5)",
            queries.topics[q], top[0], train.topics[top[0]], rel
        );
    }
    println!("top-1 topic match: {hits}/{}", queries.len());
    Ok(())
}

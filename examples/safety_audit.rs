//! Safety-auditing case study (paper App. F.3 analogue).
//!
//! The synthetic corpus designates topic 0 as the "unsafe pattern" topic
//! (the stand-in for the jailbreak-style SFT sample the paper surfaces).
//! This example shows the paper's workflow:
//!   1. build the LoRIF index,
//!   2. attribute a batch of queries drawn from *several* topics,
//!   3. find training examples that rank top-1 for unusually many
//!      queries (cross-context proponents),
//!   4. compare against RepSim retrieval, which surfaces only
//!      surface-similar examples,
//!   5. verify actionability with a tail-patch check on the flagged
//!      examples.
//!
//! Run:  cargo run --release --example safety_audit

use std::collections::BTreeMap;

use lorif::app::{build_repsim_scorer, build_store_scorer, ensure_embeddings, Method};
use lorif::config::Config;
use lorif::corpus::UNSAFE_TOPIC;
use lorif::eval::{tail_patch, TailPatchProtocol};
use lorif::index::{Pipeline, Stage1Options};
use lorif::query::QueryEngine;

fn main() -> anyhow::Result<()> {
    lorif::util::logging::init();
    let mut cfg = Config::default();
    cfg.n_train = 768;
    cfg.n_query = 24;
    cfg.train_steps = 200;
    cfg.r = 64;
    cfg.work_dir = "work/safety_audit".into();

    let p = Pipeline::new(cfg)?;
    let (train, queries) = p.corpus()?;
    let params = p.base_params(&train)?;
    let lit = p.params_literal(&params)?;
    p.stage1(&lit, &train, Stage1Options { write_dense: false, ..Default::default() })?;

    // gradient-based attribution (LoRIF)
    let scorer = build_store_scorer(&p, Method::Lorif)?;
    let qg = p.query_grads(&lit, &queries)?;
    let res = QueryEngine::new(scorer, 3).run(&qg)?;

    // 3. cross-context proponents: training examples appearing in many
    // different queries' top-3
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for top in &res.topk {
        for &t in top {
            *counts.entry(t).or_default() += 1;
        }
    }
    let mut ranked: Vec<(usize, usize)> = counts.into_iter().collect();
    ranked.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    println!("== cross-context high-influence training examples ==");
    let flagged: Vec<usize> = ranked.iter().take(5).map(|&(t, _)| t).collect();
    for &(t, c) in ranked.iter().take(5) {
        let marker = if train.topics[t] as usize == UNSAFE_TOPIC { "  <-- UNSAFE topic" } else { "" };
        println!("  train #{t} (topic {}): top-3 for {c} queries{marker}", train.topics[t]);
    }

    // 4. RepSim comparison: how often does surface similarity surface the
    // same examples?
    ensure_embeddings(&p, &lit, &train)?;
    let repsim = build_repsim_scorer(&p, &lit, &queries)?;
    let res_rs = QueryEngine::new(repsim, 3).run(&qg)?;
    let mut overlap = 0;
    for (a, b) in res.topk.iter().zip(&res_rs.topk) {
        if a.iter().any(|x| b.contains(x)) {
            overlap += 1;
        }
    }
    println!(
        "RepSim top-3 overlaps LoRIF top-3 on {overlap}/{} queries \
         (gradient attribution surfaces non-surface-similar proponents)",
        queries.len()
    );

    // 5. actionability: tail-patch on the flagged examples for the
    // unsafe-topic queries
    let unsafe_queries: Vec<usize> = (0..queries.len())
        .filter(|&q| queries.topics[q] as usize == UNSAFE_TOPIC)
        .collect();
    if !unsafe_queries.is_empty() {
        let sub = queries.subset(&unsafe_queries);
        let topk: Vec<Vec<usize>> = unsafe_queries.iter().map(|_| flagged.clone()).collect();
        let scores = tail_patch(
            &p,
            &params,
            &train,
            &sub,
            &topk,
            TailPatchProtocol { k: flagged.len(), lr: 1e-2 },
        )?;
        let mean: f64 = scores.iter().sum::<f64>() / scores.len() as f64;
        println!(
            "tail-patch of flagged examples on {} unsafe-topic queries: {:+.3} \
             (positive = the flagged data causally drives this behaviour)",
            unsafe_queries.len(),
            mean
        );
    }
    Ok(())
}

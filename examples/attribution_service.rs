//! End-to-end driver: the full system on a real (synthetic) workload.
//!
//! Proves all three layers compose (DESIGN.md "End-to-end validation"):
//!   L2/L1  train the TinyLM with the AOT train_step graph (logging the
//!          loss curve), extract per-example gradients through the
//!          Pallas-kernel grad_extract graph;
//!   L3     build the rank-1 factored index + truncated-SVD curvature,
//!          start the TCP attribution service with dynamic batching, and
//!          drive it with concurrent clients;
//! reports training loss, index build time, serving latency/throughput,
//! and retrieval quality (topic-match + judge relevance).
//!
//! Run:  cargo run --release --example attribution_service
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use lorif::app::{build_store_scorer_pool, Method};
use lorif::config::Config;
use lorif::corpus::Dataset;
use lorif::index::{Pipeline, Stage1Options};
use lorif::query::ServerConfig;
use lorif::runtime::{GradExtractor, Trainer};
use lorif::util::json::Value;
use lorif::util::prng::Rng;

const ADDR: &str = "127.0.0.1:7981";

fn main() -> anyhow::Result<()> {
    lorif::util::logging::init();
    let mut cfg = Config::default();
    cfg.n_train = 1024;
    cfg.n_query = 32;
    cfg.train_steps = 300;
    cfg.r = 96;
    cfg.work_dir = "work/service".into();

    println!("== end-to-end attribution service ==");
    let p = Pipeline::new(cfg)?;
    let (train, queries) = p.corpus()?;

    // --- L2: train with the AOT train_step, logging the loss curve -----
    let ckpt = p.cfg.work_dir.join("service_model.ckpt");
    let params = if ckpt.exists() {
        lorif::model::checkpoint::Checkpoint::load(&ckpt)?.params
    } else {
        let init = p.cfg.tier.spec().init_params(p.cfg.seed);
        let mut trainer = Trainer::new(&p.rt, p.cfg.tier, init)?;
        let mut rng = Rng::labeled(p.cfg.seed, "service-train");
        let t0 = std::time::Instant::now();
        let losses = trainer.train(&p.rt, &train, p.cfg.train_steps, p.cfg.train_lr, &mut rng)?;
        println!("loss curve (every 30 steps):");
        for (i, chunk) in losses.chunks(30).enumerate() {
            let avg: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
            println!("  step {:>4}: {:.4}", i * 30, avg);
        }
        println!(
            "trained {} steps in {:.1}s ({:.1} steps/s)",
            p.cfg.train_steps,
            t0.elapsed().as_secs_f64(),
            p.cfg.train_steps as f64 / t0.elapsed().as_secs_f64()
        );
        lorif::model::checkpoint::Checkpoint {
            tier: p.cfg.tier.name().into(),
            step: trainer.step,
            params: trainer.params.clone(),
        }
        .save(&ckpt)?;
        trainer.params
    };
    let lit = p.params_literal(&params)?;

    // --- L3: index -------------------------------------------------------
    let rep = p.stage1(&lit, &train, Stage1Options { write_dense: false, ..Default::default() })?;
    let (_, t2) = p.stage2_lorif()?;
    println!("index: stage1 {:.1}s, stage2 {:.1}s", rep.wall.as_secs_f64(), t2.as_secs_f64());

    // --- serve ------------------------------------------------------------
    // a pool of scoring workers sharing one Arc'd store + decoded-chunk
    // cache (see app::build_store_scorer_pool); gradient extraction for
    // batch N+1 overlaps batch N's store pass
    let scorers = build_store_scorer_pool(&p, Method::Lorif, 2)?;
    let extractor = GradExtractor::new(&p.rt, p.cfg.tier, p.cfg.f, p.cfg.c)?;
    let sc = ServerConfig {
        addr: ADDR.into(),
        max_batch: 8,
        window_ms: 50,
        topk: 5,
        queue_cap: 64,
    };

    // clients run on background threads; the PJRT batcher loop stays here
    let qtokens: Vec<Vec<i32>> =
        (0..queries.len()).map(|q| queries.example(q).to_vec()).collect();
    let client_handle = std::thread::spawn(move || client_driver(&qtokens));

    let source =
        lorif::query::server::XlaGradSource { rt: &p.rt, extractor: &extractor, params: &lit };
    let summary = lorif::query::serve(source, scorers, sc)?;
    let stats = client_handle.join().expect("client thread panicked")?;
    println!(
        "served {} queries in {} batches ({} shed, {} failed, {} dropped)",
        summary.served, summary.batches, summary.shed, summary.failed, summary.dropped
    );
    println!(
        "client-observed: {:.1} q/s, mean latency {:.3}s, mean batch {:.1}",
        stats.qps, stats.mean_latency, stats.mean_batch
    );

    // quality of the served answers
    let tm = p.topic_model();
    let mut hits = 0;
    for (q, top1) in stats.top1.iter().enumerate() {
        if queries.topics[q] == train.topics[*top1] {
            hits += 1;
        }
    }
    println!("top-1 topic match over the wire: {hits}/{}", stats.top1.len());
    check_loss_curve(&p, &params, &train)?;
    Ok(())
}

struct ClientStats {
    qps: f64,
    mean_latency: f64,
    mean_batch: f64,
    top1: Vec<usize>,
}

/// Drive the service with 4 concurrent client connections.
fn client_driver(qtokens: &[Vec<i32>]) -> anyhow::Result<ClientStats> {
    // wait for the listener
    let mut attempts = 0;
    loop {
        match TcpStream::connect(ADDR) {
            Ok(_) => break,
            Err(_) if attempts < 100 => {
                attempts += 1;
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let t0 = std::time::Instant::now();
    let n = qtokens.len();
    let n_conns = 4;
    let results: Vec<(usize, usize, f64, f64)> = crossbeam_utils::thread::scope(|s| {
        let mut handles = Vec::new();
        for conn in 0..n_conns {
            let slice: Vec<(usize, &Vec<i32>)> = qtokens
                .iter()
                .enumerate()
                .filter(|(i, _)| i % n_conns == conn)
                .collect();
            handles.push(s.spawn(move |_| -> anyhow::Result<Vec<(usize, usize, f64, f64)>> {
                let stream = TcpStream::connect(ADDR)?;
                let mut reader = BufReader::new(stream.try_clone()?);
                let mut stream = stream;
                let mut out = Vec::new();
                for (qi, toks) in slice {
                    let body: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
                    writeln!(stream, "{{\"tokens\": [{}]}}", body.join(","))?;
                    let mut line = String::new();
                    reader.read_line(&mut line)?;
                    let v = Value::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
                    let top1 = v.req("topk")?.as_arr().unwrap()[0].as_usize().unwrap();
                    let lat = v.req_f64("latency_s")?;
                    let batch = v.req_f64("batch")?;
                    out.push((qi, top1, lat, batch));
                }
                Ok(out)
            }));
        }
        handles.into_iter().flat_map(|h| h.join().unwrap().unwrap()).collect()
    })
    .map_err(|_| anyhow::anyhow!("client scope panicked"))?;

    let wall = t0.elapsed().as_secs_f64();
    let mut top1 = vec![0usize; n];
    let mut lat = 0.0;
    let mut batch = 0.0;
    for &(qi, t1, l, b) in &results {
        top1[qi] = t1;
        lat += l;
        batch += b;
    }
    // shut the server down
    let mut stream = TcpStream::connect(ADDR)?;
    writeln!(stream, "{{\"cmd\": \"shutdown\"}}")?;
    Ok(ClientStats {
        qps: n as f64 / wall,
        mean_latency: lat / n as f64,
        mean_batch: batch / n as f64,
        top1,
    })
}

/// Confirm the trained model actually learned the corpus (loss well below
/// the uniform floor ln(64) ~ 4.16).
fn check_loss_curve(p: &Pipeline, params: &[f32], train: &Dataset) -> anyhow::Result<()> {
    let sample = train.subset(&(0..64.min(train.len())).collect::<Vec<_>>());
    let losses = p.query_losses(params, &sample)?;
    let mean: f32 = losses.iter().sum::<f32>() / losses.len() as f32;
    println!("final train loss (64-example sample): {mean:.3} (uniform floor 4.159)");
    anyhow::ensure!(mean < 3.0, "model failed to learn the corpus");
    Ok(())
}

"""AOT driver: lower every L2 graph to HLO *text* + emit a JSON manifest.

Run once via ``make artifacts``; the Rust coordinator (L3) is self-
contained afterwards.  Interchange is HLO text, NOT a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and aot_recipe).

Artifact sets (env ``LORIF_AOT_SET`` or --set):
  minimal  smoke set (small tier, f=4) — fast CI builds
  default  everything the examples + benches need
  full     adds the wider (f, c) grids for the full paper sweeps

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import time

import jax

from . import model, spec
from .kernels import projgrad as k_projgrad
from .kernels import poweriter as k_poweriter

MANIFEST_VERSION = 2

# Fixed AOT batch sizes (compiled into the artifacts; Rust pads partial
# batches).  Small enough for a 1-core CPU, big enough to amortize
# dispatch.
BATCH_GRAD = 8
BATCH_LOSS = 32
BATCH_TRAIN = 16
BATCH_EMBED = 32
BATCH_EKFAC = 8
BATCH_SCORE = 512
SCORE_R = 128


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer ELIDES big literals
    # as `constant({...})`, which xla_extension 0.5.1's text parser reads
    # back as zeros — silently zeroing the baked projection matrices.
    text = comp.as_hlo_text(True)
    assert "constant({...})" not in text, "elided constant survived"
    return text


def grad_extract_jobs(set_name: str):
    """(tier, f, c) grid per artifact set."""
    jobs = []
    if set_name == "minimal":
        return [("small", 4, 1)]
    # default: everything benches need at the small tier + the two larger
    # tiers' main configs
    jobs += [("small", f, 1) for f in (1, 2, 4, 8, 16)]
    jobs += [("small", 2, c) for c in (2, 4, 8)]
    jobs += [("small", 4, 4)]
    jobs += [("medium", f, 1) for f in (4, 8, 16)]
    jobs += [("large", f, 1) for f in (8, 16)]
    if set_name == "full":
        jobs += [("small", 8, 4), ("small", 16, 4)]
        jobs += [("medium", 2, 1), ("large", 4, 1)]
    return jobs


def score_jobs(set_name: str):
    """Pallas scorer artifacts for the small tier's f=4 layer shapes."""
    tier = spec.TIERS["small"]
    shapes = sorted({(i // 4, o // 4) for _, _, i, o in tier.tracked_layers()})
    return [(d1, d2, 1, SCORE_R) for d1, d2 in shapes]


def shape_info(x):
    return {"dtype": str(x.dtype), "shape": list(x.shape)}


def lower_one(name: str, fn, example_args, out_dir: str, meta: dict, manifest: list):
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    outs = jax.eval_shape(fn, *example_args)
    entry = {
        "name": name,
        "inputs": [shape_info(a) for a in example_args],
        "outputs": [shape_info(o) for o in jax.tree_util.tree_leaves(outs)],
        "hlo_bytes": len(text),
        **meta,
    }
    manifest.append(entry)
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO, {time.time()-t0:.1f}s")


def tier_meta(tier: spec.TierSpec) -> dict:
    return {
        "n_layers": tier.n_layers,
        "d_model": tier.d_model,
        "d_ff": tier.d_ff,
        "n_heads": tier.n_heads,
        "vocab": tier.vocab,
        "seq_len": tier.seq_len,
        "param_count": tier.param_count(),
        "tracked_layers": [
            {"name": n, "module": m, "in_dim": i, "out_dim": o}
            for n, m, i, o in tier.tracked_layers()
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default=os.environ.get("LORIF_AOT_SET", "default"))
    ap.add_argument(
        "--no-pallas", action="store_true",
        help="lower the jnp reference path instead of the Pallas kernels",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    use_pallas = not args.no_pallas
    manifest = []
    t_start = time.time()

    tiers = ["small"] if args.set == "minimal" else ["small", "medium", "large"]
    for tname in tiers:
        tier = spec.TIERS[tname]
        for kind, batch in (
            ("loss_eval", BATCH_LOSS),
            ("train_step", BATCH_TRAIN),
            ("embed", BATCH_EMBED),
            ("sgd_step", BATCH_TRAIN),
        ):
            fn, ex = model.graph_specs(tier, kind, batch)
            lower_one(
                f"{kind}_{tname}", fn, ex, args.out_dir,
                {"kind": kind, "tier": tname, "batch": batch},
                manifest,
            )

    for tname, f, c in grad_extract_jobs(args.set):
        tier = spec.TIERS[tname]
        fn, ex = model.graph_specs(
            tier, "grad_extract", BATCH_GRAD, f=f, c=c, use_pallas=use_pallas
        )
        lower_one(
            f"grad_extract_{tname}_f{f}_c{c}", fn, ex, args.out_dir,
            {
                "kind": "grad_extract", "tier": tname, "batch": BATCH_GRAD,
                "f": f, "c": c,
                "proj_dims": [[d1, d2] for d1, d2 in tier.proj_dims(f)],
                "power_iters": spec.power_iters(c),
            },
            manifest,
        )

    # EK-FAC stats: small tier only (the Table 1 contextual baseline)
    fn, ex = model.graph_specs(spec.TIERS["small"], "ekfac_stats", BATCH_EKFAC)
    lower_one(
        "ekfac_stats_small", fn, ex, args.out_dir,
        {"kind": "ekfac_stats", "tier": "small", "batch": BATCH_EKFAC},
        manifest,
    )

    # Pallas scorer artifacts (per distinct layer shape, small tier f=4)
    for d1, d2, c, r in score_jobs(args.set):
        fn, ex = model.graph_specs(
            spec.TIERS["small"], "score_lorif", BATCH_SCORE,
            d1=d1, d2=d2, c=c, r=r, use_pallas=use_pallas,
        )
        lower_one(
            f"score_{d1}x{d2}_c{c}_r{r}", fn, ex, args.out_dir,
            {
                "kind": "score_lorif", "batch": BATCH_SCORE,
                "d1": d1, "d2": d2, "c": c, "r": r,
            },
            manifest,
        )

    doc = {
        "version": MANIFEST_VERSION,
        "set": args.set,
        "use_pallas": use_pallas,
        "tiers": {t: tier_meta(spec.TIERS[t]) for t in tiers},
        "batch_sizes": {
            "grad_extract": BATCH_GRAD, "loss_eval": BATCH_LOSS,
            "train_step": BATCH_TRAIN, "embed": BATCH_EMBED,
            "ekfac_stats": BATCH_EKFAC, "score": BATCH_SCORE,
        },
        "graphs": manifest,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(doc, fh, indent=1)
    print(
        f"wrote {len(manifest)} artifacts + manifest.json "
        f"in {time.time()-t_start:.0f}s ({args.set} set)"
    )


if __name__ == "__main__":
    main()

"""Model tier specifications shared between the Python compile path (L1/L2)
and the Rust coordinator (L3, see ``rust/src/model/spec.rs``).

The two sides never exchange pytrees: all AOT graphs take the model
parameters as a single flat ``f32[P]`` vector, and this module defines the
canonical flattening order.  Any change here must be mirrored in
``rust/src/model/spec.rs`` (both sides assert on ``param_count``).

Tiers stand in for the paper's three evaluation models (GPT2-small,
OLMo-3-7B, Apertus-70B); see DESIGN.md §1 for the substitution rationale.
"""

from dataclasses import dataclass
from typing import List, Tuple

VOCAB = 64  # byte-level synthetic vocabulary (matches corpus generator)
SEQ_LEN = 64  # fixed context length for every tier


@dataclass(frozen=True)
class TierSpec:
    name: str
    n_layers: int  # transformer blocks
    d_model: int
    d_ff: int
    n_heads: int

    @property
    def seq_len(self) -> int:
        return SEQ_LEN

    @property
    def vocab(self) -> int:
        return VOCAB

    def tracked_layers(self) -> List[Tuple[str, str, int, int]]:
        """Linear layers tracked for attribution.

        Returns (name, module_kind, in_dim, out_dim) in canonical order.
        module_kind is "attn" or "mlp" (used by Tables 9/10).
        """
        out = []
        d, f = self.d_model, self.d_ff
        for b in range(self.n_layers):
            out.append((f"blk{b}.attn_qkv", "attn", d, 3 * d))
            out.append((f"blk{b}.attn_out", "attn", d, d))
            out.append((f"blk{b}.mlp_in", "mlp", d, f))
            out.append((f"blk{b}.mlp_out", "mlp", f, d))
        return out

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Canonical flat-parameter layout (row-major concatenation)."""
        d, f = self.d_model, self.d_ff
        shapes = [("embed", (VOCAB, d)), ("pos", (SEQ_LEN, d))]
        for b in range(self.n_layers):
            shapes.append((f"blk{b}.attn_qkv", (d, 3 * d)))
            shapes.append((f"blk{b}.attn_out", (d, d)))
            shapes.append((f"blk{b}.mlp_in", (d, f)))
            shapes.append((f"blk{b}.mlp_out", (f, d)))
        shapes.append(("unembed", (d, VOCAB)))
        return shapes

    def param_count(self) -> int:
        return sum(int_prod(s) for _, s in self.param_shapes())

    def proj_dims(self, f: int) -> List[Tuple[int, int]]:
        """(d1, d2) per tracked layer for projection factor f (f=1: identity)."""
        dims = []
        for _, _, i, o in self.tracked_layers():
            assert i % f == 0 and o % f == 0, f"f={f} must divide dims ({i},{o})"
            dims.append((i // f, o // f))
        return dims

    def total_proj_dim(self, f: int) -> int:
        """Effective projection dimension D summed over tracked layers."""
        return sum(d1 * d2 for d1, d2 in self.proj_dims(f))


def int_prod(shape) -> int:
    p = 1
    for s in shape:
        p *= int(s)
    return p


TIERS = {
    # stands in for GPT2-small (124M): the LDS-evaluated tier
    "small": TierSpec("small", n_layers=2, d_model=64, d_ff=128, n_heads=2),
    # stands in for OLMo-3-7B: tail-patch tier
    "medium": TierSpec("medium", n_layers=3, d_model=128, d_ff=256, n_heads=4),
    # stands in for Apertus-70B: tail-patch tier
    "large": TierSpec("large", n_layers=4, d_model=192, d_ff=384, n_heads=6),
}

# Power-iteration counts, matching paper App. B.2.
POWER_ITERS_C1 = 8
POWER_ITERS_CMULTI = 16
# Randomized-SVD oversampling, matching paper App. B.2 (p=10).
RSVD_OVERSAMPLE = 10


def power_iters(c: int) -> int:
    return POWER_ITERS_C1 if c == 1 else POWER_ITERS_CMULTI

"""L2: TinyLM — the JAX compute graphs AOT-lowered for the Rust coordinator.

A decoder-only transformer (RMSNorm, causal MHA, GELU MLP, learned
positions) standing in for the paper's GPT2-small / OLMo-3-7B /
Apertus-70B (DESIGN.md §1 substitutions).  All graphs take the parameters
as one flat ``f32[P]`` vector in the canonical order of
``spec.TierSpec.param_shapes`` so the Rust side never handles pytrees.

Per-example gradient extraction uses the *zero-probe-bias* trick: every
tracked linear computes ``y = x W + probe`` with ``probe = 0`` of shape
(T, O); then ``d loss/d probe = dY`` (the per-token output gradient) and
the layer input ``X`` is captured as an aux output.  One vjp therefore
yields everything Eq. (4) needs, with no recomputation (the §Perf L2
target).  The projected-gradient contraction and the rank-c factorization
run through the L1 Pallas kernels so they lower into the same HLO module.
"""

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp

from . import projection, spec
from .kernels import poweriter as k_poweriter
from .kernels import projgrad as k_projgrad
from .kernels import ref as k_ref
from .kernels import score as k_score

NORM_EPS = 1e-6
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------------------
# parameter handling
# ---------------------------------------------------------------------------

def unflatten(tier: spec.TierSpec, flat):
    """Split the flat f32[P] vector into named parameter arrays."""
    params = {}
    off = 0
    for name, shape in tier.param_shapes():
        n = spec.int_prod(shape)
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    assert off == tier.param_count()
    return params


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _rmsnorm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + NORM_EPS)


def _attention(q, k, v, n_heads):
    t, d = q.shape
    hd = d // n_heads
    q = q.reshape(t, n_heads, hd).transpose(1, 0, 2)
    k = k.reshape(t, n_heads, hd).transpose(1, 0, 2)
    v = v.reshape(t, n_heads, hd).transpose(1, 0, 2)
    scores = (q @ k.transpose(0, 2, 1)) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = probs @ v  # (h, t, hd)
    return out.transpose(1, 0, 2).reshape(t, d)


def forward(tier: spec.TierSpec, params, tokens, probes: Optional[List] = None):
    """Single-example forward. tokens: i32[T].

    Returns (logits (T, V), xs) where xs are the tracked-linear inputs (in
    tracked_layers order) — the X_i of Eq. (4).  ``probes`` is a list of
    (T, O_l) offsets added to each tracked linear's output (zeros at
    runtime; their gradient is dY_l).
    """
    t = tokens.shape[0]
    x = params["embed"][tokens] + params["pos"][:t]
    xs = []
    li = 0

    def linear(inp, w):
        nonlocal li
        xs.append(inp)
        y = inp @ w
        if probes is not None:
            y = y + probes[li]
        li += 1
        return y

    for b in range(tier.n_layers):
        h = _rmsnorm(x)
        qkv = linear(h, params[f"blk{b}.attn_qkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att = _attention(q, k, v, tier.n_heads)
        x = x + linear(att, params[f"blk{b}.attn_out"])
        h = _rmsnorm(x)
        h = jax.nn.gelu(linear(h, params[f"blk{b}.mlp_in"]))
        x = x + linear(h, params[f"blk{b}.mlp_out"])

    x = _rmsnorm(x)
    logits = x @ params["unembed"]
    return logits, xs, x


def example_loss(tier: spec.TierSpec, params, tokens, probes=None):
    """Mean next-token cross-entropy for one example; aux = (xs, final_h)."""
    logits, xs, final_h = forward(tier, params, tokens, probes)
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1)[:, 0]
    return jnp.mean(nll), (xs, final_h)


# ---------------------------------------------------------------------------
# graph builders (each is jitted then AOT-lowered by aot.py)
# ---------------------------------------------------------------------------

def make_loss_eval(tier: spec.TierSpec, batch: int):
    def fn(flat, tokens):
        params = unflatten(tier, flat)
        losses = jax.vmap(lambda tk: example_loss(tier, params, tk)[0])(tokens)
        return (losses,)

    return fn


def make_embed(tier: spec.TierSpec, batch: int):
    """RepSim representation: final hidden state of the last token."""

    def fn(flat, tokens):
        params = unflatten(tier, flat)

        def one(tk):
            _, _, final_h = forward(tier, params, tk)
            return final_h[-1]

        return (jax.vmap(one)(tokens),)

    return fn


def make_train_step(tier: spec.TierSpec, batch: int):
    """One Adam step on a batch. State threads through flat vectors."""

    def fn(flat, m, v, step, tokens, lr):
        params = unflatten(tier, flat)
        def batch_loss(fl):
            p = unflatten(tier, fl)
            losses = jax.vmap(lambda tk: example_loss(tier, p, tk)[0])(tokens)
            return jnp.mean(losses)

        loss, g = jax.value_and_grad(batch_loss)(flat)
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m2 / (1.0 - ADAM_B1**step)
        vhat = v2 / (1.0 - ADAM_B2**step)
        flat2 = flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        return flat2, m2, v2, loss

    return fn


def make_sgd_step(tier: spec.TierSpec, batch: int):
    """One plain SGD step — used by the tail-patch evaluation (one
    gradient step on the retrieved proponents, Chang et al. 2024)."""

    def fn(flat, tokens, lr):
        def batch_loss(fl):
            p = unflatten(tier, fl)
            losses = jax.vmap(lambda tk: example_loss(tier, p, tk)[0])(tokens)
            return jnp.mean(losses)

        loss, g = jax.value_and_grad(batch_loss)(flat)
        return flat - lr * g, loss

    return fn


def make_grad_extract(
    tier: spec.TierSpec,
    f: int,
    c: int,
    batch: int,
    use_pallas: bool = True,
):
    """Stage-1 graph: per-example projected gradients + rank-c factors.

    Outputs: (losses (B,), then per tracked layer l:
              G~_l (B, d1, d2), u_l (B, d1, c), v_l (B, d2, c)).
    The full G~ is emitted alongside the factors so one artifact serves
    both the LoGRA baselines (dense store) and LoRIF (factored store);
    the Rust index builder decides what to persist.
    """
    layers = tier.tracked_layers()
    projs = projection.all_projections(tier.name, f)
    iters = spec.power_iters(c)

    def per_example(params, tokens):
        t = tokens.shape[0]
        probes0 = [jnp.zeros((t, o), jnp.float32) for (_, _, _, o) in layers]

        def lf(probes):
            loss, aux = example_loss(tier, params, tokens, probes)
            return loss, (loss, aux[0])

        dys, (loss, xs) = jax.grad(lf, has_aux=True)(probes0)
        outs = []
        for idx in range(len(layers)):
            p_in, p_out = projs[idx]
            a = xs[idx] if p_in is None else xs[idx] @ p_in
            bm = dys[idx] if p_out is None else dys[idx] @ p_out
            if use_pallas:
                g = k_projgrad.projgrad(a, bm)
                u, v = k_poweriter.poweriter(g, c, iters)
            else:
                g = k_ref.projgrad(a, bm)
                u, v = k_ref.poweriter(g, c, iters)
            outs.extend((g, u, v))
        return (loss, *outs)

    def fn(flat, tokens):
        params = unflatten(tier, flat)
        return jax.vmap(lambda tk: per_example(params, tk))(tokens)

    return fn


def make_ekfac_stats(tier: spec.TierSpec, batch: int):
    """K-FAC covariance accumulation for the EK-FAC baseline.

    Returns per layer: A_cov = sum_{b,t} x x^T (I,I) and
    S_cov = sum_{b,t} dy dy^T (O,O), summed over the batch (the Rust side
    accumulates across batches and normalizes).
    """
    layers = tier.tracked_layers()

    def per_example(params, tokens):
        t = tokens.shape[0]
        probes0 = [jnp.zeros((t, o), jnp.float32) for (_, _, _, o) in layers]

        def lf(probes):
            loss, aux = example_loss(tier, params, tokens, probes)
            return loss, aux[0]

        dys, xs = jax.grad(lf, has_aux=True)(probes0)
        outs = []
        for idx in range(len(layers)):
            outs.append(xs[idx].T @ xs[idx])
            outs.append(dys[idx].T @ dys[idx])
        return tuple(outs)

    def fn(flat, tokens):
        params = unflatten(tier, flat)
        per = jax.vmap(lambda tk: per_example(params, tk))(tokens)
        return tuple(jnp.sum(p, axis=0) for p in per)

    return fn


def make_score_lorif(d1: int, d2: int, c: int, r: int, batch: int, use_pallas=True):
    """Query-time scoring graph for one layer shape (paper Eq. 9)."""

    def fn(u_q, v_q, big_u, big_v, gq_r, gt_r, w, lam):
        if use_pallas:
            s = k_score.score_batch(u_q, v_q, big_u, big_v, gq_r, gt_r, w, lam[0])
        else:
            s = k_ref.score_batch(u_q, v_q, big_u, big_v, gq_r, gt_r, w, lam[0])
        return (s,)

    return fn


# ---------------------------------------------------------------------------
# example-arg factories (shape specs for AOT lowering)
# ---------------------------------------------------------------------------

def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def graph_specs(tier: spec.TierSpec, kind: str, batch: int, **kw):
    """(callable, example_args) for each AOT graph kind."""
    p = tier.param_count()
    t = tier.seq_len
    if kind == "loss_eval":
        return make_loss_eval(tier, batch), (f32(p), i32(batch, t))
    if kind == "embed":
        return make_embed(tier, batch), (f32(p), i32(batch, t))
    if kind == "sgd_step":
        return make_sgd_step(tier, batch), (f32(p), i32(batch, t), f32())
    if kind == "train_step":
        return make_train_step(tier, batch), (
            f32(p), f32(p), f32(p), f32(), i32(batch, t), f32(),
        )
    if kind == "grad_extract":
        fn = make_grad_extract(tier, kw["f"], kw["c"], batch, kw.get("use_pallas", True))
        return fn, (f32(p), i32(batch, t))
    if kind == "ekfac_stats":
        return make_ekfac_stats(tier, batch), (f32(p), i32(batch, t))
    if kind == "score_lorif":
        d1, d2, c, r = kw["d1"], kw["d2"], kw["c"], kw["r"]
        fn = make_score_lorif(d1, d2, c, r, batch, kw.get("use_pallas", True))
        return fn, (
            f32(d1, c), f32(d2, c), f32(batch, d1, c), f32(batch, d2, c),
            f32(r), f32(batch, r), f32(r), f32(1),
        )
    raise ValueError(f"unknown graph kind {kind!r}")

"""Deterministic two-sided random projections (LoGRA-style).

For every tracked linear layer with dims (I, O) and projection factor f we
draw ``P_in in R^{I x d1}`` and ``P_out in R^{O x d2}`` with
``d1 = I/f, d2 = O/f`` and i.i.d. N(0, 1/d) entries (JL scaling, so
projected gradients preserve Frobenius norm in expectation).

The matrices are baked into the AOT ``grad_extract`` graphs as constants;
they are seeded deterministically from (tier, layer index, side, f) so
rebuilding artifacts reproduces the identical index.  ``f == 1`` means no
projection: the graph uses the raw gradient (identity), used by the EK-FAC
baseline and the f=1 diagnostics.
"""

import numpy as np

from . import spec

BASE_SEED = 0x10F1F  # "LoRIF"


def layer_seed(tier: str, layer_idx: int, side: str, f: int) -> int:
    h = BASE_SEED
    for tok in (tier, str(layer_idx), side, str(f)):
        for ch in tok:
            h = (h * 1000003 + ord(ch)) & 0xFFFFFFFF
    return h


def projection_pair(tier_name: str, layer_idx: int, f: int):
    """Returns (P_in, P_out) as float32 arrays, or (None, None) for f == 1."""
    tier = spec.TIERS[tier_name]
    _, _, i_dim, o_dim = tier.tracked_layers()[layer_idx]
    if f == 1:
        return None, None
    d1, d2 = i_dim // f, o_dim // f
    rng_in = np.random.default_rng(layer_seed(tier_name, layer_idx, "in", f))
    rng_out = np.random.default_rng(layer_seed(tier_name, layer_idx, "out", f))
    p_in = rng_in.standard_normal((i_dim, d1), dtype=np.float32) / np.sqrt(d1)
    p_out = rng_out.standard_normal((o_dim, d2), dtype=np.float32) / np.sqrt(d2)
    return p_in, p_out


def all_projections(tier_name: str, f: int):
    tier = spec.TIERS[tier_name]
    return [projection_pair(tier_name, idx, f) for idx in range(len(tier.tracked_layers()))]

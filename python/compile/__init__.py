"""Build-time compile path (L1 Pallas kernels + L2 JAX graphs -> HLO text).

Never imported at runtime: the Rust coordinator consumes only the
artifacts/*.hlo.txt files this package emits.
"""

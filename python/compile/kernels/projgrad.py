"""Pallas kernel: projected per-example gradient contraction  G~ = A^T B.

This is the stage-1 compute hot-spot of the indexing pass (paper Eq. 4):
for every example and every tracked linear layer we contract the projected
activations ``A = X P_in  (T, d1)`` against the projected output gradients
``B = dY P_out  (T, d2)`` into the projected gradient matrix ``(d1, d2)``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the *output*
(d1 x d2); each program holds an (T, bd1) strip of A and an (T, bd2) strip
of B in VMEM and performs one MXU contraction over the token axis.  The
paper's CUDA version tiles threadblocks over the same output; BlockSpec
expresses the identical HBM->VMEM schedule.

Runs under ``interpret=True`` everywhere in this repo (CPU PJRT cannot
execute Mosaic custom-calls); on a real TPU the same kernel lowers to
Mosaic unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    # a_ref: (T, bd1) strip, b_ref: (T, bd2) strip -> o_ref: (bd1, bd2)
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (static tiling)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def projgrad(a, b, interpret: bool = True):
    """A: (T, d1), B: (T, d2) -> (d1, d2) = A^T B."""
    t, d1 = a.shape
    t2, d2 = b.shape
    assert t == t2, (a.shape, b.shape)
    bd1 = _pick_block(d1, 128)
    bd2 = _pick_block(d2, 128)
    grid = (d1 // bd1, d2 // bd2)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, bd1), lambda i, j: (0, i)),
            pl.BlockSpec((t, bd2), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd1, bd2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1, d2), jnp.float32),
        interpret=interpret,
    )(a, b)


def vmem_estimate(t: int, d1: int, d2: int) -> int:
    """VMEM bytes per program (f32): A strip + B strip + output tile."""
    bd1, bd2 = _pick_block(d1, 128), _pick_block(d2, 128)
    return 4 * (t * bd1 + t * bd2 + bd1 * bd2)

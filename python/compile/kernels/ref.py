"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every Pallas kernel in this package has a reference implementation here
with *identical* math (same iteration counts, same init, same epsilon), so
pytest/hypothesis can assert tight tolerances.  These functions are also
used directly by the L2 graphs when ``use_pallas=False`` (useful for
debugging and for the jnp-vs-pallas perf comparison in EXPERIMENTS.md
§Perf).
"""

import jax.numpy as jnp
from jax import lax

EPS = 1e-12


def projgrad(a, b):
    """Projected per-example gradient: G~ = A^T B.

    a: (T, d1) projected activations, b: (T, d2) projected output grads.
    """
    return a.T @ b


def _power_init(d2: int, c: int):
    """Deterministic pseudo-random init for the power-iteration subspace."""
    i = lax.broadcasted_iota(jnp.float32, (d2, c), 0)
    j = lax.broadcasted_iota(jnp.float32, (d2, c), 1)
    return jnp.cos(0.7 * i + 1.3 * j + 1.0)


def _orthonormalize(m):
    """Modified Gram-Schmidt over columns (c is small and static)."""
    cols = []
    for k in range(m.shape[1]):
        v = m[:, k]
        for q in cols:
            v = v - jnp.dot(q, v) * q
        v = v / jnp.sqrt(jnp.dot(v, v) + EPS)
        cols.append(v)
    return jnp.stack(cols, axis=1)


def poweriter(g, c: int, iters: int):
    """Rank-c factorization G ~= u v^T via block power (subspace) iteration.

    Returns (u, v) with v column-orthonormal, u = G v.  Matches paper §3.1:
    a few block power iterations on the *projected* gradient matrix.
    """
    v = _orthonormalize(_power_init(g.shape[1], c))
    for _ in range(iters):
        u = _orthonormalize(g @ v)
        v = _orthonormalize(g.T @ u)
    u = g @ v
    return u, v


def factor_dot(u_q, v_q, u_t, v_t):
    """<u_q v_q^T, u_t v_t^T>_F = sum((u_q^T u_t) * (v_q^T v_t))."""
    return jnp.sum((u_q.T @ u_t) * (v_q.T @ v_t))


def score_batch(u_q, v_q, big_u, big_v, gq_r, gt_r, w, lam):
    """LoRIF influence scores, Eq. (9) of the paper, for one layer.

    u_q:(d1,c) v_q:(d2,c)  query factors
    big_u:(B,d1,c) big_v:(B,d2,c)  training factors
    gq_r:(r,) gt_r:(B,r)  V_r-subspace projections of query/train gradients
    w:(r,)  Woodbury weights sigma_i^2/(lam*(lam+sigma_i^2)) -- precomputed
    returns (B,) scores: (1/lam) * factor_dot - sum_i w_i gq_i gt_i.
    """
    # batched factor dot: einsum over the small c x c inner products
    dots = jnp.einsum("ak,nal->nkl", u_q, big_u) * jnp.einsum(
        "bk,nbl->nkl", v_q, big_v
    )
    s1 = jnp.sum(dots, axis=(1, 2))
    corr = gt_r @ (w * gq_r)
    return s1 / lam - corr


def woodbury_weights(sigma, lam):
    """w_i = sigma_i^2 / (lam * (lam + sigma_i^2)), Eq. (13)."""
    s2 = sigma * sigma
    return s2 / (lam * (lam + s2))


def dense_influence(g_q, g_t, k_inv):
    """Full-rank reference: g_q^T (G^T G + lam I)^{-1} g_t with dense K."""
    return g_q @ k_inv @ g_t

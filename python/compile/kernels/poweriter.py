"""Pallas kernel: rank-c factorization of a projected gradient matrix.

Implements paper §3.1: ``G~ ~= u v^T`` via block power (subspace)
iteration with a fixed, static iteration count (8 for c=1, 16 for c>1 —
App. B.2), so the kernel has fully static control flow (a requirement for
Mosaic lowering; the iteration count is compiled in).

The whole (d1, d2) matrix fits in VMEM for every tier in this repo
(largest layer: 192x576 f32 = 432 KiB << 16 MiB), so the kernel runs as a
single program; the batch dimension is mapped by ``jax.vmap`` outside.
Gram-Schmidt is unrolled over the (small, static) rank c.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

EPS = ref.EPS


def _orthonormalize_cols(m, c: int):
    cols = []
    for k in range(c):
        v = m[:, k]
        for q in cols:
            v = v - jnp.dot(q, v) * q
        v = v / jnp.sqrt(jnp.dot(v, v) + EPS)
        cols.append(v)
    return jnp.stack(cols, axis=1)


def _kernel(g_ref, u_ref, v_ref, *, c: int, iters: int):
    g = g_ref[...]
    d2 = g.shape[1]
    i = jax.lax.broadcasted_iota(jnp.float32, (d2, c), 0)
    j = jax.lax.broadcasted_iota(jnp.float32, (d2, c), 1)
    v = _orthonormalize_cols(jnp.cos(0.7 * i + 1.3 * j + 1.0), c)
    # static unroll: `iters` is small (8/16) and compiled in
    for _ in range(iters):
        u = _orthonormalize_cols(g @ v, c)
        v = _orthonormalize_cols(g.T @ u, c)
    u_ref[...] = g @ v
    v_ref[...] = v


@functools.partial(jax.jit, static_argnames=("c", "iters", "interpret"))
def poweriter(g, c: int, iters: int, interpret: bool = True):
    """G: (d1, d2) -> (u: (d1, c), v: (d2, c)) with G ~= u v^T."""
    d1, d2 = g.shape
    kern = functools.partial(_kernel, c=c, iters=iters)
    return pl.pallas_call(
        kern,
        out_shape=(
            jax.ShapeDtypeStruct((d1, c), jnp.float32),
            jax.ShapeDtypeStruct((d2, c), jnp.float32),
        ),
        interpret=interpret,
    )(g)


def vmem_estimate(d1: int, d2: int, c: int) -> int:
    """VMEM bytes (f32): G + u + v + one GS scratch column set."""
    return 4 * (d1 * d2 + (d1 + 2 * d2) * c + max(d1, d2))

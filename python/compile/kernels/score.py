"""Pallas kernel: LoRIF batched influence scoring (paper Eq. 9), one layer.

The query hot-path: score one query against a batch of training examples
using only rank-c factors and r-dim curvature-subspace projections:

    s_n = (1/lam) * <u_q v_q^T, U_n V_n^T>_F  -  sum_i w_i g'_{q,i} g'_{n,i}

The factor dot is computed from the (c x c) inner-product matrices —
O(c^2 (d1+d2)) per pair instead of O(d1 d2) — which is exactly the paper's
I/O-and-compute win.  The Woodbury correction is a (B, r) @ (r,) matvec
with the weights w_i = sigma_i^2/(lam (lam + sigma_i^2)) folded in.

TPU mapping: the grid tiles the training-batch axis; each program holds
one (bn, d1, c) / (bn, d2, c) slab of factors plus the broadcast query in
VMEM, and the two contraction steps map onto the MXU as (bn*c, d1) x
(d1, c)-shaped matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(uq_ref, vq_ref, u_ref, v_ref, gqw_ref, gt_ref, lam_ref, o_ref):
    uq = uq_ref[...]  # (d1, c)
    vq = vq_ref[...]  # (d2, c)
    u = u_ref[...]  # (bn, d1, c)
    v = v_ref[...]  # (bn, d2, c)
    gqw = gqw_ref[...]  # (r,)  = w * g'_q, precombined
    gt = gt_ref[...]  # (bn, r)
    inv_lam = 1.0 / lam_ref[0]
    # (bn, c, c) inner products via dot_general batching
    uu = jax.lax.dot_general(
        u, uq, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bn, c, c): uu[n,l,k] = sum_a U[n,a,l] uq[a,k]
    vv = jax.lax.dot_general(
        v, vq, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s1 = jnp.sum(uu * vv, axis=(1, 2))
    corr = jax.lax.dot_general(
        gt, gqw, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = s1 * inv_lam - corr


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_batch(u_q, v_q, big_u, big_v, gq_r, gt_r, w, lam, interpret: bool = True):
    """Score one query against B training examples for one layer.

    u_q (d1,c), v_q (d2,c), big_u (B,d1,c), big_v (B,d2,c),
    gq_r (r,), gt_r (B,r), w (r,), lam scalar -> (B,) scores.
    """
    b, d1, c = big_u.shape
    _, d2, _ = big_v.shape
    r = gq_r.shape[0]
    bn = _pick_block(b, 256)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape((1,))
    gqw = w * gq_r  # fold Woodbury weights into the query projection
    grid = (b // bn,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d1, c), lambda n: (0, 0)),
            pl.BlockSpec((d2, c), lambda n: (0, 0)),
            pl.BlockSpec((bn, d1, c), lambda n: (n, 0, 0)),
            pl.BlockSpec((bn, d2, c), lambda n: (n, 0, 0)),
            pl.BlockSpec((r,), lambda n: (0,)),
            pl.BlockSpec((bn, r), lambda n: (n, 0)),
            pl.BlockSpec((1,), lambda n: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda n: (n,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(u_q, v_q, big_u, big_v, gqw, gt_r, lam_arr)


def vmem_estimate(bn: int, d1: int, d2: int, c: int, r: int) -> int:
    """VMEM bytes per program (f32)."""
    return 4 * (bn * (d1 * c + d2 * c + r + 1) + (d1 + d2) * c + r)

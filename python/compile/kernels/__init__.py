"""L1: Pallas kernels for LoRIF's compute hot-spots + pure-jnp oracles."""
from . import projgrad, poweriter, ref, score  # noqa: F401

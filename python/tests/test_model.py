"""L2 graph correctness: shapes, probe-gradient extraction vs autodiff,
training step behaviour, EK-FAC stats."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, projection, spec

TIER = spec.TIERS["small"]
RNG = np.random.default_rng(42)


def rand_params(scale=0.05, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(TIER.param_count()) * scale).astype(np.float32)


def rand_tokens(batch, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(0, spec.VOCAB, (batch, TIER.seq_len)).astype(np.int32)


# ---------------------------------------------------------------------------
# spec invariants
# ---------------------------------------------------------------------------

def test_param_count_matches_shapes():
    total = sum(spec.int_prod(s) for _, s in TIER.param_shapes())
    assert total == TIER.param_count()


@pytest.mark.parametrize("tier", list(spec.TIERS.values()), ids=lambda t: t.name)
@pytest.mark.parametrize("f", [1, 2, 4, 8, 16])
def test_proj_dims_divisible(tier, f):
    dims = tier.proj_dims(f)
    assert all(d1 > 0 and d2 > 0 for d1, d2 in dims)
    assert tier.total_proj_dim(f) == sum(d1 * d2 for d1, d2 in dims)


def test_tracked_layer_modules():
    kinds = {k for _, k, _, _ in TIER.tracked_layers()}
    assert kinds == {"attn", "mlp"}
    assert len(TIER.tracked_layers()) == 4 * TIER.n_layers


def test_projection_deterministic_and_scaled():
    p_in, p_out = projection.projection_pair("small", 0, 4)
    p_in2, _ = projection.projection_pair("small", 0, 4)
    np.testing.assert_array_equal(p_in, p_in2)
    # JL scaling: E||P^T x||^2 ~= ||x||^2
    x = RNG.standard_normal(p_in.shape[0]).astype(np.float32)
    ratios = []
    for trial in range(20):
        xt = np.random.default_rng(trial).standard_normal(p_in.shape[0]).astype(np.float32)
        ratios.append(np.sum((xt @ p_in) ** 2) / np.sum(xt**2))
    assert 0.5 < np.mean(ratios) < 1.5


def test_projection_f1_is_identity_marker():
    assert projection.projection_pair("small", 0, 1) == (None, None)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def test_loss_eval_shape_and_range():
    flat, toks = rand_params(), rand_tokens(4)
    losses = np.asarray(jax.jit(model.make_loss_eval(TIER, 4))(flat, toks)[0])
    assert losses.shape == (4,)
    # near-uniform init => loss ~ log(V)
    assert np.all(losses > 2.0) and np.all(losses < 8.0)


def test_forward_causality():
    """Changing a future token must not change past logits."""
    flat = rand_params()
    params = model.unflatten(TIER, jnp.asarray(flat))
    toks = rand_tokens(1)[0]
    logits1, _, _ = model.forward(TIER, params, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[-1] = (toks2[-1] + 1) % spec.VOCAB
    logits2, _, _ = model.forward(TIER, params, jnp.asarray(toks2))
    np.testing.assert_allclose(
        np.asarray(logits1[:-1]), np.asarray(logits2[:-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(logits1[-1]), np.asarray(logits2[-1]))


def test_embed_shape():
    flat, toks = rand_params(), rand_tokens(3)
    emb = np.asarray(jax.jit(model.make_embed(TIER, 3))(flat, toks)[0])
    assert emb.shape == (3, TIER.d_model)
    assert np.all(np.isfinite(emb))


# ---------------------------------------------------------------------------
# probe-trick gradient extraction vs direct autodiff
# ---------------------------------------------------------------------------

def test_probe_gradients_match_weight_gradients():
    """X^T dY from the probe trick must equal d loss / d W exactly."""
    flat = rand_params()
    toks = rand_tokens(1)[0]
    ge = jax.jit(model.make_grad_extract(TIER, 1, 1, 1, use_pallas=False))
    outs = ge(flat, toks[None])
    # direct per-parameter gradient
    def loss_of_flat(fl):
        params = model.unflatten(TIER, fl)
        return model.example_loss(TIER, params, jnp.asarray(toks))[0]

    gflat = jax.grad(loss_of_flat)(jnp.asarray(flat))
    grads = model.unflatten(TIER, gflat)
    layers = TIER.tracked_layers()
    for idx, (name, _, i_dim, o_dim) in enumerate(layers):
        got = np.asarray(outs[1 + 3 * idx][0])  # G~ with f=1 == X^T dY
        want = np.asarray(grads[name])
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=1e-6), name


def test_projected_gradient_consistency():
    """f>1 projected gradient == P_in^T (X^T dY) P_out."""
    flat = rand_params()
    toks = rand_tokens(2)
    f = 4
    full = jax.jit(model.make_grad_extract(TIER, 1, 1, 2, use_pallas=False))(flat, toks)
    proj = jax.jit(model.make_grad_extract(TIER, f, 1, 2, use_pallas=False))(flat, toks)
    projs = projection.all_projections("small", f)
    for idx in range(len(TIER.tracked_layers())):
        p_in, p_out = projs[idx]
        g_full = np.asarray(full[1 + 3 * idx])
        g_proj = np.asarray(proj[1 + 3 * idx])
        want = np.einsum("nio,ia,ob->nab", g_full, p_in, p_out)
        np.testing.assert_allclose(g_proj, want, rtol=1e-3, atol=1e-5)


def test_grad_extract_pallas_matches_jnp():
    flat, toks = rand_params(), rand_tokens(2)
    a = jax.jit(model.make_grad_extract(TIER, 4, 2, 2, use_pallas=True))(flat, toks)
    b = jax.jit(model.make_grad_extract(TIER, 4, 2, 2, use_pallas=False))(flat, toks)
    assert len(a) == len(b) == 1 + 3 * len(TIER.tracked_layers())
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=2e-3, atol=2e-3)


def test_factor_reconstruction_quality():
    """rank-c reconstruction error decreases with c (Table 9 behaviour)."""
    flat, toks = rand_params(), rand_tokens(4)
    errs = {}
    for c in (1, 4):
        outs = jax.jit(model.make_grad_extract(TIER, 2, c, 4, use_pallas=False))(flat, toks)
        g = np.asarray(outs[1])
        u, v = np.asarray(outs[2]), np.asarray(outs[3])
        rec = np.einsum("nac,nbc->nab", u, v)
        errs[c] = np.linalg.norm(rec - g) / np.linalg.norm(g)
    assert errs[4] < errs[1] <= 1.0


# ---------------------------------------------------------------------------
# training step
# ---------------------------------------------------------------------------

def test_train_step_decreases_loss():
    flat = rand_params(scale=0.02)
    toks = rand_tokens(8)
    ts = jax.jit(model.make_train_step(TIER, 8))
    p = jnp.asarray(flat)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    losses = []
    for step in range(1, 31):
        p, m, v, loss = ts(p, m, v, jnp.float32(step), toks, jnp.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_train_step_preserves_shapes_and_finiteness():
    flat = rand_params()
    toks = rand_tokens(4)
    ts = jax.jit(model.make_train_step(TIER, 4))
    p, m, v, loss = ts(
        jnp.asarray(flat), jnp.zeros(len(flat)), jnp.zeros(len(flat)),
        jnp.float32(1), toks, jnp.float32(1e-3),
    )
    assert p.shape == (TIER.param_count(),)
    for arr in (p, m, v):
        assert np.all(np.isfinite(np.asarray(arr)))


# ---------------------------------------------------------------------------
# EK-FAC stats
# ---------------------------------------------------------------------------

def test_ekfac_stats_shapes_and_psd():
    flat, toks = rand_params(), rand_tokens(2)
    outs = jax.jit(model.make_ekfac_stats(TIER, 2))(flat, toks)
    layers = TIER.tracked_layers()
    assert len(outs) == 2 * len(layers)
    for idx, (_, _, i_dim, o_dim) in enumerate(layers):
        a_cov = np.asarray(outs[2 * idx])
        s_cov = np.asarray(outs[2 * idx + 1])
        assert a_cov.shape == (i_dim, i_dim)
        assert s_cov.shape == (o_dim, o_dim)
        # covariances are symmetric PSD
        np.testing.assert_allclose(a_cov, a_cov.T, atol=1e-3)
        assert np.linalg.eigvalsh(a_cov).min() > -1e-3

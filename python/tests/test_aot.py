"""AOT pipeline regression tests.

Covers the xla_extension-0.5.1 interop contract: HLO text interchange,
manifest schema, and — critically — that large constants (the baked
projection matrices) are printed in full.  The default HLO printer elides
literals > 1024 elements as ``constant({...})``, which the 0.5.1 text
parser silently reads back as ZEROS (loss fine, all gradients zero); see
EXPERIMENTS.md §Debugging.
"""

import json

import jax
import numpy as np
import pytest

from compile import aot, model, spec


@pytest.fixture(scope="module")
def lowered_grad_extract():
    tier = spec.TIERS["small"]
    fn, ex = model.graph_specs(tier, "grad_extract", 2, f=4, c=1)
    lowered = jax.jit(fn).lower(*ex)
    return aot.to_hlo_text(lowered)


def test_no_elided_constants(lowered_grad_extract):
    assert "constant({...})" not in lowered_grad_extract


def test_projection_constants_materialized(lowered_grad_extract):
    # the f=4 graph bakes P_in (64, 16) etc. as full f32 literals: the
    # text must contain multi-element float constants of that shape
    assert "f32[64,16]" in lowered_grad_extract


def test_entry_tuple_arity(lowered_grad_extract):
    # 1 loss + 3 outputs per tracked layer
    tier = spec.TIERS["small"]
    want = 1 + 3 * len(tier.tracked_layers())
    # count top-level tuple elements in the ENTRY ROOT
    import re

    entry = lowered_grad_extract[lowered_grad_extract.index("ENTRY") :]
    m = re.search(r"ROOT [^=]+ = \(([^)]*)\) tuple\(", entry)
    assert m, "no ROOT tuple in ENTRY"
    arity = m.group(1).count("f32[")
    assert arity == want, (arity, want)


def test_manifest_generation(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "arts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--set", "minimal"],
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    doc = json.loads((out / "manifest.json").read_text())
    assert doc["version"] == aot.MANIFEST_VERSION
    names = {g["name"] for g in doc["graphs"]}
    assert "grad_extract_small_f4_c1" in names
    assert "train_step_small" in names
    assert "sgd_step_small" in names
    # tier metadata cross-checks the rust spec
    assert doc["tiers"]["small"]["param_count"] == spec.TIERS["small"].param_count()
    for g in doc["graphs"]:
        assert (out / f"{g['name']}.hlo.txt").exists()
        assert g["hlo_bytes"] > 0

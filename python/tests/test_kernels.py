"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes per the repo test policy; tolerances are
tight because kernel and oracle share identical math.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import poweriter, projgrad, ref, score

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# projgrad
# ---------------------------------------------------------------------------

@given(
    t=st.sampled_from([8, 64, 96]),
    d1=st.sampled_from([4, 16, 48, 96]),
    d2=st.sampled_from([4, 12, 64, 192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_projgrad_matches_ref(t, d1, d2, seed):
    rng = np.random.default_rng(seed)
    a, b = _rand(rng, t, d1), _rand(rng, t, d2)
    got = np.asarray(projgrad.projgrad(a, b))
    want = np.asarray(ref.projgrad(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_projgrad_zero_inputs():
    a = np.zeros((16, 8), np.float32)
    b = np.zeros((16, 12), np.float32)
    assert np.all(np.asarray(projgrad.projgrad(a, b)) == 0.0)


def test_projgrad_identity_structure():
    # A = e_i rows -> A^T B picks rows of B
    t, d1, d2 = 4, 4, 6
    a = np.eye(t, d1, dtype=np.float32)
    b = np.arange(t * d2, dtype=np.float32).reshape(t, d2)
    got = np.asarray(projgrad.projgrad(a, b))
    np.testing.assert_allclose(got, b[:d1], rtol=1e-6)


def test_projgrad_vmem_estimate_positive():
    assert projgrad.vmem_estimate(64, 192, 576) > 0
    # largest tier layer must fit in 16 MiB VMEM
    assert projgrad.vmem_estimate(64, 192, 576) < 16 * 2**20


# ---------------------------------------------------------------------------
# poweriter
# ---------------------------------------------------------------------------

@given(
    d1=st.sampled_from([8, 16, 48]),
    d2=st.sampled_from([8, 24, 64]),
    c=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_poweriter_matches_ref(d1, d2, c, seed):
    """Pallas vs jnp oracle.

    Raw factors are fp-sensitive when singular values are nearly
    degenerate (power iteration amplifies rounding into direction
    differences), so we compare the convergence-stable invariants:
    u == G v for the kernel's own v, and the reconstruction error matches
    the oracle's.
    """
    rng = np.random.default_rng(seed)
    g = _rand(rng, d1, d2)
    iters = 8 if c == 1 else 16
    u, v = map(np.asarray, poweriter.poweriter(g, c, iters))
    ur, vr = map(np.asarray, ref.poweriter(jnp.asarray(g), c, iters))
    # u is exactly G v by construction
    np.testing.assert_allclose(u, g @ v, rtol=1e-4, atol=1e-5)
    err_pallas = np.linalg.norm(u @ v.T - g)
    err_ref = np.linalg.norm(ur @ vr.T - g)
    scale = np.linalg.norm(g)
    assert abs(err_pallas - err_ref) <= 0.02 * scale + 1e-5, (err_pallas, err_ref)


@given(c=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31 - 1))
def test_poweriter_v_orthonormal(c, seed):
    rng = np.random.default_rng(seed)
    g = _rand(rng, 24, 32)
    _, v = poweriter.poweriter(g, c, 16)
    v = np.asarray(v)
    np.testing.assert_allclose(v.T @ v, np.eye(c), atol=1e-4)


def test_poweriter_exact_on_rank1():
    # an exactly rank-1 matrix must be reconstructed (near) exactly
    rng = np.random.default_rng(7)
    a = _rand(rng, 16, 1)
    b = _rand(rng, 24, 1)
    g = a @ b.T
    u, v = poweriter.poweriter(g, 1, 8)
    rec = np.asarray(u) @ np.asarray(v).T
    np.testing.assert_allclose(rec, g, rtol=1e-4, atol=1e-5)


def test_poweriter_captures_top_singular_space():
    # reconstruction error must match the optimal rank-c error (Eckart-Young)
    rng = np.random.default_rng(3)
    g = _rand(rng, 32, 48)
    for c in (1, 2, 4):
        u, v = poweriter.poweriter(g, c, 32)
        rec = np.asarray(u) @ np.asarray(v).T
        err = np.linalg.norm(rec - g)
        s = np.linalg.svd(g, compute_uv=False)
        opt = np.sqrt(np.sum(s[c:] ** 2))
        assert err <= opt * 1.05 + 1e-5, (c, err, opt)


def test_poweriter_zero_matrix_is_finite():
    g = np.zeros((8, 12), np.float32)
    u, v = poweriter.poweriter(g, 2, 16)
    assert np.all(np.isfinite(np.asarray(u))) and np.all(np.isfinite(np.asarray(v)))
    np.testing.assert_allclose(np.asarray(u), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# score
# ---------------------------------------------------------------------------

def _score_inputs(rng, b, d1, d2, c, r):
    return (
        _rand(rng, d1, c), _rand(rng, d2, c),
        _rand(rng, b, d1, c), _rand(rng, b, d2, c),
        _rand(rng, r), _rand(rng, b, r),
        np.abs(_rand(rng, r)), 0.25,
    )


@given(
    b=st.sampled_from([1, 8, 64, 256]),
    c=st.sampled_from([1, 2, 4]),
    r=st.sampled_from([4, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matches_ref(b, c, r, seed):
    rng = np.random.default_rng(seed)
    d1, d2 = 16, 24
    uq, vq, U, V, gq, gt, w, lam = _score_inputs(rng, b, d1, d2, c, r)
    got = np.asarray(score.score_batch(uq, vq, U, V, gq, gt, w, lam))
    want = np.asarray(
        ref.score_batch(
            jnp.asarray(uq), jnp.asarray(vq), jnp.asarray(U), jnp.asarray(V),
            jnp.asarray(gq), jnp.asarray(gt), jnp.asarray(w), lam,
        )
    )
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_score_factor_dot_equals_dense_frobenius():
    # (1/lam)<u_q v_q^T, u v^T>_F with zero correction == dense dot of
    # the reconstructed gradients scaled by 1/lam
    rng = np.random.default_rng(11)
    d1, d2, c = 8, 12, 2
    uq, vq = _rand(rng, d1, c), _rand(rng, d2, c)
    ut, vt = _rand(rng, 1, d1, c), _rand(rng, 1, d2, c)
    r = 4
    gq, gt = np.zeros(r, np.float32), np.zeros((1, r), np.float32)
    w = np.zeros(r, np.float32)
    lam = 0.5
    got = float(np.asarray(score.score_batch(uq, vq, ut, vt, gq, gt, w, lam))[0])
    dense_q = (uq @ vq.T).ravel()
    dense_t = (ut[0] @ vt[0].T).ravel()
    want = float(dense_q @ dense_t) / lam
    assert abs(got - want) < 1e-3 * max(1.0, abs(want))


def test_woodbury_weights_formula():
    sigma = jnp.asarray([0.0, 1.0, 3.0])
    lam = 0.5
    w = np.asarray(ref.woodbury_weights(sigma, lam))
    expect = np.array([0.0, 1.0 / (0.5 * 1.5), 9.0 / (0.5 * 9.5)])
    np.testing.assert_allclose(w, expect, rtol=1e-6)


def test_score_equals_woodbury_dense_identity():
    """Eq. (9) == g_q^T (V S^2 V^T + lam I)^{-1} g_t when factors and
    projections are exact (c = min(d1,d2), r = D): the end-to-end
    algebraic identity of the method."""
    rng = np.random.default_rng(5)
    d1, d2 = 6, 8
    D = d1 * d2
    n = 16
    G = _rand(rng, n, D)
    lam = 0.3
    # exact SVD curvature
    _, s, vt = np.linalg.svd(G, full_matrices=False)
    r = len(s)
    V = vt.T  # (D, r)
    w = np.asarray(ref.woodbury_weights(jnp.asarray(s), lam))
    gq = _rand(rng, D)
    gt = _rand(rng, D)
    # dense reference
    H = V @ np.diag(s**2) @ V.T + lam * np.eye(D)
    want = float(gq @ np.linalg.solve(H, gt))
    # factor route (exact rank)
    c = min(d1, d2)
    uq, vq = np.linalg.qr(gq.reshape(d1, d2).T)[0][:, :c], None
    # use ref.poweriter with enough iterations for near-exact factors
    uqj, vqj = ref.poweriter(jnp.asarray(gq.reshape(d1, d2)), c, 64)
    utj, vtj = ref.poweriter(jnp.asarray(gt.reshape(d1, d2)), c, 64)
    got = float(
        np.asarray(
            ref.score_batch(
                uqj, vqj,
                jnp.asarray(np.asarray(utj)[None]), jnp.asarray(np.asarray(vtj)[None]),
                jnp.asarray(V.T @ gq), jnp.asarray((V.T @ gt)[None]),
                jnp.asarray(w), lam,
            )
        )[0]
    )
    assert abs(got - want) < 5e-3 * max(1.0, abs(want)), (got, want)

//! Hand-rolled CLI argument parsing (the offline vendor set has no clap).
//!
//! Grammar: `lorif <subcommand> [--flag value | --switch] [positional...]`.
//! Flags may also be written `--flag=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

/// Flags that take no value.
const SWITCHES: &[&str] = &[
    "help",
    "verbose",
    "cached-projections",
    "no-prefetch",
    "full",
    "coordinator",
    "node",
    "json",
];

/// Parse a `k=v,k2=v2` label spec (the `metrics dump --label` flag)
/// into ordered pairs.  Keys must be non-empty and `=`-free; values may
/// contain anything except the `,` separator (escaping for the
/// Prometheus text format happens at render time, see
/// `telemetry::escape_label_value`).
pub fn parse_label_spec(spec: &str) -> anyhow::Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("label '{part}' is not k=v"))?;
        anyhow::ensure!(!k.is_empty(), "label '{part}' has an empty key");
        out.push((k.to_string(), v.to_string()));
    }
    anyhow::ensure!(!out.is_empty(), "--label needs at least one k=v pair");
    Ok(out)
}

impl Args {
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.peek() {
            if !sub.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&stripped) {
                    a.switches.push(stripped.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("flag --{stripped} needs a value"))?;
                    a.flags.insert(stripped.to_string(), v.clone());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str) -> anyhow::Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn get_f32(&self, key: &str) -> anyhow::Result<Option<f32>> {
        self.get(key)
            .map(|v| v.parse::<f32>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> anyhow::Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    /// Apply the standard config-affecting flags onto a Config.
    pub fn apply_to_config(&self, cfg: &mut crate::config::Config) -> anyhow::Result<()> {
        if let Some(path) = self.get("config") {
            *cfg = crate::config::Config::from_file(std::path::Path::new(path))?;
        }
        if let Some(t) = self.get("tier") {
            cfg.tier = crate::model::spec::Tier::parse(t)?;
        }
        macro_rules! take {
            ($field:ident, $key:literal, $getter:ident) => {
                if let Some(v) = self.$getter($key)? {
                    cfg.$field = v;
                }
            };
        }
        take!(f, "f", get_usize);
        take!(c, "c", get_usize);
        take!(r, "r", get_usize);
        take!(n_train, "n-train", get_usize);
        take!(n_query, "n-query", get_usize);
        take!(n_topics, "n-topics", get_usize);
        take!(seed, "seed", get_u64);
        take!(train_steps, "train-steps", get_usize);
        take!(train_lr, "train-lr", get_f32);
        take!(lambda_factor, "lambda-factor", get_f32);
        take!(rsvd_power_iters, "rsvd-power-iters", get_usize);
        take!(shards, "shards", get_usize);
        take!(score_threads, "score-threads", get_usize);
        take!(prefetch_depth, "prefetch-depth", get_usize);
        take!(chunk_cache_mb, "chunk-cache-mb", get_usize);
        take!(summary_chunk, "summary-chunk", get_usize);
        take!(cluster, "cluster", get_usize);
        if let Some(s) = self.get("sink") {
            cfg.score_sink = crate::attribution::SinkMode::parse(s)?;
        }
        if let Some(s) = self.get("prune") {
            cfg.prune = crate::sketch::PruneMode::parse(s)?;
        }
        if let Some(s) = self.get("codec") {
            cfg.codec = crate::store::CodecId::parse(s)?;
        }
        if let Some(s) = self.get("quant-score") {
            cfg.quant_score = crate::store::QuantScore::parse(s)?;
        }
        if let Some(d) = self.get("artifacts-dir") {
            cfg.artifacts_dir = d.into();
        }
        if let Some(d) = self.get("work-dir") {
            cfg.work_dir = d.into();
        }
        if let Some(p) = self.get("trace-out") {
            cfg.trace_out = Some(p.into());
        }
        cfg.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = parse(&["query", "--f", "8", "--tier=medium", "--verbose", "q.bin"]);
        assert_eq!(a.subcommand, "query");
        assert_eq!(a.get("f"), Some("8"));
        assert_eq!(a.get("tier"), Some("medium"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["q.bin"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["x", "--r", "256", "--train-lr", "0.003"]);
        assert_eq!(a.get_usize("r").unwrap(), Some(256));
        assert!((a.get_f32("train-lr").unwrap().unwrap() - 0.003).abs() < 1e-9);
        assert_eq!(a.get_usize("missing").unwrap(), None);
        assert!(parse(&["x", "--r", "abc"]).get_usize("r").is_err());
    }

    #[test]
    fn serve_mode_switches_take_no_value() {
        // --coordinator / --node are switches: the token after them is a
        // flag, not their value
        let a = parse(&["serve", "--coordinator", "--nodes", "a:1=0"]);
        assert!(a.has("coordinator"));
        assert_eq!(a.get("nodes"), Some("a:1=0"));
        let a = parse(&["serve", "--node", "--node-shards", "0-2"]);
        assert!(a.has("node"));
        assert_eq!(a.get("node-shards"), Some("0-2"));
    }

    #[test]
    fn missing_value_errors() {
        let argv: Vec<String> = vec!["x".into(), "--f".into()];
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn applies_to_config() {
        let a = parse(&[
            "x", "--f", "8", "--c", "2", "--tier", "medium", "--n-train", "512", "--shards",
            "4", "--score-threads", "2", "--sink", "topk", "--prune", "slack=0.1",
            "--prefetch-depth", "3", "--chunk-cache-mb", "128", "--summary-chunk", "64",
            "--cluster", "16", "--codec", "int8", "--quant-score", "on",
            "--trace-out", "work/trace.json",
        ]);
        let mut cfg = crate::config::Config::default();
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.f, 8);
        assert_eq!(cfg.c, 2);
        assert_eq!(cfg.n_train, 512);
        assert_eq!(cfg.tier, crate::model::spec::Tier::Medium);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.score_threads, 2);
        assert_eq!(cfg.score_sink, crate::attribution::SinkMode::TopK);
        assert_eq!(cfg.prune, crate::sketch::PruneMode::Slack(0.1));
        assert_eq!(cfg.prefetch_depth, 3);
        assert_eq!(cfg.chunk_cache_mb, 128);
        assert_eq!(cfg.summary_chunk, 64);
        assert_eq!(cfg.cluster, 16);
        assert_eq!(cfg.codec, crate::store::CodecId::Int8);
        assert_eq!(cfg.quant_score, crate::store::QuantScore::On);
        assert_eq!(cfg.trace_out.as_deref(), Some(std::path::Path::new("work/trace.json")));
    }

    #[test]
    fn rejects_unknown_quant_score() {
        let a = parse(&["x", "--quant-score", "fast"]);
        let mut cfg = crate::config::Config::default();
        assert!(a.apply_to_config(&mut cfg).is_err());
        let a = parse(&["x", "--quant-score", "off"]);
        let mut cfg = crate::config::Config::default();
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.quant_score, crate::store::QuantScore::Off);
    }

    #[test]
    fn rejects_unknown_codec() {
        let a = parse(&["x", "--codec", "zip"]);
        let mut cfg = crate::config::Config::default();
        assert!(a.apply_to_config(&mut cfg).is_err());
        let a = parse(&["x", "--codec", "int4"]);
        let mut cfg = crate::config::Config::default();
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.codec, crate::store::CodecId::Int4);
    }

    #[test]
    fn rejects_unknown_prune_mode() {
        let a = parse(&["x", "--prune", "fuzzy"]);
        let mut cfg = crate::config::Config::default();
        assert!(a.apply_to_config(&mut cfg).is_err());
        let a = parse(&["x", "--prune", "off"]);
        let mut cfg = crate::config::Config::default();
        a.apply_to_config(&mut cfg).unwrap();
        assert_eq!(cfg.prune, crate::sketch::PruneMode::Off);
    }

    #[test]
    fn label_spec_parses_pairs_and_rejects_malformed() {
        assert_eq!(
            parse_label_spec("role=coordinator,env=ci").unwrap(),
            vec![
                ("role".to_string(), "coordinator".to_string()),
                ("env".to_string(), "ci".to_string())
            ]
        );
        // values may carry '=' (only the first splits)
        assert_eq!(
            parse_label_spec("q=a=b").unwrap(),
            vec![("q".to_string(), "a=b".to_string())]
        );
        assert!(parse_label_spec("novalue").is_err());
        assert!(parse_label_spec("=x").is_err());
        assert!(parse_label_spec("").is_err());
    }

    #[test]
    fn rejects_unknown_sink() {
        let a = parse(&["x", "--sink", "columnar"]);
        let mut cfg = crate::config::Config::default();
        assert!(a.apply_to_config(&mut cfg).is_err());
    }
}

//! Gradient-side utilities: CPU factorization oracle and extraction
//! drivers (the AOT-graph wrappers live in runtime::graphs).

pub mod factorize;

//! CPU rank-c factorization via block power iteration — identical math
//! to the L1 Pallas kernel (`python/compile/kernels/poweriter.py`), used
//! for diagnostics (Table 9 rank sweeps without re-running extraction)
//! and as the test oracle on the Rust side.

use crate::linalg::Mat;

const EPS: f32 = 1e-12;

fn orthonormalize_cols(m: &mut Mat) {
    let (rows, cols) = (m.rows, m.cols);
    for k in 0..cols {
        let mut col: Vec<f32> = (0..rows).map(|r| m.at(r, k)).collect();
        for q in 0..k {
            let mut dot = 0.0f32;
            for r in 0..rows {
                dot += m.at(r, q) * col[r];
            }
            for r in 0..rows {
                col[r] -= dot * m.at(r, q);
            }
        }
        let norm = (col.iter().map(|x| x * x).sum::<f32>() + EPS).sqrt();
        for r in 0..rows {
            *m.at_mut(r, k) = col[r] / norm;
        }
    }
}

/// Deterministic init matching the Pallas kernel: cos(0.7 i + 1.3 j + 1).
fn power_init(d2: usize, c: usize) -> Mat {
    let mut v = Mat::zeros(d2, c);
    for i in 0..d2 {
        for j in 0..c {
            *v.at_mut(i, j) = (0.7 * i as f32 + 1.3 * j as f32 + 1.0).cos();
        }
    }
    v
}

/// G (d1, d2) ~= u v^T with u (d1, c) = G v, v (d2, c) orthonormal.
pub fn poweriter(g: &Mat, c: usize, iters: usize) -> (Mat, Mat) {
    let mut v = power_init(g.cols, c);
    orthonormalize_cols(&mut v);
    for _ in 0..iters {
        let mut u = g.matmul(&v);
        orthonormalize_cols(&mut u);
        v = g.matmul_tn(&u);
        orthonormalize_cols(&mut v);
    }
    let u = g.matmul(&v);
    (u, v)
}

/// Relative Frobenius reconstruction error ||uv^T - G|| / ||G||
/// and the explained-variance ratio (Table 9 columns).
pub fn reconstruction_error(g: &Mat, u: &Mat, v: &Mat) -> (f32, f32) {
    let rec = u.matmul_nt(v);
    let mut err2 = 0.0f32;
    let mut tot2 = 0.0f32;
    for (x, y) in rec.data.iter().zip(&g.data) {
        err2 += (x - y) * (x - y);
        tot2 += y * y;
    }
    if tot2 == 0.0 {
        return (0.0, 1.0);
    }
    let rel = (err2 / tot2).sqrt();
    let evr = 1.0 - err2 / tot2;
    (rel, evr.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exact_on_rank_c() {
        let mut rng = Rng::new(1);
        for c in [1, 2, 3] {
            let a = Mat::random_normal(10, c, 1.0, &mut rng);
            let b = Mat::random_normal(c, 14, 1.0, &mut rng);
            let g = a.matmul(&b);
            let (u, v) = poweriter(&g, c, 24);
            let (rel, evr) = reconstruction_error(&g, &u, &v);
            assert!(rel < 1e-2, "c={c} rel={rel}");
            assert!(evr > 0.999);
        }
    }

    #[test]
    fn error_decreases_with_c() {
        let mut rng = Rng::new(2);
        let g = Mat::random_normal(12, 16, 1.0, &mut rng);
        let errs: Vec<f32> = [1, 2, 4, 8]
            .iter()
            .map(|&c| {
                let (u, v) = poweriter(&g, c, 16);
                reconstruction_error(&g, &u, &v).0
            })
            .collect();
        assert!(errs.windows(2).all(|w| w[1] <= w[0] + 1e-5), "{errs:?}");
    }

    #[test]
    fn v_orthonormal() {
        let mut rng = Rng::new(3);
        let g = Mat::random_normal(9, 11, 1.0, &mut rng);
        let (_, v) = poweriter(&g, 3, 16);
        let vtv = v.matmul_tn(&v);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn near_eckart_young() {
        let mut rng = Rng::new(4);
        let g = Mat::random_normal(16, 20, 1.0, &mut rng);
        for c in [1, 2] {
            let (u, v) = poweriter(&g, c, 32);
            let rec = u.matmul_nt(&v);
            let mut err2 = 0.0;
            for (x, y) in rec.data.iter().zip(&g.data) {
                err2 += (x - y) * (x - y);
            }
            let (_, s, _) = crate::linalg::eigh::svd_small(&g);
            let opt2: f32 = s[c..].iter().map(|x| x * x).sum();
            assert!(err2.sqrt() <= opt2.sqrt() * 1.05 + 1e-4, "c={c}");
        }
    }
}

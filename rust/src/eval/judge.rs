//! Programmatic relevance judge — the Claude-Haiku stand-in for the
//! paper's LLM-as-a-judge evaluation (Tables 3/11/12/13; DESIGN.md §1).
//!
//! The synthetic corpus carries ground-truth latent structure (topic id +
//! inserted template ids), so the paper's 1–5 rubric maps to measurable
//! agreement:
//!   5  same topic AND a shared template phrase ("nearly identical task")
//!   4  same topic ("closely related problem")
//!   3  different topic but high token-set overlap ("same broad topic")
//!   2  moderate token-set overlap ("vaguely related")
//!   1  otherwise ("completely irrelevant")

use crate::corpus::{Dataset, TopicModel};

#[derive(Clone, Debug, Default)]
pub struct JudgeSummary {
    pub avg_score: f64,
    /// histogram over scores 1..=5 (fractions)
    pub dist: [f64; 5],
    pub score1_rate: f64,
    pub score_ge4_rate: f64,
}

/// Jaccard overlap of two topics' preferred token sets.
fn topic_overlap(tm: &TopicModel, a: usize, b: usize) -> f64 {
    let sa: std::collections::BTreeSet<i32> = tm.topics[a].tokens.iter().copied().collect();
    let sb: std::collections::BTreeSet<i32> = tm.topics[b].tokens.iter().copied().collect();
    let inter = sa.intersection(&sb).count() as f64;
    let union = sa.union(&sb).count() as f64;
    inter / union
}

/// Relevance score (1–5) of one retrieved training example for a query.
pub fn relevance(
    tm: &TopicModel,
    queries: &Dataset,
    train: &Dataset,
    query: usize,
    retrieved: usize,
) -> u8 {
    let qt = queries.topics[query] as usize;
    let tt = train.topics[retrieved] as usize;
    if qt == tt {
        let qtpl: std::collections::BTreeSet<u16> =
            queries.templates[query].iter().copied().collect();
        let shared = train.templates[retrieved].iter().any(|t| qtpl.contains(t));
        return if shared { 5 } else { 4 };
    }
    let ov = topic_overlap(tm, qt, tt);
    if ov > 0.5 {
        3
    } else if ov > 0.22 {
        2
    } else {
        1
    }
}

/// Judge the top-1 retrievals of a method (Table 12 row).
pub fn judge_top1(
    tm: &TopicModel,
    queries: &Dataset,
    train: &Dataset,
    top1: &[usize],
) -> JudgeSummary {
    let n = top1.len() as f64;
    let mut dist = [0.0f64; 5];
    let mut sum = 0.0;
    for (q, &t) in top1.iter().enumerate() {
        let s = relevance(tm, queries, train, q, t);
        dist[(s - 1) as usize] += 1.0;
        sum += s as f64;
    }
    for d in dist.iter_mut() {
        *d /= n;
    }
    JudgeSummary {
        avg_score: sum / n,
        dist,
        score1_rate: dist[0],
        score_ge4_rate: dist[3] + dist[4],
    }
}

/// Pairwise preference between two methods' top-1 retrievals
/// (Table 3: % better / % worse / % tie; identical retrieval = tie).
pub fn preference(
    tm: &TopicModel,
    queries: &Dataset,
    train: &Dataset,
    top1_a: &[usize],
    top1_b: &[usize],
) -> (f64, f64, f64) {
    let n = top1_a.len() as f64;
    let (mut a_wins, mut b_wins, mut ties) = (0.0, 0.0, 0.0);
    for q in 0..top1_a.len() {
        if top1_a[q] == top1_b[q] {
            ties += 1.0;
            continue;
        }
        let sa = relevance(tm, queries, train, q, top1_a[q]);
        let sb = relevance(tm, queries, train, q, top1_b[q]);
        match sa.cmp(&sb) {
            std::cmp::Ordering::Greater => a_wins += 1.0,
            std::cmp::Ordering::Less => b_wins += 1.0,
            std::cmp::Ordering::Equal => ties += 1.0,
        }
    }
    (a_wins / n, b_wins / n, ties / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (TopicModel, Dataset, Dataset) {
        let tm = TopicModel::new(6, 5);
        let train = Dataset::generate(&tm, 60, 32, 1);
        let queries = Dataset::generate(&tm, 12, 32, 2);
        (tm, train, queries)
    }

    #[test]
    fn same_topic_scores_at_least_4() {
        let (tm, train, queries) = setup();
        for q in 0..queries.len() {
            for t in 0..train.len() {
                let s = relevance(&tm, &queries, &train, q, t);
                if queries.topics[q] == train.topics[t] {
                    assert!(s >= 4);
                } else {
                    assert!(s <= 3);
                }
            }
        }
    }

    #[test]
    fn judge_summary_consistent() {
        let (tm, train, queries) = setup();
        // oracle retrieval: first train example of the same topic
        let top1: Vec<usize> = (0..queries.len())
            .map(|q| {
                (0..train.len())
                    .find(|&t| train.topics[t] == queries.topics[q])
                    .unwrap()
            })
            .collect();
        let s = judge_top1(&tm, &queries, &train, &top1);
        assert!(s.avg_score >= 4.0);
        assert!(s.score1_rate == 0.0);
        assert!((s.dist.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn preference_sums_to_one_and_detects_better() {
        let (tm, train, queries) = setup();
        let oracle: Vec<usize> = (0..queries.len())
            .map(|q| {
                (0..train.len())
                    .find(|&t| train.topics[t] == queries.topics[q])
                    .unwrap()
            })
            .collect();
        // adversarial retrieval: first example of a different topic
        let bad: Vec<usize> = (0..queries.len())
            .map(|q| {
                (0..train.len())
                    .find(|&t| train.topics[t] != queries.topics[q])
                    .unwrap()
            })
            .collect();
        let (a, b, t) = preference(&tm, &queries, &train, &oracle, &bad);
        assert!((a + b + t - 1.0).abs() < 1e-9);
        assert!(a > b, "oracle should win: {a} vs {b}");
    }

    #[test]
    fn identical_retrievals_tie() {
        let (tm, train, queries) = setup();
        let same: Vec<usize> = (0..queries.len()).map(|q| q % train.len()).collect();
        let (_, _, t) = preference(&tm, &queries, &train, &same, &same);
        assert_eq!(t, 1.0);
    }
}

//! Linear Datamodeling Score (Park et al. 2023) — the retraining-based
//! attribution-quality metric of Figures 2/4/7 and Table 1.
//!
//! Protocol (App. B.5): sample M random half-subsets of the training
//! data; retrain a model on each (averaging `models_per_subset` seeds);
//! measure every query's loss under each retrained model; LDS for a
//! query = Spearman(actual losses, predicted losses) where the predicted
//! loss of subset S is `-sum_{i in S} score_i` (more included proponents
//! -> lower loss; the sign makes good methods score positive).
//!
//! The expensive part — the (M x Nq) actual-loss matrix — depends only on
//! (tier, corpus, subsets, training), NOT on the attribution method, so
//! it is computed once and cached on disk; every method/config then pays
//! only a Spearman.

use std::io::{Read, Write};
use std::path::PathBuf;

use crate::index::Pipeline;
use crate::corpus::Dataset;
use crate::linalg::Mat;
use crate::runtime::Trainer;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct LdsProtocol {
    /// number of subsets M
    pub n_subsets: usize,
    /// subset fraction alpha
    pub alpha: f64,
    /// models averaged per subset
    pub models_per_subset: usize,
    /// retraining steps per model
    pub steps: usize,
    pub lr: f32,
}

impl Default for LdsProtocol {
    fn default() -> Self {
        // paper: M=100, alpha=0.5, 5 models, full training.  Scaled to the
        // 1-core testbed; LORIF_SCALE=full benches raise M.
        LdsProtocol { n_subsets: 24, alpha: 0.5, models_per_subset: 1, steps: 150, lr: 3e-3 }
    }
}

/// The cached retraining ground truth.
pub struct LdsActuals {
    /// (M, Nq) query losses under each retrained subset model
    pub losses: Mat,
    /// subset membership: per subset, sorted training indices
    pub subsets: Vec<Vec<usize>>,
}

impl LdsActuals {
    fn cache_path(p: &Pipeline, proto: &LdsProtocol) -> PathBuf {
        p.cfg.work_dir.join(format!(
            "lds_actuals_{}_s{}_m{}_a{}_st{}_k{}.bin",
            p.cfg.tier.name(),
            p.cfg.seed,
            proto.n_subsets,
            (proto.alpha * 100.0) as usize,
            proto.steps,
            proto.models_per_subset,
        ))
    }

    /// Compute (or load) the actual-loss matrix by subset retraining.
    pub fn get(
        p: &Pipeline,
        proto: &LdsProtocol,
        train: &Dataset,
        queries: &Dataset,
    ) -> anyhow::Result<LdsActuals> {
        let path = Self::cache_path(p, proto);
        let mut rng = Rng::labeled(p.cfg.seed, "lds-subsets");
        let k = (train.len() as f64 * proto.alpha) as usize;
        let subsets: Vec<Vec<usize>> = (0..proto.n_subsets)
            .map(|_| rng.sample_indices(train.len(), k))
            .collect();
        if path.exists() {
            let losses = load_mat(&path)?;
            anyhow::ensure!(
                losses.rows == proto.n_subsets && losses.cols == queries.len(),
                "stale LDS cache shape"
            );
            return Ok(LdsActuals { losses, subsets });
        }
        let mut losses = Mat::zeros(proto.n_subsets, queries.len());
        let t0 = std::time::Instant::now();
        for (m, subset) in subsets.iter().enumerate() {
            let sub = train.subset(subset);
            let mut acc = vec![0.0f32; queries.len()];
            for rep in 0..proto.models_per_subset {
                let seed = p.cfg.seed ^ (m as u64) << 8 ^ (rep as u64) << 20 ^ 0x1D5;
                let init = p.cfg.tier.spec().init_params(seed);
                let mut trainer = Trainer::new(&p.rt, p.cfg.tier, init)?;
                let mut trng = Rng::labeled(seed, "lds-train");
                trainer.train(&p.rt, &sub, proto.steps, proto.lr, &mut trng)?;
                let ql = {
                    let lit = p.params_literal(&trainer.params)?;
                    let le = crate::runtime::LossEval::new(&p.rt, p.cfg.tier)?;
                    le.losses(&p.rt, &lit, queries)?
                };
                for (a, l) in acc.iter_mut().zip(&ql) {
                    *a += l / proto.models_per_subset as f32;
                }
            }
            losses.row_mut(m).copy_from_slice(&acc);
            log::info!(
                "LDS retraining {}/{} ({:.0}s elapsed)",
                m + 1,
                proto.n_subsets,
                t0.elapsed().as_secs_f64()
            );
        }
        save_mat(&path, &losses)?;
        Ok(LdsActuals { losses, subsets })
    }

    /// LDS per query for a given score matrix (Nq, N).
    pub fn lds_per_query(&self, scores: &Mat) -> Vec<f64> {
        let nq = scores.rows;
        let m = self.subsets.len();
        (0..nq)
            .map(|q| {
                let actual: Vec<f32> = (0..m).map(|s| self.losses.at(s, q)).collect();
                let predicted: Vec<f32> = self
                    .subsets
                    .iter()
                    .map(|subset| {
                        let srow = scores.row(q);
                        -subset.iter().map(|&i| srow[i]).sum::<f32>()
                    })
                    .collect();
                crate::eval::spearman::spearman(&actual, &predicted)
            })
            .collect()
    }

    /// Mean LDS with bootstrap CI (the Table 1 numbers).
    pub fn lds(&self, scores: &Mat) -> (f64, f64) {
        let per_query = self.lds_per_query(scores);
        crate::eval::spearman::bootstrap_mean(&per_query, 500, 7)
    }
}

fn save_mat(path: &PathBuf, m: &Mat) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&(m.rows as u64).to_le_bytes())?;
    f.write_all(&(m.cols as u64).to_le_bytes())?;
    for &x in &m.data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn load_mat(path: &PathBuf) -> anyhow::Result<Mat> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut b8 = [0u8; 8];
    f.read_exact(&mut b8)?;
    let rows = u64::from_le_bytes(b8) as usize;
    f.read_exact(&mut b8)?;
    let cols = u64::from_le_bytes(b8) as usize;
    let mut buf = vec![0u8; rows * cols * 4];
    f.read_exact(&mut buf)?;
    Ok(Mat::from_vec(
        rows,
        cols,
        buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic sanity: if actual losses are exactly -sum of a "true"
    /// score vector over subsets, a scorer equal to the truth gets LDS 1
    /// and an anti-correlated scorer gets LDS -1.
    #[test]
    fn lds_identity_on_synthetic() {
        let n = 50;
        let nq = 4;
        let m = 16;
        let mut rng = Rng::new(3);
        let truth = Mat::random_normal(nq, n, 1.0, &mut rng);
        let subsets: Vec<Vec<usize>> =
            (0..m).map(|_| rng.sample_indices(n, 25)).collect();
        let mut losses = Mat::zeros(m, nq);
        for (s, subset) in subsets.iter().enumerate() {
            for q in 0..nq {
                let sum: f32 = subset.iter().map(|&i| truth.at(q, i)).sum();
                *losses.at_mut(s, q) = -sum + 10.0;
            }
        }
        let actuals = LdsActuals { losses, subsets };
        let (lds, _) = actuals.lds(&truth);
        assert!(lds > 0.999, "{lds}");
        let mut anti = truth.clone();
        anti.scale(-1.0);
        let (lds_anti, _) = actuals.lds(&anti);
        assert!(lds_anti < -0.999, "{lds_anti}");
    }

    #[test]
    fn lds_random_scores_near_zero() {
        let n = 60;
        let mut rng = Rng::new(4);
        let subsets: Vec<Vec<usize>> = (0..40).map(|_| rng.sample_indices(n, 30)).collect();
        let mut losses = Mat::zeros(40, 2);
        rng.fill_normal(&mut losses.data, 1.0);
        let actuals = LdsActuals { losses, subsets };
        let scores = Mat::random_normal(2, n, 1.0, &mut rng);
        let (lds, _) = actuals.lds(&scores);
        assert!(lds.abs() < 0.35, "{lds}");
    }
}

//! Tail-patch score (Chang et al. 2024) — the retraining-free quality
//! metric for the larger tiers (Table 2, Fig 4b).
//!
//! For each query: take the method's top-k proponents, apply ONE plain
//! SGD step on them (batched, following Li et al. 2025), and measure the
//! increase in the query's mean token log-probability.  We report
//! `100 * (loss_before - loss_after)` (nats x 100), averaged over
//! queries, with a bootstrap CI.

use crate::corpus::Dataset;
use crate::index::Pipeline;
use crate::runtime::{lit_f32, lit_i32, LossEval};

#[derive(Clone, Copy, Debug)]
pub struct TailPatchProtocol {
    pub k: usize,
    pub lr: f32,
}

impl Default for TailPatchProtocol {
    fn default() -> Self {
        TailPatchProtocol { k: 8, lr: 1e-2 }
    }
}

/// Tail-patch scores per query.
pub fn tail_patch(
    p: &Pipeline,
    params: &[f32],
    train: &Dataset,
    queries: &Dataset,
    topk: &[Vec<usize>],
    proto: TailPatchProtocol,
) -> anyhow::Result<Vec<f64>> {
    anyhow::ensure!(topk.len() == queries.len(), "topk/query mismatch");
    let sgd_name = format!("sgd_step_{}", p.cfg.tier.name());
    let meta = p.rt.manifest.graph(&sgd_name)?.clone();
    let exe = p.rt.load(&sgd_name)?;
    let le = LossEval::new(&p.rt, p.cfg.tier)?;
    let base_lit = p.params_literal(params)?;
    let before = le.losses(&p.rt, &base_lit, queries)?;
    let seq = crate::model::spec::SEQ_LEN;

    let mut scores = Vec::with_capacity(queries.len());
    for (q, prop) in topk.iter().enumerate() {
        anyhow::ensure!(!prop.is_empty(), "empty proponent list for query {q}");
        let take: Vec<usize> = prop.iter().copied().take(proto.k.min(meta.batch)).collect();
        let toks = train.batch(&take, meta.batch);
        let tokens = lit_i32(&toks, &[meta.batch as i64, seq as i64])?;
        let lr = xla::Literal::scalar(proto.lr);
        let outs = p.rt.exec(&exe, &[&base_lit, &tokens, &lr])?;
        let patched = crate::runtime::lit_to_vec_f32(&outs[0])?;
        let patched_lit = lit_f32(&patched, &[patched.len() as i64])?;
        // single-query loss re-eval: build a one-example dataset view
        let qset = queries.subset(&[q]);
        let after = le.losses(&p.rt, &patched_lit, &qset)?[0];
        scores.push(100.0 * (before[q] as f64 - after as f64));
    }
    Ok(scores)
}

/// Mean with bootstrap CI (Table 2 convention).
pub fn tail_patch_mean(scores: &[f64]) -> (f64, f64) {
    crate::eval::spearman::bootstrap_mean(scores, 500, 11)
}

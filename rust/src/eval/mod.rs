//! Evaluation harness: LDS (subset retraining), tail-patch, the
//! programmatic relevance judge, and rank-correlation utilities.
//!
//! LDS and tail-patch retrain/re-evaluate models through the PJRT
//! runtime, so they sit behind the `xla` cargo feature; the judge and
//! Spearman utilities are plain CPU code.

pub mod judge;
#[cfg(feature = "xla")]
pub mod lds;
pub mod spearman;
#[cfg(feature = "xla")]
pub mod tailpatch;

#[cfg(feature = "xla")]
pub use lds::{LdsActuals, LdsProtocol};
#[cfg(feature = "xla")]
pub use tailpatch::{tail_patch, tail_patch_mean, TailPatchProtocol};

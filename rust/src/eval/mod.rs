//! Evaluation harness: LDS (subset retraining), tail-patch, the
//! programmatic relevance judge, and rank-correlation utilities.

pub mod judge;
pub mod lds;
pub mod spearman;
pub mod tailpatch;

pub use lds::{LdsActuals, LdsProtocol};
pub use tailpatch::{tail_patch, tail_patch_mean, TailPatchProtocol};

//! Spearman rank correlation (the rho inside LDS) + bootstrap CIs
//! (the paper's ± values are bootstrap half-widths over the query set).

/// Ranks with average ties.
fn ranks(xs: &[f32]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut r = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Spearman rank correlation.
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return 0.0;
    }
    pearson(&ranks(a), &ranks(b))
}

/// Mean of per-query values with a bootstrap CI half-width
/// (resampling the query set, matching the paper's ± convention).
pub fn bootstrap_mean(values: &[f64], n_boot: usize, seed: u64) -> (f64, f64) {
    let n = values.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    let mut rng = crate::util::prng::Rng::labeled(seed, "bootstrap");
    let mut means: Vec<f64> = (0..n_boot)
        .map(|_| {
            let mut s = 0.0;
            for _ in 0..n {
                s += values[rng.below(n)];
            }
            s / n as f64
        })
        .collect();
    means.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = means[(0.025 * n_boot as f64) as usize];
    let hi = means[((0.975 * n_boot as f64) as usize).min(n_boot - 1)];
    (mean, (hi - lo) / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_correlation() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [10.0f32, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
        let c = [40.0f32, 30.0, 20.0, 10.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_invariance() {
        // monotone transform does not change spearman
        let a = [0.1f32, 0.5, 0.3, 0.9, 0.7];
        let b = [1.0f32, 3.0, 2.0, 8.0, 4.0];
        let b_exp: Vec<f32> = b.iter().map(|x| x.exp()).collect();
        assert!((spearman(&a, &b) - spearman(&a, &b_exp)).abs() < 1e-9);
    }

    #[test]
    fn ties_handled() {
        let a = [1.0f32, 1.0, 2.0, 3.0];
        let b = [1.0f32, 1.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn uncorrelated_near_zero() {
        let mut rng = crate::util::prng::Rng::new(1);
        let a: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        assert!(spearman(&a, &b).abs() < 0.07);
    }

    #[test]
    fn bootstrap_shrinks_with_consensus() {
        let tight: Vec<f64> = vec![0.5; 50];
        let (m, ci) = bootstrap_mean(&tight, 200, 0);
        assert!((m - 0.5).abs() < 1e-12);
        assert!(ci < 1e-12);
        let wide: Vec<f64> = (0..50).map(|i| (i % 2) as f64).collect();
        let (_, ci_wide) = bootstrap_mean(&wide, 200, 0);
        assert!(ci_wide > 0.05);
    }
}

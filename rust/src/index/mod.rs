//! Index-building pipeline: corpus -> base model -> stage 1 (extract +
//! factorize + persist) -> stage 2 (curvature).
//!
//! Mirrors the paper's preprocessing (App. C): stage 1 computes and
//! stores per-example gradients (dense for the baselines, rank-c factors
//! for LoRIF, embeddings for RepSim); stage 2 builds the inverse-Hessian
//! approximation (streaming rSVD for LoRIF; the dense Gram assembly is
//! timed on demand for LoGRA).  All stage timings feed Tables 5–7.
//!
//! Stage 1 needs the PJRT runtime, so the whole pipeline sits behind the
//! `xla` cargo feature.  With `shards > 1` in the config, stage 1 writes
//! the v2 sharded store layout consumed by the parallel query path.

#[cfg(feature = "xla")]
pub mod builder;

#[cfg(feature = "xla")]
pub use builder::{Pipeline, Stage1Options, Stage1Report};

//! The `Pipeline`: one object that owns the runtime + config and exposes
//! every preprocessing stage with caching on disk.
//!
//! Everything is keyed by config so benches can reuse expensive steps
//! (base-model training, LDS retraining actuals) across attribution
//! configurations.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::attribution::repsim::EmbedStore;
use crate::attribution::QueryGrads;
use crate::config::Config;
use crate::corpus::{Dataset, TopicModel};
use crate::curvature::{DenseCurvature, TruncatedCurvature};
use crate::model::checkpoint::Checkpoint;
use crate::model::spec::SEQ_LEN;
use crate::runtime::{lit_f32, Embedder, GradExtractor, LossEval, Runtime, Trainer};
use crate::runtime::ExtractBatch;
use crate::store::{
    recode_store, ClusterMeta, RecodeOptions, ShardSet, ShardedWriter, StoreKind, StoreMeta,
    StoreWriter,
};
use crate::util::prng::Rng;

/// Stage-1 writer over either store layout, picked by `Config::shards`.
enum Stage1Writer {
    Mono(StoreWriter),
    Sharded(ShardedWriter),
}

impl Stage1Writer {
    fn create(
        base: &std::path::Path,
        meta: StoreMeta,
        shards: usize,
        n_expected: usize,
        summary_chunk: usize,
    ) -> anyhow::Result<Stage1Writer> {
        if shards <= 1 {
            let mut w = StoreWriter::create(base, meta)?;
            w.set_summary_chunk(summary_chunk)?;
            Ok(Stage1Writer::Mono(w))
        } else {
            let mut w = ShardedWriter::create(base, meta, shards, n_expected)?;
            w.set_summary_chunk(summary_chunk)?;
            Ok(Stage1Writer::Sharded(w))
        }
    }

    fn append(&mut self, batch: &ExtractBatch) -> anyhow::Result<()> {
        match self {
            Stage1Writer::Mono(w) => w.append(batch),
            Stage1Writer::Sharded(w) => w.append(batch),
        }
    }

    fn finalize(self) -> anyhow::Result<StoreMeta> {
        match self {
            Stage1Writer::Mono(w) => w.finalize(),
            Stage1Writer::Sharded(w) => w.finalize(),
        }
    }
}

/// Every on-disk file of the store described by `meta`, as
/// `(at_from, at_to)` rename pairs between two base paths.
fn store_file_moves(meta: &StoreMeta, from: &Path, to: &Path) -> Vec<(PathBuf, PathBuf)> {
    let mut v = vec![(StoreMeta::meta_path(from), StoreMeta::meta_path(to))];
    match &meta.shards {
        None => v.push((StoreMeta::data_path(from), StoreMeta::data_path(to))),
        Some(counts) => {
            for i in 0..counts.len() {
                v.push((
                    StoreMeta::shard_data_path(from, i),
                    StoreMeta::shard_data_path(to, i),
                ));
            }
        }
    }
    if meta.summary_chunk.is_some() {
        v.push((StoreMeta::summaries_path(from), StoreMeta::summaries_path(to)));
    }
    v
}

/// Cluster a freshly written stage-1 store: `store recode --cluster k`
/// into a sibling `<base>_ctmp`, then rename the clustered files over
/// the originals (the renames land on the same layout — a plain recode
/// preserves shard counts and the summary grid).  The suffix is
/// deliberately dot-free: the path helpers use `with_extension`, so a
/// `.ctmp` base would resolve to the *source* file names.
fn cluster_store(base: &Path, k: usize) -> anyhow::Result<()> {
    let name = base
        .file_name()
        .ok_or_else(|| anyhow::anyhow!("store base {} has no file name", base.display()))?;
    let tmp = base.with_file_name(format!("{}_ctmp", name.to_string_lossy()));
    let rep =
        recode_store(base, &tmp, &RecodeOptions { cluster: Some(k), ..Default::default() })?;
    let meta = StoreMeta::load(&tmp)?;
    for (from, to) in store_file_moves(&meta, &tmp, base) {
        std::fs::rename(&from, &to)?;
    }
    log::info!(
        "stage1: clustered {} into k={k} groups (v{}, {:.2}s)",
        base.display(),
        rep.version,
        rep.wall.as_secs_f64()
    );
    Ok(())
}

pub struct Pipeline {
    pub cfg: Config,
    pub rt: Runtime,
}

#[derive(Clone, Copy, Debug)]
pub struct Stage1Options {
    pub write_factored: bool,
    pub write_dense: bool,
    pub write_embeddings: bool,
}

impl Default for Stage1Options {
    fn default() -> Self {
        Stage1Options { write_factored: true, write_dense: true, write_embeddings: true }
    }
}

#[derive(Debug)]
pub struct Stage1Report {
    pub factored_base: Option<PathBuf>,
    pub dense_base: Option<PathBuf>,
    pub embed_path: Option<PathBuf>,
    pub wall: Duration,
    pub n_examples: usize,
}

impl Pipeline {
    pub fn new(cfg: Config) -> anyhow::Result<Pipeline> {
        cfg.validate()?;
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        std::fs::create_dir_all(&cfg.work_dir)?;
        Ok(Pipeline { cfg, rt })
    }

    pub fn topic_model(&self) -> TopicModel {
        TopicModel::new(self.cfg.n_topics, self.cfg.seed)
    }

    /// Train + query datasets (cached on disk).
    pub fn corpus(&self) -> anyhow::Result<(Dataset, Dataset)> {
        let tm = self.topic_model();
        let train_path = self.cfg.work_dir.join(format!(
            "corpus_train_{}_{}.bin",
            self.cfg.n_train, self.cfg.seed
        ));
        let query_path = self.cfg.work_dir.join(format!(
            "corpus_query_{}_{}.bin",
            self.cfg.n_query, self.cfg.seed
        ));
        let train = if train_path.exists() {
            Dataset::load(&train_path)?
        } else {
            let d = Dataset::generate(&tm, self.cfg.n_train, SEQ_LEN, self.cfg.seed);
            d.save(&train_path)?;
            d
        };
        let queries = if query_path.exists() {
            Dataset::load(&query_path)?
        } else {
            // distinct stream: queries are held out
            let d = Dataset::generate(&tm, self.cfg.n_query, SEQ_LEN, self.cfg.seed ^ 0xABCD);
            d.save(&query_path)?;
            d
        };
        Ok((train, queries))
    }

    fn ckpt_path(&self) -> PathBuf {
        self.cfg.work_dir.join(format!(
            "model_{}_s{}_t{}.ckpt",
            self.cfg.tier.name(),
            self.cfg.seed,
            self.cfg.train_steps
        ))
    }

    /// Train the base model on the training corpus (cached checkpoint).
    pub fn base_params(&self, train: &Dataset) -> anyhow::Result<Vec<f32>> {
        let path = self.ckpt_path();
        if path.exists() {
            let ck = Checkpoint::load(&path)?;
            anyhow::ensure!(ck.tier == self.cfg.tier.name(), "checkpoint tier mismatch");
            return Ok(ck.params);
        }
        let spec = self.cfg.tier.spec();
        let init = spec.init_params(self.cfg.seed);
        let mut trainer = Trainer::new(&self.rt, self.cfg.tier, init)?;
        let mut rng = Rng::labeled(self.cfg.seed, "base-train");
        let t0 = Instant::now();
        let losses =
            trainer.train(&self.rt, train, self.cfg.train_steps, self.cfg.train_lr, &mut rng)?;
        log::info!(
            "base model: {} steps, loss {:.3} -> {:.3} ({:?})",
            self.cfg.train_steps,
            losses.first().unwrap_or(&0.0),
            losses.last().unwrap_or(&0.0),
            t0.elapsed()
        );
        let ck = Checkpoint {
            tier: self.cfg.tier.name().to_string(),
            step: trainer.step,
            params: trainer.params.clone(),
        };
        ck.save(&path)?;
        Ok(ck.params)
    }

    pub fn params_literal(&self, params: &[f32]) -> anyhow::Result<xla::Literal> {
        lit_f32(params, &[params.len() as i64])
    }

    // ---- stage 1 -----------------------------------------------------------

    pub fn factored_base(&self) -> PathBuf {
        self.cfg.index_dir().join("factored")
    }

    pub fn dense_base(&self) -> PathBuf {
        // dense store does not depend on c
        self.cfg.work_dir.join(format!(
            "index_{}_f{}_c{}",
            self.cfg.tier.name(),
            self.cfg.f,
            self.cfg.c
        )).join("dense")
    }

    pub fn embed_path(&self) -> PathBuf {
        self.cfg
            .work_dir
            .join(format!("embed_{}_{}.bin", self.cfg.tier.name(), self.cfg.n_train))
    }

    /// Does an existing store at `base` already have the layout the
    /// current config asks for?  A missing or unreadable manifest, a
    /// v1/v2 (or shard-count) mismatch, a summary-sidecar grid that
    /// disagrees with `--summary-chunk`, a record codec that disagrees
    /// with `--codec`, or v5 cluster metadata that disagrees with
    /// `--cluster` means stage 1 must rewrite it — otherwise those
    /// flags would be silently ignored by the cache.
    fn store_layout_current(&self, base: &PathBuf) -> bool {
        let Ok(meta) = StoreMeta::load(base) else { return false };
        let shards_current = match &meta.shards {
            None => self.cfg.shards <= 1,
            Some(counts) => {
                self.cfg.shards > 1
                    && counts.len()
                        == ShardedWriter::expected_shards(meta.n_examples, self.cfg.shards)
            }
        };
        let want_summaries =
            (self.cfg.summary_chunk > 0).then_some(self.cfg.summary_chunk);
        let summaries_current = meta.summary_chunk == want_summaries;
        let codec_current = meta.codec == self.cfg.codec;
        let cluster_current = match ClusterMeta::load(base) {
            Ok(Some(cm)) => cm.k == self.cfg.cluster,
            Ok(None) => self.cfg.cluster == 0,
            Err(_) => false,
        };
        if !shards_current || !summaries_current || !codec_current || !cluster_current {
            log::info!(
                "stage1: store {} does not match --shards {} / --summary-chunk {} / \
                 --codec {} / --cluster {}; rebuilding",
                base.display(),
                self.cfg.shards,
                self.cfg.summary_chunk,
                self.cfg.codec.as_str(),
                self.cfg.cluster
            );
        }
        shards_current && summaries_current && codec_current && cluster_current
    }

    /// Stage 1: extract per-example gradients for the whole training set
    /// and persist the requested stores.  Skips stores that already
    /// exist with the configured shard layout.
    pub fn stage1(
        &self,
        params: &xla::Literal,
        train: &Dataset,
        opts: Stage1Options,
    ) -> anyhow::Result<Stage1Report> {
        let t0 = Instant::now();
        let spec = self.cfg.tier.spec();
        let layers = spec.proj_dims(self.cfg.f);
        let fac_base = self.factored_base();
        let dense_base = self.dense_base();
        let embed_path = self.embed_path();

        let need_fac = opts.write_factored && !self.store_layout_current(&fac_base);
        let need_dense = opts.write_dense && !self.store_layout_current(&dense_base);
        let need_embed = opts.write_embeddings && !embed_path.exists();

        if need_fac || need_dense {
            let extractor = GradExtractor::new(&self.rt, self.cfg.tier, self.cfg.f, self.cfg.c)?;
            let mut fac_writer = if need_fac {
                Some(Stage1Writer::create(
                    &fac_base,
                    StoreMeta {
                        kind: StoreKind::Factored,
                        tier: self.cfg.tier.name().to_string(),
                        f: self.cfg.f,
                        c: self.cfg.c,
                        layers: layers.clone(),
                        n_examples: 0,
                        shards: None,
                        summary_chunk: None,
                        codec: self.cfg.codec,
                    },
                    self.cfg.shards,
                    train.len(),
                    self.cfg.summary_chunk,
                )?)
            } else {
                None
            };
            let mut dense_writer = if need_dense {
                Some(Stage1Writer::create(
                    &dense_base,
                    StoreMeta {
                        kind: StoreKind::Dense,
                        tier: self.cfg.tier.name().to_string(),
                        f: self.cfg.f,
                        c: self.cfg.c,
                        layers: layers.clone(),
                        n_examples: 0,
                        shards: None,
                        summary_chunk: None,
                        codec: self.cfg.codec,
                    },
                    self.cfg.shards,
                    train.len(),
                    self.cfg.summary_chunk,
                )?)
            } else {
                None
            };
            let mut i = 0;
            while i < train.len() {
                let take = extractor.batch.min(train.len() - i);
                let idx: Vec<usize> = (i..i + take).collect();
                let batch = extractor.run(&self.rt, params, train, &idx)?;
                if let Some(w) = fac_writer.as_mut() {
                    w.append(&batch)?;
                }
                if let Some(w) = dense_writer.as_mut() {
                    w.append(&batch)?;
                }
                i += take;
                if i % 1024 == 0 {
                    log::debug!("stage1: {i}/{} examples", train.len());
                }
            }
            if let Some(w) = fac_writer {
                w.finalize()?;
                if self.cfg.cluster > 0 {
                    cluster_store(&fac_base, self.cfg.cluster)?;
                }
            }
            if let Some(w) = dense_writer {
                w.finalize()?;
                if self.cfg.cluster > 0 {
                    cluster_store(&dense_base, self.cfg.cluster)?;
                }
            }
        }

        if need_embed {
            let embedder = Embedder::new(&self.rt, self.cfg.tier)?;
            let emb = embedder.embed_all(&self.rt, params, train)?;
            EmbedStore::save(&embed_path, &emb)?;
        }

        Ok(Stage1Report {
            factored_base: opts.write_factored.then(|| fac_base),
            dense_base: opts.write_dense.then(|| dense_base),
            embed_path: opts.write_embeddings.then(|| embed_path),
            wall: t0.elapsed(),
            n_examples: train.len(),
        })
    }

    // ---- stage 2 -----------------------------------------------------------

    fn curvature_path(&self) -> PathBuf {
        self.cfg.index_dir().join(format!("curvature_r{}.bin", self.cfg.r))
    }

    /// Stage 2 for LoRIF: streaming rSVD over the factor store (cached).
    pub fn stage2_lorif(&self) -> anyhow::Result<(TruncatedCurvature, Duration)> {
        let path = self.curvature_path();
        let t0 = Instant::now();
        if path.exists() {
            return Ok((TruncatedCurvature::load(&path)?, t0.elapsed()));
        }
        let set = ShardSet::open(&self.factored_base())?;
        let curv = TruncatedCurvature::build(
            &set,
            self.cfg.r,
            self.cfg.rsvd_oversample,
            self.cfg.rsvd_power_iters,
            self.cfg.lambda_factor,
            self.cfg.seed,
        )?;
        curv.save(&path, true)?;
        Ok((curv, t0.elapsed()))
    }

    /// Stage 2 for LoGRA/TrackStar: dense Gram assembly + Cholesky.
    pub fn stage2_dense(&self) -> anyhow::Result<(DenseCurvature, Duration)> {
        let t0 = Instant::now();
        let set = ShardSet::open(&self.dense_base())?;
        let curv = DenseCurvature::build(&set, self.cfg.lambda_factor)?;
        Ok((curv, t0.elapsed()))
    }

    // ---- query-side helpers -------------------------------------------------

    pub fn query_grads(
        &self,
        params: &xla::Literal,
        queries: &Dataset,
    ) -> anyhow::Result<QueryGrads> {
        let extractor = GradExtractor::new(&self.rt, self.cfg.tier, self.cfg.f, self.cfg.c)?;
        QueryGrads::extract(&self.rt, &extractor, params, queries)
    }

    pub fn query_losses(
        &self,
        params: &[f32],
        queries: &Dataset,
    ) -> anyhow::Result<Vec<f32>> {
        let le = LossEval::new(&self.rt, self.cfg.tier)?;
        let lit = self.params_literal(params)?;
        le.losses(&self.rt, &lit, queries)
    }
}

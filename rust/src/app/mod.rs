//! High-level composition used by the CLI, examples, and benches:
//! build any paper method end-to-end from a `Pipeline`.
//!
//! The `Method` enum and its parsing are plain CPU code; everything that
//! needs the PJRT runtime (the builders below) sits behind the `xla`
//! cargo feature.

#[cfg(feature = "xla")]
use crate::attribution::ekfac::EkfacScorer;
#[cfg(feature = "xla")]
use crate::attribution::graddot::GradDotScorer;
#[cfg(feature = "xla")]
use crate::attribution::logra::LograScorer;
#[cfg(feature = "xla")]
use crate::attribution::lorif::LorifScorer;
#[cfg(feature = "xla")]
use crate::attribution::repsim::{EmbedStore, RepSimScorer};
#[cfg(feature = "xla")]
use crate::attribution::trackstar::TrackStarScorer;
#[cfg(feature = "xla")]
use crate::attribution::Scorer;
#[cfg(feature = "xla")]
use crate::corpus::Dataset;
#[cfg(feature = "xla")]
use crate::index::Pipeline;
#[cfg(feature = "xla")]
use crate::runtime::{Embedder, GradExtractor};
#[cfg(feature = "xla")]
use crate::store::ShardSet;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Lorif,
    Logra,
    GradDot,
    TrackStar,
    RepSim,
    Ekfac,
}

impl Method {
    pub fn parse(s: &str) -> anyhow::Result<Method> {
        Ok(match s {
            "lorif" => Method::Lorif,
            "logra" => Method::Logra,
            "graddot" => Method::GradDot,
            "trackstar" => Method::TrackStar,
            "repsim" => Method::RepSim,
            "ekfac" => Method::Ekfac,
            _ => anyhow::bail!("unknown method '{s}' (lorif|logra|graddot|trackstar|repsim|ekfac)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Lorif => "lorif",
            Method::Logra => "logra",
            Method::GradDot => "graddot",
            Method::TrackStar => "trackstar",
            Method::RepSim => "repsim",
            Method::Ekfac => "ekfac",
        }
    }

    pub fn needs_dense_store(self) -> bool {
        matches!(self, Method::Logra | Method::GradDot | Method::TrackStar)
    }
}

/// Build a boxed scorer for the simple (store-backed) methods.  Opens
/// the store as a `ShardSet` (v1 or v2 layout) and hands the configured
/// shard-scoring thread count through.  Every scorer built here is a
/// `ChunkKernel` run by the shared streaming executor, so it supports
/// both the full-matrix and the streaming top-k sink
/// (`Scorer::score_sink`).
/// EK-FAC and RepSim have extra dependencies — see the dedicated fns.
#[cfg(feature = "xla")]
pub fn build_store_scorer(
    p: &Pipeline,
    method: Method,
) -> anyhow::Result<Box<dyn Scorer>> {
    let mut pool = build_store_scorer_pool(p, method, 1)?;
    let scorer: Box<dyn Scorer> = pool.pop().expect("pool of one");
    Ok(scorer)
}

/// Build `workers` independent scorer instances for the serving pool,
/// all sharing ONE opened `ShardSet` behind `Arc` (and, when
/// `cfg.chunk_cache_mb > 0`, one decoded-chunk cache) plus one curvature
/// build — so N workers cost N small structs, not N store opens and N
/// rSVD passes, and a chunk decoded for any worker is resident for all
/// of them.
#[cfg(feature = "xla")]
pub fn build_store_scorer_pool(
    p: &Pipeline,
    method: Method,
    workers: usize,
) -> anyhow::Result<Vec<Box<dyn Scorer + Send>>> {
    build_store_scorer_pool_subset(p, method, workers, None)
}

/// Like [`build_store_scorer_pool`], but opening only `subset` of the
/// store's manifest shards (shard-node serving mode).  Scores stay in
/// GLOBAL example coordinates — subset spans keep their manifest
/// offsets — so a coordinator can merge heaps from disjoint nodes
/// without any index translation.
#[cfg(feature = "xla")]
pub fn build_store_scorer_pool_subset(
    p: &Pipeline,
    method: Method,
    workers: usize,
    subset: Option<&[usize]>,
) -> anyhow::Result<Vec<Box<dyn Scorer + Send>>> {
    use std::sync::Arc;

    let workers = workers.max(1);
    let threads = p.cfg.score_threads;
    let prune = p.cfg.prune;
    let depth = p.cfg.prefetch_depth;
    let quant = p.cfg.quant_score;
    let base = match method {
        Method::Lorif => p.factored_base(),
        Method::Logra | Method::GradDot | Method::TrackStar => p.dense_base(),
        Method::RepSim | Method::Ekfac => {
            anyhow::bail!("use build_repsim_scorer / build_ekfac_scorer for {method:?}")
        }
    };
    let mut set = ShardSet::open_subset(&base, subset)?;
    if let Some(cache) = crate::store::ChunkCache::from_mb(p.cfg.chunk_cache_mb) {
        set.set_cache(Some(cache));
    }
    let set = Arc::new(set);
    let mut out: Vec<Box<dyn Scorer + Send>> = Vec::with_capacity(workers);
    match method {
        Method::Lorif => {
            let (curv, _) = p.stage2_lorif()?;
            let curv = Arc::new(curv);
            for _ in 0..workers {
                let mut s = LorifScorer::new(Arc::clone(&set), Arc::clone(&curv));
                s.score_threads = threads;
                s.prune = prune;
                s.prefetch_depth = depth;
                s.quant = quant;
                out.push(Box::new(s));
            }
        }
        Method::Logra => {
            let (curv, _) = p.stage2_dense()?;
            let curv = Arc::new(curv);
            for _ in 0..workers {
                let mut s = LograScorer::new(Arc::clone(&set), Arc::clone(&curv));
                s.score_threads = threads;
                s.prune = prune;
                s.prefetch_depth = depth;
                s.quant = quant;
                out.push(Box::new(s));
            }
        }
        Method::GradDot => {
            for _ in 0..workers {
                let mut s = GradDotScorer::new(Arc::clone(&set));
                s.score_threads = threads;
                s.prune = prune;
                s.prefetch_depth = depth;
                s.quant = quant;
                out.push(Box::new(s));
            }
        }
        Method::TrackStar => {
            let (curv, _) = p.stage2_dense()?;
            let curv = Arc::new(curv);
            for _ in 0..workers {
                let mut s = TrackStarScorer::new(Arc::clone(&set), Arc::clone(&curv));
                s.score_threads = threads;
                s.prune = prune;
                s.prefetch_depth = depth;
                s.quant = quant;
                out.push(Box::new(s));
            }
        }
        Method::RepSim | Method::Ekfac => unreachable!("rejected above"),
    }
    Ok(out)
}

/// RepSim needs query embeddings computed with the same model.
#[cfg(feature = "xla")]
pub fn build_repsim_scorer(
    p: &Pipeline,
    params: &xla::Literal,
    queries: &Dataset,
) -> anyhow::Result<RepSimScorer> {
    let embedder = Embedder::new(&p.rt, p.cfg.tier)?;
    let qemb = embedder.embed_all(&p.rt, params, queries)?;
    RepSimScorer::new(&p.embed_path(), qemb)
}

/// EK-FAC: covariance fit + eigenvalue-correction pass (stage 1'), then
/// the recomputation-based scorer.  `corr_examples` bounds the correction
/// pass (paper uses the full corpus; we default to min(n, 512)).
#[cfg(feature = "xla")]
pub fn build_ekfac_scorer<'a>(
    p: &'a Pipeline,
    extractor_f1: &'a GradExtractor,
    params: &'a xla::Literal,
    train: &'a Dataset,
    corr_examples: usize,
) -> anyhow::Result<EkfacScorer<'a>> {
    let stats = crate::runtime::EkfacStats::new(&p.rt, p.cfg.tier)?;
    let covs = stats.accumulate(&p.rt, params, train, train.len())?;
    let ekfac = crate::curvature::Ekfac::from_covariances(&covs, p.cfg.lambda_factor);
    let layer_dims = p
        .cfg
        .tier
        .spec()
        .tracked_layers()
        .iter()
        .map(|l| (l.in_dim, l.out_dim))
        .collect();
    let mut scorer = EkfacScorer {
        rt: &p.rt,
        extractor: extractor_f1,
        params,
        train,
        ekfac,
        layer_dims,
    };
    scorer.fit_corrections(corr_examples, p.cfg.lambda_factor)?;
    Ok(scorer)
}

/// Ensure the embedding store exists (stage 1 for RepSim).
#[cfg(feature = "xla")]
pub fn ensure_embeddings(
    p: &Pipeline,
    params: &xla::Literal,
    train: &Dataset,
) -> anyhow::Result<()> {
    let path = p.embed_path();
    if !path.exists() {
        let embedder = Embedder::new(&p.rt, p.cfg.tier)?;
        let emb = embedder.embed_all(&p.rt, params, train)?;
        EmbedStore::save(&path, &emb)?;
    }
    Ok(())
}

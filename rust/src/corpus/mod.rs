//! Synthetic topic-Markov corpus: the WikiText/SFT stand-in with latent
//! attribution ground truth (topics + templates).

pub mod dataset;
pub mod topics;

pub use dataset::Dataset;
pub use topics::{TopicModel, UNSAFE_TOPIC, VOCAB};

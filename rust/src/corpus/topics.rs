//! Latent-topic Markov text model — the synthetic stand-in for
//! WikiText-103 / SFT corpora (DESIGN.md §1 substitutions).
//!
//! Each topic owns (a) a preferred token subset with a cyclic bigram
//! structure and (b) a handful of fixed template phrases.  Sequences mix
//! topic-bigram steps, template insertions, and uniform noise, so a
//! language model genuinely learns per-topic structure — which is what
//! gives attribution a ground truth: training examples of the query's
//! topic are the true proponents, and the programmatic judge
//! (`eval::judge`) can grade retrievals on the paper's 1–5 rubric.

use crate::util::prng::Rng;

pub const VOCAB: usize = 64;

#[derive(Clone, Debug)]
pub struct Topic {
    pub id: usize,
    /// preferred token subset (the topic's "vocabulary")
    pub tokens: Vec<i32>,
    /// cyclic successor within the preferred subset: bigram backbone
    pub successor: Vec<i32>, // indexed by vocab token; -1 if not preferred
    /// fixed template phrases (n-grams) characteristic of the topic
    pub templates: Vec<Vec<i32>>,
}

#[derive(Clone, Debug)]
pub struct TopicModel {
    pub topics: Vec<Topic>,
    pub seed: u64,
}

/// Index of the designated "unsafe pattern" topic used by the
/// safety-auditing example (paper App. F.3 analogue).
pub const UNSAFE_TOPIC: usize = 0;

impl TopicModel {
    pub fn new(n_topics: usize, seed: u64) -> Self {
        assert!(n_topics >= 2 && n_topics <= 16);
        let mut topics = Vec::with_capacity(n_topics);
        for t in 0..n_topics {
            let mut rng = Rng::labeled(seed, &format!("topic-{t}"));
            // preferred subset: 16 tokens; overlapping subsets across
            // topics keep the task non-trivial
            let mut all: Vec<i32> = (0..VOCAB as i32).collect();
            rng.shuffle(&mut all);
            let tokens: Vec<i32> = all[..16].to_vec();
            // cyclic successor over a shuffled order of the subset
            let mut order = tokens.clone();
            rng.shuffle(&mut order);
            let mut successor = vec![-1i32; VOCAB];
            for i in 0..order.len() {
                successor[order[i] as usize] = order[(i + 1) % order.len()];
            }
            // templates: 4 phrases of 6 tokens from the preferred subset
            let templates = (0..4)
                .map(|_| (0..6).map(|_| tokens[rng.below(tokens.len())]).collect())
                .collect();
            topics.push(Topic { id: t, tokens, successor, templates });
        }
        TopicModel { topics, seed }
    }

    pub fn n_topics(&self) -> usize {
        self.topics.len()
    }

    /// Generate one sequence of `len` tokens from `topic`, returning the
    /// tokens and the ids of templates that were inserted.
    pub fn generate(&self, topic: usize, len: usize, rng: &mut Rng) -> (Vec<i32>, Vec<usize>) {
        let t = &self.topics[topic];
        let mut out = Vec::with_capacity(len);
        let mut used_templates = Vec::new();
        let mut cur = t.tokens[rng.below(t.tokens.len())];
        out.push(cur);
        while out.len() < len {
            let roll = rng.uniform();
            if roll < 0.12 {
                // insert a template phrase
                let ti = rng.below(t.templates.len());
                used_templates.push(ti);
                for &tok in &t.templates[ti] {
                    if out.len() < len {
                        out.push(tok);
                    }
                }
                cur = *out.last().unwrap();
            } else if roll < 0.80 {
                // bigram backbone step
                let succ = t.successor[cur as usize];
                cur = if succ >= 0 { succ } else { t.tokens[rng.below(t.tokens.len())] };
                out.push(cur);
            } else if roll < 0.92 {
                // in-topic jump
                cur = t.tokens[rng.below(t.tokens.len())];
                out.push(cur);
            } else {
                // uniform noise
                cur = rng.below(VOCAB) as i32;
                out.push(cur);
            }
        }
        (out, used_templates)
    }

    /// Fraction of bigrams in `tokens` that follow this topic's backbone —
    /// used by the programmatic judge to measure topical agreement.
    pub fn topic_affinity(&self, topic: usize, tokens: &[i32]) -> f64 {
        let t = &self.topics[topic];
        if tokens.len() < 2 {
            return 0.0;
        }
        let hits = tokens
            .windows(2)
            .filter(|w| t.successor[w[0] as usize] == w[1])
            .count();
        hits as f64 / (tokens.len() - 1) as f64
    }

    /// Most likely topic for a sequence by backbone affinity.
    pub fn classify(&self, tokens: &[i32]) -> usize {
        (0..self.n_topics())
            .max_by(|&a, &b| {
                self.topic_affinity(a, tokens)
                    .partial_cmp(&self.topic_affinity(b, tokens))
                    .unwrap()
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_model() {
        let a = TopicModel::new(8, 42);
        let b = TopicModel::new(8, 42);
        assert_eq!(a.topics[3].tokens, b.topics[3].tokens);
        assert_eq!(a.topics[5].templates, b.topics[5].templates);
    }

    #[test]
    fn generate_respects_length_and_vocab() {
        let tm = TopicModel::new(4, 1);
        let mut rng = Rng::new(2);
        let (toks, _) = tm.generate(1, 64, &mut rng);
        assert_eq!(toks.len(), 64);
        assert!(toks.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn affinity_separates_topics() {
        let tm = TopicModel::new(8, 7);
        let mut rng = Rng::new(3);
        for topic in 0..8 {
            let (toks, _) = tm.generate(topic, 64, &mut rng);
            let own = tm.topic_affinity(topic, &toks);
            let other_max = (0..8)
                .filter(|&o| o != topic)
                .map(|o| tm.topic_affinity(o, &toks))
                .fold(0.0f64, f64::max);
            assert!(own > other_max, "topic {topic}: own {own} other {other_max}");
        }
    }

    #[test]
    fn classify_recovers_topic() {
        let tm = TopicModel::new(6, 9);
        let mut rng = Rng::new(4);
        let mut correct = 0;
        for _ in 0..60 {
            let topic = rng.below(6);
            let (toks, _) = tm.generate(topic, 64, &mut rng);
            if tm.classify(&toks) == topic {
                correct += 1;
            }
        }
        assert!(correct >= 55, "classification accuracy too low: {correct}/60");
    }

    #[test]
    fn templates_within_vocab() {
        let tm = TopicModel::new(8, 11);
        for t in &tm.topics {
            for tpl in &t.templates {
                assert_eq!(tpl.len(), 6);
                assert!(tpl.iter().all(|&x| (0..VOCAB as i32).contains(&x)));
            }
        }
    }
}

//! Token datasets: generation, binary save/load, splits.
//!
//! A dataset is (N, T) token ids plus per-example latent metadata (topic
//! id, inserted template ids).  The metadata is *never* visible to the
//! model — it exists so LDS/tail-patch/judge evaluations have ground
//! truth (DESIGN.md §1).

use std::io::{Read, Write};
use std::path::Path;

use super::topics::TopicModel;
use crate::util::prng::Rng;

const MAGIC: &[u8; 8] = b"LORIFDS1";

#[derive(Clone, Debug)]
pub struct Dataset {
    pub seq_len: usize,
    /// (N * seq_len) row-major token ids
    pub tokens: Vec<i32>,
    /// latent topic per example
    pub topics: Vec<u16>,
    /// template ids inserted per example (topic-local ids)
    pub templates: Vec<Vec<u16>>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.topics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn example(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// Generate `n` examples with topics drawn round-robin + jitter so
    /// every topic is well represented.
    pub fn generate(tm: &TopicModel, n: usize, seq_len: usize, seed: u64) -> Dataset {
        let mut rng = Rng::labeled(seed, "dataset");
        let k = tm.n_topics();
        let mut tokens = Vec::with_capacity(n * seq_len);
        let mut topics = Vec::with_capacity(n);
        let mut templates = Vec::with_capacity(n);
        for i in 0..n {
            let topic = if rng.uniform() < 0.15 { rng.below(k) } else { i % k };
            let (toks, tpls) = tm.generate(topic, seq_len, &mut rng);
            tokens.extend_from_slice(&toks);
            topics.push(topic as u16);
            templates.push(tpls.into_iter().map(|t| t as u16).collect());
        }
        Dataset { seq_len, tokens, topics, templates }
    }

    /// Gather a token batch (B, T) for examples `idx`, padding by
    /// repeating the last index to fill fixed AOT batch shapes.
    pub fn batch(&self, idx: &[usize], batch: usize) -> Vec<i32> {
        assert!(!idx.is_empty() && idx.len() <= batch);
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for i in 0..batch {
            let ex = idx[i.min(idx.len() - 1)];
            out.extend_from_slice(self.example(ex));
        }
        out
    }

    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut tokens = Vec::with_capacity(idx.len() * self.seq_len);
        let mut topics = Vec::with_capacity(idx.len());
        let mut templates = Vec::with_capacity(idx.len());
        for &i in idx {
            tokens.extend_from_slice(self.example(i));
            topics.push(self.topics[i]);
            templates.push(self.templates[i].clone());
        }
        Dataset { seq_len: self.seq_len, tokens, topics, templates }
    }

    // -- binary persistence -------------------------------------------------

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        let n = self.len() as u64;
        f.write_all(&n.to_le_bytes())?;
        f.write_all(&(self.seq_len as u64).to_le_bytes())?;
        for &t in &self.tokens {
            f.write_all(&t.to_le_bytes())?;
        }
        for &t in &self.topics {
            f.write_all(&t.to_le_bytes())?;
        }
        for tpl in &self.templates {
            f.write_all(&(tpl.len() as u16).to_le_bytes())?;
            for &t in tpl {
                f.write_all(&t.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Dataset> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad dataset magic in {}", path.display());
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        f.read_exact(&mut b8)?;
        let seq_len = u64::from_le_bytes(b8) as usize;
        let mut tokens = vec![0i32; n * seq_len];
        let mut b4 = [0u8; 4];
        for t in tokens.iter_mut() {
            f.read_exact(&mut b4)?;
            *t = i32::from_le_bytes(b4);
        }
        let mut b2 = [0u8; 2];
        let mut topics = vec![0u16; n];
        for t in topics.iter_mut() {
            f.read_exact(&mut b2)?;
            *t = u16::from_le_bytes(b2);
        }
        let mut templates = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut b2)?;
            let len = u16::from_le_bytes(b2) as usize;
            let mut tpl = vec![0u16; len];
            for t in tpl.iter_mut() {
                f.read_exact(&mut b2)?;
                *t = u16::from_le_bytes(b2);
            }
            templates.push(tpl);
        }
        Ok(Dataset { seq_len, tokens, topics, templates })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let tm = TopicModel::new(4, 1);
        Dataset::generate(&tm, 20, 16, 2)
    }

    #[test]
    fn generate_covers_topics() {
        let ds = tiny();
        assert_eq!(ds.len(), 20);
        let mut seen = [false; 4];
        for &t in &ds.topics {
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn batch_pads_with_last() {
        let ds = tiny();
        let b = ds.batch(&[3, 5], 4);
        assert_eq!(b.len(), 4 * 16);
        assert_eq!(&b[16..32], ds.example(5));
        assert_eq!(&b[48..64], ds.example(5));
    }

    #[test]
    fn subset_selects() {
        let ds = tiny();
        let s = ds.subset(&[1, 4, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.example(1), ds.example(4));
        assert_eq!(s.topics[2], ds.topics[7]);
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = tiny();
        let dir = std::env::temp_dir().join("lorif_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ds.bin");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(ds.tokens, back.tokens);
        assert_eq!(ds.topics, back.topics);
        assert_eq!(ds.templates, back.templates);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("lorif_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

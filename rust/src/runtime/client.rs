//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! L3 hot path.  Pattern follows /opt/xla-example/load_hlo.
//!
//! All graphs are lowered with `return_tuple=True`, so every execution
//! returns one tuple literal that we decompose.  Executables are cached
//! by artifact name; XLA compilation happens once per process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::manifest::Manifest;
use crate::linalg::Mat;

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &std::path::Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: platform={} ({} artifacts)",
            client.platform_name(),
            manifest.graphs.len()
        );
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(name);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        log::debug!("compiled {name} in {:?}", t0.elapsed());
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute and decompose the output tuple into literals.
    pub fn exec(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---- literal helpers -------------------------------------------------------

pub fn lit_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn lit_i32(data: &[i32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "literal shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract an f32 literal into a Vec, converting if needed.
pub fn lit_to_vec_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a literal shaped (rows, cols...) into a Mat with `cols` =
/// product of trailing dims.
pub fn lit_to_mat(lit: &xla::Literal, rows: usize) -> anyhow::Result<Mat> {
    let data = lit_to_vec_f32(lit)?;
    anyhow::ensure!(data.len() % rows == 0, "literal not divisible into {rows} rows");
    let cols = data.len() / rows;
    Ok(Mat::from_vec(rows, cols, data))
}

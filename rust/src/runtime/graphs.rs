//! Typed wrappers over the AOT graphs: gradient extraction, training,
//! loss evaluation, embeddings, EK-FAC statistics.
//!
//! Each wrapper owns its compiled executable, knows the fixed AOT batch
//! size, and handles padding partial batches (the graphs were lowered
//! with static shapes).

use std::rc::Rc;

use super::client::{lit_f32, lit_i32, lit_to_mat, lit_to_vec_f32, Runtime};
use super::manifest::Manifest;
use super::types::{ExtractBatch, LayerGrads};
use crate::corpus::Dataset;
use crate::linalg::Mat;
use crate::model::spec::Tier;

/// Gradient extractor for a fixed (tier, f, c).
pub struct GradExtractor {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub seq_len: usize,
    pub c: usize,
    pub proj_dims: Vec<(usize, usize)>,
}

impl GradExtractor {
    pub fn new(rt: &Runtime, tier: Tier, f: usize, c: usize) -> anyhow::Result<Self> {
        let name = Manifest::grad_extract_name(tier, f, c);
        let meta = rt.manifest.graph(&name)?.clone();
        let exe = rt.load(&name)?;
        let spec = tier.spec();
        let proj_dims = if meta.proj_dims.is_empty() {
            spec.proj_dims(f)
        } else {
            meta.proj_dims.clone()
        };
        anyhow::ensure!(proj_dims == spec.proj_dims(f), "proj_dims drift for {name}");
        Ok(GradExtractor {
            exe,
            batch: meta.batch,
            seq_len: crate::model::spec::SEQ_LEN,
            c: meta.c.unwrap_or(c),
            proj_dims,
        })
    }

    /// Extract for `idx` examples (<= batch; padded internally).
    pub fn run(
        &self,
        rt: &Runtime,
        params: &xla::Literal,
        data: &Dataset,
        idx: &[usize],
    ) -> anyhow::Result<ExtractBatch> {
        anyhow::ensure!(!idx.is_empty() && idx.len() <= self.batch);
        let toks = data.batch(idx, self.batch);
        let tokens = lit_i32(&toks, &[self.batch as i64, self.seq_len as i64])?;
        let outs = rt.exec(&self.exe, &[params, &tokens])?;
        anyhow::ensure!(
            outs.len() == 1 + 3 * self.proj_dims.len(),
            "grad_extract output arity mismatch: {} vs {}",
            outs.len(),
            1 + 3 * self.proj_dims.len()
        );
        let losses = lit_to_vec_f32(&outs[0])?;
        let mut layers = Vec::with_capacity(self.proj_dims.len());
        for (l, &(_d1, _d2)) in self.proj_dims.iter().enumerate() {
            let g = lit_to_mat(&outs[1 + 3 * l], self.batch)?;
            let u = lit_to_mat(&outs[2 + 3 * l], self.batch)?;
            let v = lit_to_mat(&outs[3 + 3 * l], self.batch)?;
            layers.push(LayerGrads { g, u, v });
        }
        Ok(ExtractBatch { losses, layers, valid: idx.len() })
    }
}

/// Adam trainer around the train_step graph.
pub struct Trainer {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    seq_len: usize,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    pub step: u64,
}

impl Trainer {
    pub fn new(rt: &Runtime, tier: Tier, params: Vec<f32>) -> anyhow::Result<Trainer> {
        let name = format!("train_step_{}", tier.name());
        let meta = rt.manifest.graph(&name)?.clone();
        let exe = rt.load(&name)?;
        let n = params.len();
        anyhow::ensure!(n == tier.spec().param_count(), "param vector size mismatch");
        Ok(Trainer {
            exe,
            batch: meta.batch,
            seq_len: crate::model::spec::SEQ_LEN,
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            step: 0,
        })
    }

    /// One optimizer step on the given examples; returns the batch loss.
    pub fn step(
        &mut self,
        rt: &Runtime,
        data: &Dataset,
        idx: &[usize],
        lr: f32,
    ) -> anyhow::Result<f32> {
        self.step += 1;
        let toks = data.batch(idx, self.batch);
        let p = lit_f32(&self.params, &[self.params.len() as i64])?;
        let m = lit_f32(&self.m, &[self.m.len() as i64])?;
        let v = lit_f32(&self.v, &[self.v.len() as i64])?;
        let step = xla::Literal::scalar(self.step as f32);
        let tokens = lit_i32(&toks, &[self.batch as i64, self.seq_len as i64])?;
        let lr = xla::Literal::scalar(lr);
        let outs = rt.exec(&self.exe, &[&p, &m, &v, &step, &tokens, &lr])?;
        anyhow::ensure!(outs.len() == 4, "train_step arity");
        self.params = lit_to_vec_f32(&outs[0])?;
        self.m = lit_to_vec_f32(&outs[1])?;
        self.v = lit_to_vec_f32(&outs[2])?;
        Ok(outs[3].to_vec::<f32>()?[0])
    }

    /// Train `steps` steps sampling batches from `data`.
    pub fn train(
        &mut self,
        rt: &Runtime,
        data: &Dataset,
        steps: usize,
        lr: f32,
        rng: &mut crate::util::prng::Rng,
    ) -> anyhow::Result<Vec<f32>> {
        let mut losses = Vec::with_capacity(steps);
        for _ in 0..steps {
            let idx: Vec<usize> = (0..self.batch).map(|_| rng.below(data.len())).collect();
            losses.push(self.step(rt, data, &idx, lr)?);
        }
        Ok(losses)
    }
}

/// Per-example loss evaluation.
pub struct LossEval {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    seq_len: usize,
}

impl LossEval {
    pub fn new(rt: &Runtime, tier: Tier) -> anyhow::Result<LossEval> {
        let name = format!("loss_eval_{}", tier.name());
        let meta = rt.manifest.graph(&name)?.clone();
        Ok(LossEval { exe: rt.load(&name)?, batch: meta.batch, seq_len: crate::model::spec::SEQ_LEN })
    }

    /// Losses for all examples of `data` (handles batching internally).
    pub fn losses(
        &self,
        rt: &Runtime,
        params: &xla::Literal,
        data: &Dataset,
    ) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::with_capacity(data.len());
        let mut i = 0;
        while i < data.len() {
            let take = self.batch.min(data.len() - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let toks = data.batch(&idx, self.batch);
            let tokens = lit_i32(&toks, &[self.batch as i64, self.seq_len as i64])?;
            let outs = rt.exec(&self.exe, &[params, &tokens])?;
            let losses = lit_to_vec_f32(&outs[0])?;
            out.extend_from_slice(&losses[..take]);
            i += take;
        }
        Ok(out)
    }
}

/// RepSim embeddings (last-token final hidden state).
pub struct Embedder {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    seq_len: usize,
    pub d_model: usize,
}

impl Embedder {
    pub fn new(rt: &Runtime, tier: Tier) -> anyhow::Result<Embedder> {
        let name = format!("embed_{}", tier.name());
        let meta = rt.manifest.graph(&name)?.clone();
        Ok(Embedder {
            exe: rt.load(&name)?,
            batch: meta.batch,
            seq_len: crate::model::spec::SEQ_LEN,
            d_model: tier.spec().d_model,
        })
    }

    pub fn embed_all(
        &self,
        rt: &Runtime,
        params: &xla::Literal,
        data: &Dataset,
    ) -> anyhow::Result<Mat> {
        let mut out = Mat::zeros(data.len(), self.d_model);
        let mut i = 0;
        while i < data.len() {
            let take = self.batch.min(data.len() - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let toks = data.batch(&idx, self.batch);
            let tokens = lit_i32(&toks, &[self.batch as i64, self.seq_len as i64])?;
            let outs = rt.exec(&self.exe, &[params, &tokens])?;
            let emb = lit_to_mat(&outs[0], self.batch)?;
            for k in 0..take {
                out.row_mut(i + k).copy_from_slice(emb.row(k));
            }
            i += take;
        }
        Ok(out)
    }
}

/// EK-FAC covariance statistics accumulator.
pub struct EkfacStats {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    seq_len: usize,
    layer_dims: Vec<(usize, usize)>,
}

impl EkfacStats {
    pub fn new(rt: &Runtime, tier: Tier) -> anyhow::Result<EkfacStats> {
        let name = format!("ekfac_stats_{}", tier.name());
        let meta = rt.manifest.graph(&name)?.clone();
        let layer_dims = tier
            .spec()
            .tracked_layers()
            .iter()
            .map(|l| (l.in_dim, l.out_dim))
            .collect();
        Ok(EkfacStats {
            exe: rt.load(&name)?,
            batch: meta.batch,
            seq_len: crate::model::spec::SEQ_LEN,
            layer_dims,
        })
    }

    /// Accumulate (A_cov, S_cov) per layer over all of `data`.
    pub fn accumulate(
        &self,
        rt: &Runtime,
        params: &xla::Literal,
        data: &Dataset,
        max_examples: usize,
    ) -> anyhow::Result<Vec<(Mat, Mat)>> {
        let mut covs: Vec<(Mat, Mat)> = self
            .layer_dims
            .iter()
            .map(|&(i, o)| (Mat::zeros(i, i), Mat::zeros(o, o)))
            .collect();
        let n = data.len().min(max_examples);
        let mut i = 0;
        while i < n {
            let take = self.batch.min(n - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let toks = data.batch(&idx, self.batch);
            let tokens = lit_i32(&toks, &[self.batch as i64, self.seq_len as i64])?;
            let outs = rt.exec(&self.exe, &[params, &tokens])?;
            for (l, &(di, do_)) in self.layer_dims.iter().enumerate() {
                let a = lit_to_mat(&outs[2 * l], di)?;
                let s = lit_to_mat(&outs[2 * l + 1], do_)?;
                // padding repeats the last example — acceptable bias for
                // covariance estimation on the last partial batch
                for (dst, src) in covs[l].0.data.iter_mut().zip(&a.data) {
                    *dst += src;
                }
                for (dst, src) in covs[l].1.data.iter_mut().zip(&s.data) {
                    *dst += src;
                }
            }
            i += take;
        }
        let scale = 1.0 / n as f32;
        for (a, s) in &mut covs {
            a.scale(scale);
            s.scale(scale);
        }
        Ok(covs)
    }
}

//! Runtime: PJRT client wrapper loading AOT artifacts (HLO text) and the
//! typed graph interfaces the coordinator calls on the hot path.

pub mod client;
pub mod graphs;
pub mod manifest;

pub use client::{lit_f32, lit_i32, lit_to_mat, lit_to_vec_f32, Runtime};
pub use graphs::{Embedder, EkfacStats, ExtractBatch, GradExtractor, LayerGrads, LossEval, Trainer};
pub use manifest::Manifest;

//! Runtime: PJRT client wrapper loading AOT artifacts (HLO text) and the
//! typed graph interfaces the coordinator calls on the hot path.
//!
//! The PJRT-backed pieces (`client`, `graphs`) sit behind the `xla`
//! cargo feature; the artifact manifest and the extract-batch data types
//! are plain Rust and always available (the store layer consumes them).

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod graphs;
pub mod manifest;
pub mod types;

#[cfg(feature = "xla")]
pub use client::{lit_f32, lit_i32, lit_to_mat, lit_to_vec_f32, Runtime};
#[cfg(feature = "xla")]
pub use graphs::{Embedder, EkfacStats, GradExtractor, LossEval, Trainer};
pub use manifest::Manifest;
pub use types::{ExtractBatch, LayerGrads};

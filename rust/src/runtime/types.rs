//! Plain data types shared between the (xla-gated) graph runtime and the
//! CPU-only store/scoring stack.  Kept outside the `xla` feature so
//! writers, fixtures, and tests build without the PJRT bindings.

use crate::linalg::Mat;

/// Per-layer outputs of one grad-extract batch.
pub struct LayerGrads {
    /// dense projected gradients, rows = examples, cols = d1*d2
    pub g: Mat,
    /// rank-c left factors, rows = examples, cols = d1*c
    pub u: Mat,
    /// rank-c right factors, rows = examples, cols = d2*c
    pub v: Mat,
}

pub struct ExtractBatch {
    pub losses: Vec<f32>,
    pub layers: Vec<LayerGrads>,
    /// number of valid (non-padding) examples
    pub valid: usize,
}

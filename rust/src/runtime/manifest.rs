//! Artifact manifest: the contract between `python/compile/aot.py` (L2)
//! and the Rust runtime.
//!
//! Loaded from `artifacts/manifest.json`; cross-checked against the Rust
//! model spec so any drift between `spec.py` and `spec.rs` fails at
//! startup, not as silent numerical garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::spec::Tier;
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub kind: String,
    pub tier: Option<String>,
    pub batch: usize,
    pub f: Option<usize>,
    pub c: Option<usize>,
    pub proj_dims: Vec<(usize, usize)>,
    pub n_outputs: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    pub graphs: BTreeMap<String, GraphMeta>,
    pub batch_sizes: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let version = v.req_usize("version")?;
        anyhow::ensure!(version == 2, "manifest version {version} unsupported");

        // cross-check tier metadata against the Rust spec
        if let Some(tiers) = v.get("tiers").and_then(Value::as_obj) {
            for (name, meta) in tiers {
                let tier = Tier::parse(name)?;
                let want = tier.spec().param_count();
                let got = meta.req_usize("param_count")?;
                anyhow::ensure!(
                    want == got,
                    "param_count mismatch for tier {name}: rust {want} vs python {got} \
                     — spec.rs and spec.py have drifted"
                );
                let layers = meta.req("tracked_layers")?.as_arr().unwrap_or(&[]);
                let rust_layers = tier.spec().tracked_layers();
                anyhow::ensure!(layers.len() == rust_layers.len(), "layer count drift");
                for (jl, rl) in layers.iter().zip(&rust_layers) {
                    anyhow::ensure!(
                        jl.req_usize("in_dim")? == rl.in_dim
                            && jl.req_usize("out_dim")? == rl.out_dim,
                        "layer dim drift at {}",
                        rl.name
                    );
                }
            }
        }

        let mut graphs = BTreeMap::new();
        for g in v.req("graphs")?.as_arr().unwrap_or(&[]) {
            let name = g.req_str("name")?.to_string();
            let proj_dims = g
                .get("proj_dims")
                .and_then(Value::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|p| {
                            let p = p.as_arr()?;
                            Some((p[0].as_usize()?, p[1].as_usize()?))
                        })
                        .collect()
                })
                .unwrap_or_default();
            graphs.insert(
                name.clone(),
                GraphMeta {
                    name,
                    kind: g.req_str("kind")?.to_string(),
                    tier: g.get("tier").and_then(Value::as_str).map(String::from),
                    batch: g.get("batch").and_then(Value::as_usize).unwrap_or(1),
                    f: g.get("f").and_then(Value::as_usize),
                    c: g.get("c").and_then(Value::as_usize),
                    proj_dims,
                    n_outputs: g
                        .get("outputs")
                        .and_then(Value::as_arr)
                        .map(|a| a.len())
                        .unwrap_or(0),
                },
            );
        }
        let batch_sizes = v
            .get("batch_sizes")
            .and_then(Value::as_obj)
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| Some((k.clone(), v.as_usize()?)))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Manifest { dir: dir.to_path_buf(), version, graphs, batch_sizes })
    }

    pub fn graph(&self, name: &str) -> anyhow::Result<&GraphMeta> {
        self.graphs.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest — rebuild with \
                 LORIF_AOT_SET=default (or full) make artifacts"
            )
        })
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Name of the grad_extract artifact for (tier, f, c).
    pub fn grad_extract_name(tier: Tier, f: usize, c: usize) -> String {
        format!("grad_extract_{}_f{f}_c{c}", tier.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let doc = r#"{"version": 2, "batch_sizes": {"score": 512},
          "graphs": [{"name": "g1", "kind": "loss_eval", "tier": "small",
                      "batch": 32, "outputs": [{"dtype":"float32","shape":[32]}]}]}"#;
        let dir = std::env::temp_dir().join("lorif_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.graph("g1").unwrap().batch, 32);
        assert_eq!(m.batch_sizes["score"], 512);
        assert!(m.graph("nope").is_err());
    }

    #[test]
    fn rejects_param_count_drift() {
        let doc = r#"{"version": 2, "graphs": [],
          "tiers": {"small": {"param_count": 1, "tracked_layers": []}}}"#;
        let dir = std::env::temp_dir().join("lorif_test_manifest2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), doc).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn grad_extract_naming() {
        assert_eq!(
            Manifest::grad_extract_name(Tier::Small, 4, 1),
            "grad_extract_small_f4_c1"
        );
    }
}

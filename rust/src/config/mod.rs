//! Experiment configuration: one struct that every pipeline stage reads.
//!
//! Defaults are 1-core-CPU-sized; a JSON config file and/or CLI flags
//! override them.  `lambda_factor` is the paper's damping rule
//! (lambda = 0.1 * mean eigenvalue, App. B.2); `rsvd_power_iters = 3`
//! and `rsvd_oversample = 10` also follow App. B.2.

use std::path::{Path, PathBuf};

use crate::attribution::SinkMode;
use crate::model::spec::Tier;
use crate::sketch::{PruneMode, DEFAULT_SUMMARY_CHUNK};
use crate::store::{CodecId, QuantScore, DEFAULT_PREFETCH_DEPTH};
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct Config {
    pub tier: Tier,
    /// projection factor: d1 = I/f, d2 = O/f (f = 1 means no projection)
    pub f: usize,
    /// rank of the per-example gradient factorization (LoRIF §3.1)
    pub c: usize,
    /// truncation rank of the curvature SVD (LoRIF §3.2)
    pub r: usize,
    /// damping = lambda_factor * mean(retained eigenvalues)
    pub lambda_factor: f32,
    pub rsvd_power_iters: usize,
    pub rsvd_oversample: usize,

    pub n_train: usize,
    pub n_query: usize,
    pub n_topics: usize,
    pub seed: u64,

    /// training steps & lr for the base model
    pub train_steps: usize,
    pub train_lr: f32,

    /// gradient-store shards written by stage 1 (1 = v1 single file;
    /// >= 2 = v2 sharded layout for the parallel query path)
    pub shards: usize,
    /// worker threads for shard scoring and top-k (0 = all cores)
    pub score_threads: usize,
    /// score sink for the query engine: `full` materializes the
    /// (n_query, n_train) matrix, `topk` streams into O(Nq·k) heaps
    pub score_sink: SinkMode,
    /// chunk pruning for top-k passes (`--prune on|off|slack=x`);
    /// exact mode skips only provably unreachable chunks
    pub prune: PruneMode,
    /// store-reader prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// decoded-chunk cache budget in MB for the serving/query path
    /// (`--chunk-cache-mb`; 0 disables the cache).  Cache-backed scoring
    /// is bit-identical to cold scoring — the knob trades memory for
    /// store I/O, never accuracy.
    pub chunk_cache_mb: usize,
    /// stage-1 summary-sidecar grid in records (0 disables the sidecar,
    /// producing a pre-v3 store with no pruning)
    pub summary_chunk: usize,
    /// cluster the stage-1 stores into this many k-means groups
    /// (`--cluster k`; 0 keeps arrival order).  Clustering reorders
    /// records into the v5 layout so the summary bounds prune early —
    /// stage 1 runs `store recode --cluster` after extraction, and the
    /// permutation keeps all reported indices in caller coordinates.
    pub cluster: usize,
    /// record codec for the stage-1 stores (`--codec bf16|int8|int4`);
    /// non-default codecs write the v4 layout.  Changing it rebuilds
    /// the store, same as `--shards` (`store_layout_current`), and
    /// existing stores can migrate without re-extraction via
    /// `lorif store recode`.
    pub codec: CodecId,
    /// quantized-domain scoring (`--quant-score on|off|auto`): score
    /// int8/int4 records straight off their encoded bytes instead of
    /// decode-then-score.  `auto` (default) enables it per query when
    /// the kernel supports it and the store codec is quantized.
    pub quant_score: QuantScore,

    pub artifacts_dir: PathBuf,
    pub work_dir: PathBuf,

    /// write Chrome trace-event JSON here (`--trace-out`; viewable in
    /// Perfetto / `chrome://tracing`).  `None` disables tracing — the
    /// span call sites then cost one static load.
    pub trace_out: Option<PathBuf>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            tier: Tier::Small,
            f: 4,
            c: 1,
            r: 128,
            lambda_factor: 0.1,
            rsvd_power_iters: 3,
            rsvd_oversample: 10,
            n_train: 2048,
            n_query: 64,
            n_topics: 8,
            seed: 17,
            train_steps: 300,
            train_lr: 3e-3,
            shards: 1,
            score_threads: 0,
            score_sink: SinkMode::Full,
            prune: PruneMode::Exact,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            chunk_cache_mb: 0,
            summary_chunk: DEFAULT_SUMMARY_CHUNK,
            cluster: 0,
            codec: CodecId::Bf16,
            quant_score: QuantScore::Auto,
            artifacts_dir: PathBuf::from("artifacts"),
            work_dir: PathBuf::from("work"),
            trace_out: None,
        }
    }
}

impl Config {
    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Config::default();
        cfg.apply_json(&v)?;
        Ok(cfg)
    }

    pub fn apply_json(&mut self, v: &Value) -> anyhow::Result<()> {
        if let Some(t) = v.get("tier").and_then(Value::as_str) {
            self.tier = Tier::parse(t)?;
        }
        macro_rules! num {
            ($field:ident, $key:literal, $ty:ty) => {
                if let Some(n) = v.get($key).and_then(Value::as_f64) {
                    self.$field = n as $ty;
                }
            };
        }
        num!(f, "f", usize);
        num!(c, "c", usize);
        num!(r, "r", usize);
        num!(lambda_factor, "lambda_factor", f32);
        num!(rsvd_power_iters, "rsvd_power_iters", usize);
        num!(rsvd_oversample, "rsvd_oversample", usize);
        num!(n_train, "n_train", usize);
        num!(n_query, "n_query", usize);
        num!(n_topics, "n_topics", usize);
        num!(seed, "seed", u64);
        num!(train_steps, "train_steps", usize);
        num!(train_lr, "train_lr", f32);
        num!(shards, "shards", usize);
        num!(score_threads, "score_threads", usize);
        num!(prefetch_depth, "prefetch_depth", usize);
        num!(chunk_cache_mb, "chunk_cache_mb", usize);
        num!(summary_chunk, "summary_chunk", usize);
        num!(cluster, "cluster", usize);
        if let Some(s) = v.get("score_sink").and_then(Value::as_str) {
            self.score_sink = SinkMode::parse(s)?;
        }
        if let Some(s) = v.get("prune").and_then(Value::as_str) {
            self.prune = PruneMode::parse(s)?;
        }
        if let Some(s) = v.get("codec").and_then(Value::as_str) {
            self.codec = CodecId::parse(s)?;
        }
        if let Some(s) = v.get("quant_score").and_then(Value::as_str) {
            self.quant_score = QuantScore::parse(s)?;
        }
        if let Some(s) = v.get("artifacts_dir").and_then(Value::as_str) {
            self.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("work_dir").and_then(Value::as_str) {
            self.work_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("trace_out").and_then(Value::as_str) {
            self.trace_out = (!s.is_empty()).then(|| PathBuf::from(s));
        }
        self.validate()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let spec = self.tier.spec();
        for l in spec.tracked_layers() {
            anyhow::ensure!(
                l.in_dim % self.f == 0 && l.out_dim % self.f == 0,
                "f={} does not divide layer ({}, {})",
                self.f,
                l.in_dim,
                l.out_dim
            );
        }
        let min_side = spec
            .proj_dims(self.f)
            .iter()
            .map(|&(a, b)| a.min(b))
            .min()
            .unwrap();
        anyhow::ensure!(
            self.c >= 1 && self.c <= min_side,
            "c={} out of range [1, {min_side}] at f={}",
            self.c,
            self.f
        );
        anyhow::ensure!(self.r >= 1, "r must be >= 1");
        anyhow::ensure!(self.n_train >= 8 && self.n_query >= 1, "dataset too small");
        anyhow::ensure!(self.shards >= 1, "shards must be >= 1");
        anyhow::ensure!(self.prefetch_depth >= 1, "prefetch_depth must be >= 1");
        anyhow::ensure!(
            self.cluster == 0 || self.summary_chunk >= 1,
            "cluster={} needs a summary grid (summary_chunk >= 1): the sidecar is \
             the retrieval tier the clustering serves",
            self.cluster
        );
        Ok(())
    }

    /// Subdirectory for this configuration's index.
    pub fn index_dir(&self) -> PathBuf {
        self.work_dir.join(format!(
            "index_{}_f{}_c{}",
            self.tier.name(),
            self.f,
            self.c
        ))
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("tier", self.tier.name().into()),
            ("f", self.f.into()),
            ("c", self.c.into()),
            ("r", self.r.into()),
            ("lambda_factor", (self.lambda_factor as f64).into()),
            ("rsvd_power_iters", self.rsvd_power_iters.into()),
            ("rsvd_oversample", self.rsvd_oversample.into()),
            ("n_train", self.n_train.into()),
            ("n_query", self.n_query.into()),
            ("n_topics", self.n_topics.into()),
            ("seed", (self.seed as usize).into()),
            ("train_steps", self.train_steps.into()),
            ("train_lr", (self.train_lr as f64).into()),
            ("shards", self.shards.into()),
            ("score_threads", self.score_threads.into()),
            ("score_sink", self.score_sink.name().into()),
            ("prune", self.prune.label().into()),
            ("prefetch_depth", self.prefetch_depth.into()),
            ("chunk_cache_mb", self.chunk_cache_mb.into()),
            ("summary_chunk", self.summary_chunk.into()),
            ("cluster", self.cluster.into()),
            ("codec", self.codec.as_str().into()),
            ("quant_score", self.quant_score.as_str().into()),
            ("artifacts_dir", self.artifacts_dir.display().to_string().into()),
            ("work_dir", self.work_dir.display().to_string().into()),
        ];
        if let Some(p) = &self.trace_out {
            fields.push(("trace_out", p.display().to_string().into()));
        }
        crate::util::json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut cfg = Config::default();
        cfg.f = 8;
        cfg.r = 64;
        cfg.tier = Tier::Medium;
        cfg.shards = 6;
        cfg.score_threads = 3;
        cfg.score_sink = SinkMode::TopK;
        cfg.prune = PruneMode::Slack(0.25);
        cfg.prefetch_depth = 4;
        cfg.chunk_cache_mb = 256;
        cfg.summary_chunk = 128;
        cfg.cluster = 32;
        cfg.codec = CodecId::Int8;
        cfg.quant_score = QuantScore::On;
        cfg.trace_out = Some(PathBuf::from("trace/q.json"));
        let v = cfg.to_json();
        let mut back = Config::default();
        back.apply_json(&v).unwrap();
        assert_eq!(back.f, 8);
        assert_eq!(back.r, 64);
        assert_eq!(back.tier, Tier::Medium);
        assert_eq!(back.shards, 6);
        assert_eq!(back.score_threads, 3);
        assert_eq!(back.score_sink, SinkMode::TopK);
        assert_eq!(back.prune, PruneMode::Slack(0.25));
        assert_eq!(back.prefetch_depth, 4);
        assert_eq!(back.chunk_cache_mb, 256);
        assert_eq!(back.summary_chunk, 128);
        assert_eq!(back.cluster, 32);
        assert_eq!(back.codec, CodecId::Int8);
        assert_eq!(back.quant_score, QuantScore::On);
        assert_eq!(back.trace_out, Some(PathBuf::from("trace/q.json")));
        // absent from the JSON -> stays off
        assert_eq!(Config::default().trace_out, None);
    }

    #[test]
    fn rejects_clustering_without_a_summary_grid() {
        let mut cfg = Config::default();
        cfg.cluster = 8;
        cfg.summary_chunk = 0;
        assert!(cfg.validate().is_err());
        cfg.summary_chunk = 64;
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_unknown_quant_score_mode() {
        let mut cfg = Config::default();
        assert_eq!(cfg.quant_score, QuantScore::Auto);
        let v = crate::util::json::obj([("quant_score", "maybe".into())]);
        assert!(cfg.apply_json(&v).is_err());
        let v = crate::util::json::obj([("quant_score", "off".into())]);
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.quant_score, QuantScore::Off);
    }

    #[test]
    fn rejects_unknown_codec() {
        let mut cfg = Config::default();
        let v = crate::util::json::obj([("codec", "zip".into())]);
        assert!(cfg.apply_json(&v).is_err());
        let v = crate::util::json::obj([("codec", "int4".into())]);
        cfg.apply_json(&v).unwrap();
        assert_eq!(cfg.codec, CodecId::Int4);
    }

    #[test]
    fn rejects_bad_prune_and_prefetch() {
        let mut cfg = Config::default();
        let v = crate::util::json::obj([("prune", "sometimes".into())]);
        assert!(cfg.apply_json(&v).is_err());
        let mut cfg = Config::default();
        cfg.prefetch_depth = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_unknown_sink() {
        let mut cfg = Config::default();
        let v = crate::util::json::obj([("score_sink", "columnar".into())]);
        assert!(cfg.apply_json(&v).is_err());
    }

    #[test]
    fn rejects_zero_shards() {
        let mut cfg = Config::default();
        cfg.shards = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_bad_f() {
        let mut cfg = Config::default();
        cfg.f = 7; // does not divide 64
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_oversized_c() {
        let mut cfg = Config::default();
        cfg.f = 16;
        cfg.c = 100;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn index_dir_encodes_config() {
        let cfg = Config::default();
        let d = cfg.index_dir();
        assert!(d.display().to_string().contains("index_small_f4_c1"));
    }
}

//! Attribution scorers: LoRIF and every baseline the paper compares
//! against (LoGRA, TrackStar, GradDot, EK-FAC, RepSim).
//!
//! A scorer consumes query gradients and produces a phase-timed
//! `ScoreReport` separating index I/O from compute — the measurement
//! Figure 3 and the latency columns of Tables 1–2 are built on.  The
//! report's payload is chosen by a `SinkSpec`: the full
//! `(n_query, n_train)` matrix (eval/LDS need every score) or streamed
//! per-query top-k heaps holding O(Nq·k) elements regardless of the
//! store size.  Store-backed methods are `exec::ChunkKernel`s run by
//! the shared streaming executor in [`exec`]; adding a scorer means
//! writing one kernel in one file.

pub mod ablation;
#[cfg(feature = "xla")]
pub mod ekfac;
pub mod exec;
pub mod graddot;
pub mod logra;
pub mod lorif;
pub mod repsim;
pub mod trackstar;

use crate::linalg::Mat;
use crate::query::parallel::TopK;
use crate::util::timer::PhaseTimer;

pub use exec::{ChunkKernel, ExecOptions, FullMatrixSink, ScoreSink, Scratch, StreamingTopK};
pub use lorif::LorifScorer;

/// Per-layer query gradients (dense + rank-c factors), rows = queries.
pub struct QueryLayer {
    /// (Nq, d1*d2) dense projected gradients
    pub g: Mat,
    /// (Nq, d1*c) left factors
    pub u: Mat,
    /// (Nq, d2*c) right factors
    pub v: Mat,
}

pub struct QueryGrads {
    pub n_query: usize,
    pub c: usize,
    pub proj_dims: Vec<(usize, usize)>,
    pub layers: Vec<QueryLayer>,
}

impl QueryGrads {
    /// Extract gradients for every example of `queries` via the AOT graph.
    #[cfg(feature = "xla")]
    pub fn extract(
        rt: &crate::runtime::Runtime,
        extractor: &crate::runtime::GradExtractor,
        params: &xla::Literal,
        queries: &crate::corpus::Dataset,
    ) -> anyhow::Result<QueryGrads> {
        let nq = queries.len();
        let dims = extractor.proj_dims.clone();
        let c = extractor.c;
        let mut layers: Vec<QueryLayer> = dims
            .iter()
            .map(|&(d1, d2)| QueryLayer {
                g: Mat::zeros(nq, d1 * d2),
                u: Mat::zeros(nq, d1 * c),
                v: Mat::zeros(nq, d2 * c),
            })
            .collect();
        let mut i = 0;
        while i < nq {
            let take = extractor.batch.min(nq - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let batch = extractor.run(rt, params, queries, &idx)?;
            for (l, lg) in batch.layers.iter().enumerate() {
                for k in 0..take {
                    layers[l].g.row_mut(i + k).copy_from_slice(lg.g.row(k));
                    layers[l].u.row_mut(i + k).copy_from_slice(lg.u.row(k));
                    layers[l].v.row_mut(i + k).copy_from_slice(lg.v.row(k));
                }
            }
            i += take;
        }
        Ok(QueryGrads { n_query: nq, c, proj_dims: dims, layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Which score sink a pass should fold into (per-call, with the top-k
/// budget attached).  The config/CLI-level knob is [`SinkMode`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SinkSpec {
    /// Materialize the full `(n_query, n_train)` matrix.
    Full,
    /// Stream into per-query bounded top-k heaps: O(Nq·k) score memory.
    TopK(usize),
}

/// Config-level sink selection (`--sink full|topk`); the top-k budget
/// comes from the query (`--topk`) at call time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SinkMode {
    Full,
    TopK,
}

impl SinkMode {
    pub fn parse(s: &str) -> anyhow::Result<SinkMode> {
        Ok(match s {
            "full" => SinkMode::Full,
            "topk" => SinkMode::TopK,
            _ => anyhow::bail!("unknown sink '{s}' (full|topk)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SinkMode::Full => "full",
            SinkMode::TopK => "topk",
        }
    }
}

/// What a scoring pass produced.
pub enum ScoreOutput {
    /// `(n_query, n_train)` matrix.
    Full(Mat),
    /// Per-query top-k heaps (best first), merged across shards.
    TopK(Vec<TopK>),
}

/// Result of scoring all training examples for a batch of queries.
pub struct ScoreReport {
    pub output: ScoreOutput,
    pub n_train: usize,
    /// phases: "load" (store I/O + decode), "compute", "precondition"
    pub timer: PhaseTimer,
    pub bytes_read: u64,
    /// Store bytes the chunk pruner proved could not reach the top-k
    /// and seeked past (`crate::sketch`); 0 for full-matrix passes and
    /// on stores without a summary sidecar.  `bytes_read +
    /// bytes_skipped` always equals the full-scan byte count.
    pub bytes_skipped: u64,
    /// Summary-grid chunks skipped without a disk read.
    pub chunks_skipped: usize,
    /// Chunks served from the decoded-chunk cache (`store::cache`); 0
    /// when the store has no cache attached.
    pub cache_hits: usize,
    /// Chunks decoded from disk while a cache was attached.
    pub cache_misses: usize,
    /// The portion of `bytes_read` that was served from the cache and
    /// never hit disk (cache-backed scoring is bit-identical, so
    /// `bytes_read` stays the logical byte count either way).
    pub bytes_from_cache: u64,
    /// Sum over shards of the peak score elements each shard's sink
    /// held: `nq * n_train` for the full matrix, `<= nq * k * shards`
    /// for the streaming top-k path (asserted in `tests/prop.rs`).
    pub peak_sink_elems: usize,
}

impl ScoreReport {
    /// A report holding a fully-materialized score matrix (the only
    /// form non-streaming scorers like RepSim/EK-FAC produce).
    pub fn full(scores: Mat, timer: PhaseTimer, bytes_read: u64) -> ScoreReport {
        let peak = scores.rows * scores.cols;
        ScoreReport {
            n_train: scores.cols,
            output: ScoreOutput::Full(scores),
            timer,
            bytes_read,
            bytes_skipped: 0,
            chunks_skipped: 0,
            cache_hits: 0,
            cache_misses: 0,
            bytes_from_cache: 0,
            peak_sink_elems: peak,
        }
    }

    pub fn n_query(&self) -> usize {
        match &self.output {
            ScoreOutput::Full(m) => m.rows,
            ScoreOutput::TopK(heaps) => heaps.len(),
        }
    }

    /// The full score matrix.  Panics on a streaming top-k report —
    /// callers that need every score (eval, LDS, the figure benches)
    /// must run with `SinkSpec::Full`.
    pub fn scores(&self) -> &Mat {
        match &self.output {
            ScoreOutput::Full(m) => m,
            ScoreOutput::TopK(_) => {
                panic!("score matrix requested from a streaming top-k report")
            }
        }
    }

    /// Consume the report, returning the full score matrix (same
    /// contract as [`ScoreReport::scores`]).
    pub fn into_scores(self) -> Mat {
        match self.output {
            ScoreOutput::Full(m) => m,
            ScoreOutput::TopK(_) => {
                panic!("score matrix requested from a streaming top-k report")
            }
        }
    }

    /// Top-k training indices per query (descending score; NaN-safe
    /// total order, ties toward the lower index).  On a streaming
    /// report `k` is clamped to the heaps' budget.
    pub fn topk(&self, k: usize) -> Vec<Vec<usize>> {
        self.topk_with_scores(k)
            .into_iter()
            .map(|row| row.into_iter().map(|(i, _)| i).collect())
            .collect()
    }

    /// Top-k `(train_index, score)` pairs per query, best first.
    pub fn topk_with_scores(&self, k: usize) -> Vec<Vec<(usize, f32)>> {
        match &self.output {
            ScoreOutput::Full(scores) => (0..scores.rows)
                .map(|q| {
                    let row = scores.row(q);
                    let mut idx: Vec<usize> = (0..row.len()).collect();
                    // stable sort + total_cmp: NaN sorts by the IEEE
                    // total order instead of panicking, and ties keep
                    // the lower index first — the exact order the
                    // bounded heaps (`query::parallel::TopK`) produce
                    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
                    idx.truncate(k);
                    idx.into_iter().map(|i| (i, row[i])).collect()
                })
                .collect(),
            ScoreOutput::TopK(heaps) => heaps
                .iter()
                .map(|h| {
                    h.entries().iter().take(k).map(|&(s, i)| (i, s)).collect()
                })
                .collect(),
        }
    }

    /// Convert a full-matrix report into the requested sink's shape
    /// (no-op for `Full`).  Used by the default `Scorer::score_sink`
    /// for scorers without a streaming path; `peak_sink_elems` keeps
    /// honestly reporting the materialized matrix.
    pub fn reduce(mut self, sink: SinkSpec) -> ScoreReport {
        if let SinkSpec::TopK(k) = sink {
            let heaps = match &self.output {
                ScoreOutput::Full(scores) => Some(
                    (0..scores.rows)
                        .map(|q| {
                            let mut heap = TopK::new(k);
                            for (i, &s) in scores.row(q).iter().enumerate() {
                                heap.push(i, s);
                            }
                            heap
                        })
                        .collect::<Vec<TopK>>(),
                ),
                ScoreOutput::TopK(_) => None,
            };
            if let Some(h) = heaps {
                self.output = ScoreOutput::TopK(h);
            }
        }
        self
    }
}

/// Common scorer interface (the L3 query engine is generic over this).
pub trait Scorer {
    fn name(&self) -> &'static str;
    /// Persistent index bytes this scorer reads per full pass.
    fn index_bytes(&self) -> u64;
    /// Score every training example, materializing the full matrix.
    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport>;
    /// Score with an explicit sink.  Store-backed scorers stream into
    /// the sink directly (O(Nq·k) memory for top-k); the default falls
    /// back to a full pass and reduces.
    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        Ok(self.score(queries)?.reduce(sink))
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::runtime::{ExtractBatch, LayerGrads};
    use crate::store::{ShardedWriter, StoreKind, StoreMeta, StoreWriter};
    use crate::util::prng::Rng;

    /// Build an in-temp-dir store with known gradients (rank-`true_rank`
    /// structure + noise) and matching QueryGrads computed with exact
    /// rank-c power iteration on the CPU.
    pub struct Fixture {
        pub base: std::path::PathBuf,
        pub layer_dims: Vec<(usize, usize)>,
        /// exact dense gradients per layer (n_train rows)
        pub train_g: Vec<Mat>,
        pub queries: QueryGrads,
    }

    pub fn make_fixture(
        n_train: usize,
        n_query: usize,
        layer_dims: &[(usize, usize)],
        c: usize,
        kind: StoreKind,
        name: &str,
    ) -> Fixture {
        build_fixture(n_train, n_query, layer_dims, c, kind, name, 0.05, 1)
    }

    pub fn make_fixture_noise(
        n_train: usize,
        n_query: usize,
        layer_dims: &[(usize, usize)],
        c: usize,
        kind: StoreKind,
        name: &str,
        noise: f32,
    ) -> Fixture {
        build_fixture(n_train, n_query, layer_dims, c, kind, name, noise, 1)
    }

    /// Same deterministic data as `make_fixture`, persisted in the v2
    /// sharded layout (`shards` >= 2).
    pub fn make_fixture_sharded(
        n_train: usize,
        n_query: usize,
        layer_dims: &[(usize, usize)],
        c: usize,
        kind: StoreKind,
        shards: usize,
        name: &str,
    ) -> Fixture {
        build_fixture(n_train, n_query, layer_dims, c, kind, name, 0.05, shards)
    }

    #[allow(clippy::too_many_arguments)]
    fn build_fixture(
        n_train: usize,
        n_query: usize,
        layer_dims: &[(usize, usize)],
        c: usize,
        kind: StoreKind,
        name: &str,
        noise: f32,
        shards: usize,
    ) -> Fixture {
        let dir = std::env::temp_dir().join("lorif_attr_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join(name);
        let mut rng = Rng::new(42);
        // low-rank-ish gradients: rank-3 + small noise (realistic for the
        // factorization paths)
        let gen = |n: usize, rng: &mut Rng| -> Vec<Mat> {
            layer_dims
                .iter()
                .map(|&(d1, d2)| {
                    let a = Mat::random_normal(n, 3, 1.0, rng);
                    let b = Mat::random_normal(3, d1 * d2, 1.0, rng);
                    let mut g = a.matmul(&b);
                    if noise > 0.0 {
                        let e = Mat::random_normal(n, d1 * d2, noise, rng);
                        for (x, ee) in g.data.iter_mut().zip(&e.data) {
                            *x += ee;
                        }
                    }
                    g
                })
                .collect()
        };
        let train_g = gen(n_train, &mut rng);
        let query_g = gen(n_query, &mut rng);

        // factorize on CPU (same math as the kernel)
        let fac = |g: &Mat, d1: usize, d2: usize| -> (Mat, Mat) {
            let mut u = Mat::zeros(g.rows, d1 * c);
            let mut v = Mat::zeros(g.rows, d2 * c);
            for ex in 0..g.rows {
                let gm = Mat::from_vec(d1, d2, g.row(ex).to_vec());
                let (ue, ve) = crate::grads::factorize::poweriter(&gm, c, 16);
                u.row_mut(ex).copy_from_slice(&ue.data);
                v.row_mut(ex).copy_from_slice(&ve.data);
            }
            (u, v)
        };

        // write the store (v1 monolithic, or v2 sharded for shards >= 2;
        // both carry the default summary sidecar, so scorer tests also
        // exercise the v3 open path)
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers: layer_dims.to_vec(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let layers: Vec<LayerGrads> = layer_dims
            .iter()
            .zip(&train_g)
            .map(|(&(d1, d2), g)| {
                let (u, v) = fac(g, d1, d2);
                LayerGrads { g: g.clone(), u, v }
            })
            .collect();
        let batch = ExtractBatch { losses: vec![0.0; n_train], layers, valid: n_train };
        if shards <= 1 {
            let mut w = StoreWriter::create(&base, meta).unwrap();
            w.append(&batch).unwrap();
            w.finalize().unwrap();
        } else {
            let mut w = ShardedWriter::create(&base, meta, shards, n_train).unwrap();
            w.append(&batch).unwrap();
            w.finalize().unwrap();
        }

        let qlayers: Vec<QueryLayer> = layer_dims
            .iter()
            .zip(&query_g)
            .map(|(&(d1, d2), g)| {
                let (u, v) = fac(g, d1, d2);
                QueryLayer { g: g.clone(), u, v }
            })
            .collect();
        Fixture {
            base,
            layer_dims: layer_dims.to_vec(),
            train_g,
            queries: QueryGrads {
                n_query,
                c,
                proj_dims: layer_dims.to_vec(),
                layers: qlayers,
            },
        }
    }
}

impl Scorer for Box<dyn Scorer + '_> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn index_bytes(&self) -> u64 {
        (**self).index_bytes()
    }
    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        (**self).score(queries)
    }
    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        (**self).score_sink(queries, sink)
    }
}

//! LoGRA scorer (Choe et al. 2024) — the primary baseline.
//!
//! Stores *dense* projected gradients and scores with the dense damped
//! Gauss–Newton inverse (paper Eq. 3): queries are preconditioned once
//! per layer by solving `K x = g_q` (Cholesky), then every training
//! example contributes a D-dim dot product — the O(D)-per-pair I/O and
//! compute profile that Fig 3 shows is I/O-bound.  Like every store
//! scorer, the streaming pass is the shared executor in
//! `attribution::exec`; this file only supplies the kernel.

use std::sync::Arc;

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::curvature::DenseCurvature;
use crate::linalg::{matmul_nt_acc, Mat};
use crate::sketch::{ChunkSummary, PruneMode, QueryBounds};
use crate::store::codec::quant;
use crate::store::{
    Chunk, ChunkLayer, QuantPlan, QuantScore, ShardSet, StoreKind, StoreMeta,
    DEFAULT_PREFETCH_DEPTH,
};

pub struct LograScorer {
    /// `Arc`-shared so a pool of serving workers can score against one
    /// opened store (and one decoded-chunk cache)
    pub shards: Arc<ShardSet>,
    pub curv: Arc<DenseCurvature>,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the summary sidecar (`--prune`)
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`)
    pub quant: QuantScore,
}

impl LograScorer {
    pub fn new(
        shards: impl Into<Arc<ShardSet>>,
        curv: impl Into<Arc<DenseCurvature>>,
    ) -> LograScorer {
        LograScorer {
            shards: shards.into(),
            curv: curv.into(),
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
            quant: QuantScore::Auto,
        }
    }
}

/// The LoGRA `ChunkKernel`: preconditioned dot products per chunk.
/// The preconditioned queries `K⁻¹ g_q` are exactly the effective
/// vectors the pruning bound needs (score = ⟨g_t, K⁻¹ g_q⟩), so the
/// kernel stores them once, inside the bound state.
struct LograKernel<'a> {
    curv: &'a DenseCurvature,
    /// per layer (Nq, D) `K⁻¹ g_q` blocks + their pruning-bound norms
    bounds: Option<QueryBounds>,
    /// encoded-segment addressing for quantized-domain scoring
    plan: Option<QuantPlan>,
}

impl ChunkKernel for LograKernel<'_> {
    fn name(&self) -> &'static str {
        "logra"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        let pre: Vec<Mat> = (0..queries.n_layers())
            .map(|l| self.curv.chols[l].solve_rows(&queries.layers[l].g))
            .collect();
        self.bounds = Some(QueryBounds::new(pre));
        self.plan = Some(QuantPlan::dense(meta)?);
        Ok(())
    }

    fn supports_encoded(&self) -> bool {
        true
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        _queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        let pre = &self.bounds.as_ref().expect("precondition ran").blocks;
        if let Some(raw) = &chunk.encoded {
            // quantized-domain path: the preconditioned queries are
            // plain (Nq, D) row blocks, so the score is still a linear
            // dot against the stored codes
            let plan = self.plan.as_ref().expect("precondition builds the quant plan");
            for (l, pre_l) in pre.iter().enumerate() {
                for ex in 0..chunk.count {
                    let (seg, n) = plan.seg(raw, ex, l);
                    quant::accum_row_scores(
                        plan.codec(),
                        seg,
                        n,
                        pre_l,
                        out.row_mut(ex),
                        &mut scratch.quant,
                    );
                }
            }
            return Ok(());
        }
        for (l, pre_l) in pre.iter().enumerate() {
            let g = match &chunk.layers[l] {
                ChunkLayer::Dense { g } => g,
                _ => anyhow::bail!("expected dense chunk"),
            };
            matmul_nt_acc(out, g, pre_l, 1.0);
        }
        Ok(())
    }

    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        self.bounds.as_ref().map(|b| b.upper_bound(s, q))
    }

    fn bound_evals(&self) -> u64 {
        self.bounds.as_ref().map_or(0, |b| b.evals())
    }
}

impl Scorer for LograScorer {
    fn name(&self) -> &'static str {
        "logra"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = LograKernel { curv: self.curv.as_ref(), bounds: None, plan: None };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            quant: self.quant,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::{make_fixture, make_fixture_sharded};

    #[test]
    fn matches_direct_formula() {
        let fx = make_fixture(25, 2, &[(4, 5)], 1, StoreKind::Dense, "logra_direct");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let lambda = curv.lambdas[0];
        let mut scorer = LograScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        scorer.chunk_size = 7;
        let report = scorer.score(&fx.queries).unwrap();

        // direct: g_q^T (G^T G + lam I)^{-1} g_t using the *stored*
        // (bf16-quantized) gradients so the reference sees the same data
        let stored = scorer.shards.read_range(0, 25).unwrap();
        let g = stored.layers[0].dense().clone();
        let mut gram = g.matmul_tn(&g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let scale = report.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            let kq = ch.solve(fx.queries.layers[0].g.row(q));
            for t in 0..25 {
                let want: f32 = g.row(t).iter().zip(&kq).map(|(a, b)| a * b).sum();
                let got = report.scores().at(q, t);
                assert!((got - want).abs() < 0.01 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_factored_store() {
        let fx = make_fixture(10, 1, &[(4, 4)], 1, StoreKind::Factored, "logra_reject");
        let set = ShardSet::open(&fx.base).unwrap();
        // dense curvature can build from factored (reconstructs), but the
        // scorer itself requires dense records
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = LograScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        assert!(scorer.score(&fx.queries).is_err());
    }

    #[test]
    fn sharded_store_matches_monolithic() {
        let fx = make_fixture(30, 2, &[(4, 5), (3, 3)], 1, StoreKind::Dense, "logra_mono");
        let sharded_fx = make_fixture_sharded(
            30,
            2,
            &[(4, 5), (3, 3)],
            1,
            StoreKind::Dense,
            3,
            "logra_split",
        );
        let curv_a = DenseCurvature::build(&ShardSet::open(&fx.base).unwrap(), 0.1).unwrap();
        let curv_b = DenseCurvature::build(&ShardSet::open(&fx.base).unwrap(), 0.1).unwrap();
        let mut mono = LograScorer::new(ShardSet::open(&fx.base).unwrap(), curv_a);
        mono.chunk_size = 7;
        let mut sharded =
            LograScorer::new(ShardSet::open(&sharded_fx.base).unwrap(), curv_b);
        sharded.chunk_size = 4;
        sharded.score_threads = 2;
        assert_eq!(sharded.shards.n_shards(), 3);
        let ra = mono.score(&fx.queries).unwrap();
        let rb = sharded.score(&fx.queries).unwrap();
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in ra.scores().data.iter().zip(&rb.scores().data) {
            assert!((a - b).abs() <= 1e-5 * scale.max(1.0), "{a} vs {b}");
        }
    }
}

//! LoGRA scorer (Choe et al. 2024) — the primary baseline.
//!
//! Stores *dense* projected gradients and scores with the dense damped
//! Gauss–Newton inverse (paper Eq. 3): queries are preconditioned once
//! per layer by solving `K x = g_q` (Cholesky), then every training
//! example contributes a D-dim dot product — the O(D)-per-pair I/O and
//! compute profile that Fig 3 shows is I/O-bound.

use super::{QueryGrads, ScoreReport, Scorer};
use crate::curvature::DenseCurvature;
use crate::linalg::Mat;
use crate::store::{ChunkLayer, StoreKind, StoreReader};
use crate::util::timer::PhaseTimer;

pub struct LograScorer {
    pub reader: StoreReader,
    pub curv: DenseCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
}

impl LograScorer {
    pub fn new(reader: StoreReader, curv: DenseCurvature) -> LograScorer {
        LograScorer { reader, curv, prefetch: true, chunk_size: 512 }
    }
}

impl Scorer for LograScorer {
    fn name(&self) -> &'static str {
        "logra"
    }

    fn index_bytes(&self) -> u64 {
        self.reader.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(
            self.reader.meta.kind == StoreKind::Dense,
            "LoGRA scorer needs a dense store"
        );
        let n = self.reader.meta.n_examples;
        let nq = queries.n_query;
        let n_layers = queries.n_layers();
        let mut timer = PhaseTimer::new();

        // precondition queries per layer: rows = K^{-1} g_q
        let pre: Vec<Mat> = timer.time("precondition", || {
            (0..n_layers)
                .map(|l| self.curv.chols[l].solve_rows(&queries.layers[l].g))
                .collect()
        });

        let mut scores = Mat::zeros(nq, n);
        let mut compute = std::time::Duration::ZERO;
        let (io_time, bytes) = self.reader.stream(self.chunk_size, self.prefetch, |chunk| {
            let t0 = std::time::Instant::now();
            for l in 0..n_layers {
                let g = match &chunk.layers[l] {
                    ChunkLayer::Dense { g } => g,
                    _ => anyhow::bail!("expected dense chunk"),
                };
                let part = g.matmul_nt(&pre[l]); // (B, Nq)
                for nn in 0..chunk.count {
                    let row = part.row(nn);
                    let global = chunk.start + nn;
                    for q in 0..nq {
                        *scores.at_mut(q, global) += row[q];
                    }
                }
            }
            compute += t0.elapsed();
            Ok(())
        })?;
        timer.add("load", io_time);
        timer.add("compute", compute);
        Ok(ScoreReport { scores, timer, bytes_read: bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn matches_direct_formula() {
        let fx = make_fixture(25, 2, &[(4, 5)], 1, StoreKind::Dense, "logra_direct");
        let reader = StoreReader::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&reader, 0.1).unwrap();
        let lambda = curv.lambdas[0];
        let mut scorer = LograScorer::new(StoreReader::open(&fx.base).unwrap(), curv);
        scorer.chunk_size = 7;
        let report = scorer.score(&fx.queries).unwrap();

        // direct: g_q^T (G^T G + lam I)^{-1} g_t using the *stored*
        // (bf16-quantized) gradients so the reference sees the same data
        let stored = scorer.reader.read_range(0, 25).unwrap();
        let g = stored.layers[0].dense().clone();
        let mut gram = g.matmul_tn(&g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let scale = report.scores.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            let kq = ch.solve(fx.queries.layers[0].g.row(q));
            for t in 0..25 {
                let want: f32 = g.row(t).iter().zip(&kq).map(|(a, b)| a * b).sum();
                let got = report.scores.at(q, t);
                assert!((got - want).abs() < 0.01 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_factored_store() {
        let fx = make_fixture(10, 1, &[(4, 4)], 1, StoreKind::Factored, "logra_reject");
        let reader = StoreReader::open(&fx.base).unwrap();
        // dense curvature can build from factored (reconstructs), but the
        // scorer itself requires dense records
        let curv = DenseCurvature::build(&reader, 0.1).unwrap();
        let mut scorer = LograScorer::new(StoreReader::open(&fx.base).unwrap(), curv);
        assert!(scorer.score(&fx.queries).is_err());
    }
}

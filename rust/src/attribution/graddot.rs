//! GradDot baseline (Charpiat et al. 2019 / TracIn-style): plain dot
//! products of projected gradients — the identity-curvature limit of
//! Eq. (3), equivalently LoRIF with r = 0 (Fig 2b's leftmost point).

use super::{QueryGrads, ScoreReport, Scorer};
use crate::linalg::Mat;
use crate::store::{ChunkLayer, StoreKind, StoreReader};
use crate::util::timer::PhaseTimer;

pub struct GradDotScorer {
    pub reader: StoreReader,
    pub prefetch: bool,
    pub chunk_size: usize,
}

impl GradDotScorer {
    pub fn new(reader: StoreReader) -> GradDotScorer {
        GradDotScorer { reader, prefetch: true, chunk_size: 512 }
    }
}

impl Scorer for GradDotScorer {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn index_bytes(&self) -> u64 {
        self.reader.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(
            self.reader.meta.kind == StoreKind::Dense,
            "GradDot scorer needs a dense store"
        );
        let n = self.reader.meta.n_examples;
        let nq = queries.n_query;
        let mut timer = PhaseTimer::new();
        let mut scores = Mat::zeros(nq, n);
        let mut compute = std::time::Duration::ZERO;
        let (io_time, bytes) = self.reader.stream(self.chunk_size, self.prefetch, |chunk| {
            let t0 = std::time::Instant::now();
            for (l, layer) in chunk.layers.iter().enumerate() {
                let g = match layer {
                    ChunkLayer::Dense { g } => g,
                    _ => anyhow::bail!("expected dense chunk"),
                };
                let part = g.matmul_nt(&queries.layers[l].g); // (B, Nq)
                for nn in 0..chunk.count {
                    let row = part.row(nn);
                    for q in 0..nq {
                        *scores.at_mut(q, chunk.start + nn) += row[q];
                    }
                }
            }
            compute += t0.elapsed();
            Ok(())
        })?;
        timer.add("load", io_time);
        timer.add("compute", compute);
        Ok(ScoreReport { scores, timer, bytes_read: bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn matches_plain_dot() {
        let fx = make_fixture(15, 2, &[(4, 4), (3, 5)], 1, StoreKind::Dense, "graddot");
        let mut scorer = GradDotScorer::new(StoreReader::open(&fx.base).unwrap());
        scorer.chunk_size = 4;
        let report = scorer.score(&fx.queries).unwrap();
        let scale = report.scores.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            for t in 0..15 {
                let mut want = 0.0f32;
                for l in 0..2 {
                    want += fx.train_g[l]
                        .row(t)
                        .iter()
                        .zip(fx.queries.layers[l].g.row(q))
                        .map(|(a, b)| a * b)
                        .sum::<f32>();
                }
                let got = report.scores.at(q, t);
                assert!((got - want).abs() < 0.05 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }
}

//! GradDot baseline (Charpiat et al. 2019 / TracIn-style): plain dot
//! products of projected gradients — the identity-curvature limit of
//! Eq. (3), equivalently LoRIF with r = 0 (Fig 2b's leftmost point).
//! The streaming pass is the shared executor in `attribution::exec`;
//! this file only supplies the kernel (the simplest one in the repo —
//! a template for adding new scorers).

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::linalg::Mat;
use crate::store::{Chunk, ChunkLayer, ShardSet, StoreKind, StoreMeta};

pub struct GradDotScorer {
    pub shards: ShardSet,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
}

impl GradDotScorer {
    pub fn new(shards: ShardSet) -> GradDotScorer {
        GradDotScorer { shards, prefetch: true, chunk_size: 512, score_threads: 0 }
    }
}

/// The GradDot `ChunkKernel`: raw gradient dot products, no
/// preconditioned state at all.
struct GradDotKernel;

impl ChunkKernel for GradDotKernel {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, _meta: &StoreMeta, _queries: &QueryGrads) -> anyhow::Result<()> {
        Ok(())
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        _scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        for (l, layer) in chunk.layers.iter().enumerate() {
            let g = match layer {
                ChunkLayer::Dense { g } => g,
                _ => anyhow::bail!("expected dense chunk"),
            };
            let part = g.matmul_nt(&queries.layers[l].g); // (B, Nq)
            for (o, p) in out.data.iter_mut().zip(&part.data) {
                *o += p;
            }
        }
        Ok(())
    }
}

impl Scorer for GradDotScorer {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
        };
        exec::execute(&self.shards, &opts, &mut GradDotKernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn matches_plain_dot() {
        let fx = make_fixture(15, 2, &[(4, 4), (3, 5)], 1, StoreKind::Dense, "graddot");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        scorer.chunk_size = 4;
        let report = scorer.score(&fx.queries).unwrap();
        let scale = report.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            for t in 0..15 {
                let mut want = 0.0f32;
                for l in 0..2 {
                    want += fx.train_g[l]
                        .row(t)
                        .iter()
                        .zip(fx.queries.layers[l].g.row(q))
                        .map(|(a, b)| a * b)
                        .sum::<f32>();
                }
                let got = report.scores().at(q, t);
                assert!((got - want).abs() < 0.05 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_factored_store() {
        let fx = make_fixture(10, 1, &[(4, 4)], 1, StoreKind::Factored, "graddot_reject");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        let err = scorer.score(&fx.queries).unwrap_err();
        assert!(format!("{err}").contains("dense store"), "{err}");
    }

    #[test]
    fn streaming_topk_equals_full_argsort() {
        let fx = make_fixture(20, 3, &[(4, 4)], 1, StoreKind::Dense, "graddot_sink");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        scorer.chunk_size = 6;
        let full = scorer.score(&fx.queries).unwrap();
        let streamed = scorer.score_sink(&fx.queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(streamed.topk(4), full.topk(4));
        assert_eq!(streamed.bytes_read, full.bytes_read);
        assert!(streamed.peak_sink_elems <= 3 * 4);
    }
}

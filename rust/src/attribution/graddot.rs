//! GradDot baseline (Charpiat et al. 2019 / TracIn-style): plain dot
//! products of projected gradients — the identity-curvature limit of
//! Eq. (3), equivalently LoRIF with r = 0 (Fig 2b's leftmost point).
//! The streaming pass is the shared executor in `attribution::exec`;
//! this file only supplies the kernel (the simplest one in the repo —
//! a template for adding new scorers).

use std::sync::Arc;

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::linalg::{matmul_nt_acc, Mat};
use crate::sketch::{ChunkSummary, PruneMode, QueryBounds};
use crate::store::codec::quant;
use crate::store::{
    Chunk, ChunkLayer, QuantPlan, QuantScore, ShardSet, StoreKind, StoreMeta,
    DEFAULT_PREFETCH_DEPTH,
};

pub struct GradDotScorer {
    /// `Arc`-shared so a pool of serving workers can score against one
    /// opened store (and one decoded-chunk cache)
    pub shards: Arc<ShardSet>,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the summary sidecar (`--prune`)
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`)
    pub quant: QuantScore,
}

impl GradDotScorer {
    pub fn new(shards: impl Into<Arc<ShardSet>>) -> GradDotScorer {
        GradDotScorer {
            shards: shards.into(),
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
            quant: QuantScore::Auto,
        }
    }
}

/// The GradDot `ChunkKernel`: raw gradient dot products; the query
/// gradients themselves double as the pruning-bound blocks (the score
/// IS `⟨g_t, g_q⟩`).
struct GradDotKernel {
    bounds: Option<QueryBounds>,
    /// encoded-segment addressing for quantized-domain scoring
    plan: Option<QuantPlan>,
}

impl ChunkKernel for GradDotKernel {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        // the one kernel with no preconditioned state of its own: clone
        // the query blocks into the bound state (`upper_bound` cannot
        // reach `queries`, and one extra query-batch copy is noise next
        // to the store pass it lets us skip)
        self.bounds =
            Some(QueryBounds::new(queries.layers.iter().map(|l| l.g.clone()).collect()));
        self.plan = Some(QuantPlan::dense(meta)?);
        Ok(())
    }

    fn supports_encoded(&self) -> bool {
        true
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        if let Some(raw) = &chunk.encoded {
            // quantized-domain path: integer-code dots straight off the
            // record bytes, one scale multiply per group
            let plan = self.plan.as_ref().expect("precondition builds the quant plan");
            for l in 0..plan.n_layers() {
                let yl = &queries.layers[l].g;
                for ex in 0..chunk.count {
                    let (seg, n) = plan.seg(raw, ex, l);
                    quant::accum_row_scores(
                        plan.codec(),
                        seg,
                        n,
                        yl,
                        out.row_mut(ex),
                        &mut scratch.quant,
                    );
                }
            }
            return Ok(());
        }
        for (l, layer) in chunk.layers.iter().enumerate() {
            let g = match layer {
                ChunkLayer::Dense { g } => g,
                _ => anyhow::bail!("expected dense chunk"),
            };
            matmul_nt_acc(out, g, &queries.layers[l].g, 1.0);
        }
        Ok(())
    }

    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        self.bounds.as_ref().map(|b| b.upper_bound(s, q))
    }

    fn bound_evals(&self) -> u64 {
        self.bounds.as_ref().map_or(0, |b| b.evals())
    }
}

impl Scorer for GradDotScorer {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            quant: self.quant,
        };
        let mut kernel = GradDotKernel { bounds: None, plan: None };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn matches_plain_dot() {
        let fx = make_fixture(15, 2, &[(4, 4), (3, 5)], 1, StoreKind::Dense, "graddot");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        scorer.chunk_size = 4;
        let report = scorer.score(&fx.queries).unwrap();
        let scale = report.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            for t in 0..15 {
                let mut want = 0.0f32;
                for l in 0..2 {
                    want += fx.train_g[l]
                        .row(t)
                        .iter()
                        .zip(fx.queries.layers[l].g.row(q))
                        .map(|(a, b)| a * b)
                        .sum::<f32>();
                }
                let got = report.scores().at(q, t);
                assert!((got - want).abs() < 0.05 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_factored_store() {
        let fx = make_fixture(10, 1, &[(4, 4)], 1, StoreKind::Factored, "graddot_reject");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        let err = scorer.score(&fx.queries).unwrap_err();
        assert!(format!("{err}").contains("dense store"), "{err}");
    }

    #[test]
    fn streaming_topk_equals_full_argsort() {
        let fx = make_fixture(20, 3, &[(4, 4)], 1, StoreKind::Dense, "graddot_sink");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        scorer.chunk_size = 6;
        let full = scorer.score(&fx.queries).unwrap();
        let streamed = scorer.score_sink(&fx.queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(streamed.topk(4), full.topk(4));
        // with pruning on, skipped bytes account for the difference
        assert_eq!(streamed.bytes_read + streamed.bytes_skipped, full.bytes_read);
        assert!(streamed.peak_sink_elems <= 3 * 4);
    }

    #[test]
    fn exact_pruning_skips_unreachable_chunks_and_stays_exact() {
        use crate::attribution::{QueryLayer, SinkSpec};
        use crate::runtime::{ExtractBatch, LayerGrads};
        use crate::store::{StoreMeta, StoreWriter};
        use crate::util::prng::Rng;

        // clustered store: the first summary chunk holds strong rows
        // aligned with the query; every later chunk holds near-zero rows
        // that provably cannot reach the top-k once the heap is full
        let dir = std::env::temp_dir().join("lorif_attr_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("graddot_prune");
        let (n, d, chunk) = (64usize, 16usize, 8usize);
        let mut rng = Rng::new(31);
        let mut g = Mat::zeros(n, d);
        for t in 0..n {
            let scale = if t < chunk { 10.0 } else { 0.01 };
            for x in g.row_mut(t) {
                *x = scale * (0.5 + 0.05 * rng.normal() as f32);
            }
        }
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(4, 4)],
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let mut w = StoreWriter::create(&base, meta).unwrap();
        w.set_summary_chunk(chunk).unwrap();
        w.append(&ExtractBatch {
            losses: vec![0.0; n],
            layers: vec![LayerGrads {
                g: g.clone(),
                u: Mat::zeros(n, 4),
                v: Mat::zeros(n, 4),
            }],
            valid: n,
        })
        .unwrap();
        w.finalize().unwrap();

        let queries = crate::attribution::QueryGrads {
            n_query: 2,
            c: 1,
            proj_dims: vec![(4, 4)],
            layers: vec![QueryLayer {
                g: Mat::from_vec(2, d, vec![1.0; 2 * d]),
                u: Mat::zeros(2, 4),
                v: Mat::zeros(2, 4),
            }],
        };

        let mut scorer = GradDotScorer::new(ShardSet::open(&base).unwrap());
        let full = scorer.score(&queries).unwrap();

        scorer.prune = PruneMode::Exact;
        let pruned = scorer.score_sink(&queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(pruned.topk(4), full.topk(4), "exact pruning must not change top-k");
        let stride = scorer.shards.meta.bytes_per_example() as u64;
        // all 7 weak chunks are provably unreachable after chunk 0
        assert_eq!(pruned.chunks_skipped, 7, "expected every weak chunk skipped");
        assert_eq!(pruned.bytes_skipped, 7 * chunk as u64 * stride);
        assert_eq!(pruned.bytes_read + pruned.bytes_skipped, full.bytes_read);

        // prune off: same results, no skips
        scorer.prune = PruneMode::Off;
        let unpruned = scorer.score_sink(&queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(unpruned.topk(4), full.topk(4));
        assert_eq!(unpruned.bytes_skipped, 0);
        assert_eq!(unpruned.chunks_skipped, 0);
        assert_eq!(unpruned.bytes_read, full.bytes_read);

        // the same clustered store behind a decoded-chunk cache: the 7
        // provably-skippable chunks must never POPULATE the cache (only
        // chunk 0 is read and inserted), skip decisions are unchanged
        // by residency, and the warm rerun serves its one read hot —
        // all bit-identical to the cold pruned pass
        let mut cached_set = ShardSet::open(&base).unwrap();
        let cache = crate::store::ChunkCache::with_capacity(8 << 20);
        cached_set.set_cache(Some(cache.clone()));
        let mut cached = GradDotScorer::new(cached_set);
        cached.prune = PruneMode::Exact;
        let p1 = cached.score_sink(&queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(p1.topk(4), pruned.topk(4));
        assert_eq!(p1.chunks_skipped, 7);
        assert_eq!((p1.cache_hits, p1.cache_misses), (0, 1));
        assert_eq!(cache.stats().insertions, 1, "skipped chunks were cached");
        let p2 = cached.score_sink(&queries, SinkSpec::TopK(4)).unwrap();
        assert_eq!(p2.topk(4), pruned.topk(4));
        assert_eq!(p2.chunks_skipped, 7, "a resident chunk changed a skip decision");
        assert_eq!((p2.cache_hits, p2.cache_misses), (1, 0));
        assert_eq!(p2.bytes_from_cache, p2.bytes_read);
        assert_eq!(cache.stats().insertions, 1);
    }
}

//! GradDot baseline (Charpiat et al. 2019 / TracIn-style): plain dot
//! products of projected gradients — the identity-curvature limit of
//! Eq. (3), equivalently LoRIF with r = 0 (Fig 2b's leftmost point).
//! Streams per shard on the worker pool like the other store scorers.

use super::{QueryGrads, ScoreReport, Scorer};
use crate::linalg::Mat;
use crate::query::parallel::{self, ShardScores};
use crate::store::{ChunkLayer, ShardSet, StoreKind};
use crate::util::timer::PhaseTimer;

pub struct GradDotScorer {
    pub shards: ShardSet,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
}

impl GradDotScorer {
    pub fn new(shards: ShardSet) -> GradDotScorer {
        GradDotScorer { shards, prefetch: true, chunk_size: 512, score_threads: 0 }
    }
}

impl Scorer for GradDotScorer {
    fn name(&self) -> &'static str {
        "graddot"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(
            self.shards.meta.kind == StoreKind::Dense,
            "GradDot scorer needs a dense store"
        );
        let n = self.shards.meta.n_examples;
        let nq = queries.n_query;
        let mut timer = PhaseTimer::new();
        let chunk_size = self.chunk_size;
        // with multiple shard workers the workers themselves overlap I/O
        // and compute, so per-shard prefetch threads would only
        // oversubscribe the cores; prefetch only on the 1-worker path
        let workers =
            crate::util::pool::effective_threads(self.score_threads).min(self.shards.n_shards());
        let prefetch = self.prefetch && workers <= 1;
        let parts = parallel::map_shards(&self.shards, self.score_threads, |_, reader| {
            let shard_start = reader.start;
            let mut local = Mat::zeros(nq, reader.count);
            let mut compute = std::time::Duration::ZERO;
            let (io, bytes) = reader.stream(chunk_size, prefetch, |chunk| {
                let t0 = std::time::Instant::now();
                for (l, layer) in chunk.layers.iter().enumerate() {
                    let g = match layer {
                        ChunkLayer::Dense { g } => g,
                        _ => anyhow::bail!("expected dense chunk"),
                    };
                    let part = g.matmul_nt(&queries.layers[l].g); // (B, Nq)
                    for nn in 0..chunk.count {
                        let row = part.row(nn);
                        let col = chunk.start - shard_start + nn;
                        for q in 0..nq {
                            *local.at_mut(q, col) += row[q];
                        }
                    }
                }
                compute += t0.elapsed();
                Ok(())
            })?;
            Ok(ShardScores { start: shard_start, scores: local, io, compute, bytes })
        })?;
        let (scores, shard_timer, bytes) = parallel::merge_scores(nq, n, parts);
        timer.merge(&shard_timer);
        Ok(ScoreReport { scores, timer, bytes_read: bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn matches_plain_dot() {
        let fx = make_fixture(15, 2, &[(4, 4), (3, 5)], 1, StoreKind::Dense, "graddot");
        let mut scorer = GradDotScorer::new(ShardSet::open(&fx.base).unwrap());
        scorer.chunk_size = 4;
        let report = scorer.score(&fx.queries).unwrap();
        let scale = report.scores.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            for t in 0..15 {
                let mut want = 0.0f32;
                for l in 0..2 {
                    want += fx.train_g[l]
                        .row(t)
                        .iter()
                        .zip(fx.queries.layers[l].g.row(q))
                        .map(|(a, b)| a * b)
                        .sum::<f32>();
                }
                let got = report.scores.at(q, t);
                assert!((got - want).abs() < 0.05 * scale + 1e-4, "{got} vs {want}");
            }
        }
    }
}

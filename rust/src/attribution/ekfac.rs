//! EK-FAC scorer (Grosse et al. 2023) — parameter-space influence with
//! per-query training-gradient *recomputation* (no stored index).
//!
//! This is the Table 1 contextual baseline: highest LDS, tiny persistent
//! storage (only the covariance eigenbases), but orders of magnitude
//! slower at query time because every query batch re-runs gradient
//! extraction (f = 1, unprojected) over the training corpus.

use super::{QueryGrads, ScoreReport, Scorer};
use crate::corpus::Dataset;
use crate::curvature::Ekfac;
use crate::linalg::Mat;
use crate::runtime::{GradExtractor, Runtime};
use crate::util::timer::PhaseTimer;

pub struct EkfacScorer<'a> {
    pub rt: &'a Runtime,
    pub extractor: &'a GradExtractor,
    pub params: &'a xla::Literal,
    pub train: &'a Dataset,
    pub ekfac: Ekfac,
    /// (I, O) dims per layer (f = 1)
    pub layer_dims: Vec<(usize, usize)>,
}

impl<'a> EkfacScorer<'a> {
    /// Eigenvalue-correction pass (the "EK" in EK-FAC): average the
    /// squared rotated gradients over up to `max_examples` training
    /// examples, then install them as corrected eigenvalues.
    pub fn fit_corrections(
        &mut self,
        max_examples: usize,
        lambda_factor: f32,
    ) -> anyhow::Result<()> {
        let n = self.train.len().min(max_examples);
        let mut acc: Vec<Mat> = self
            .layer_dims
            .iter()
            .map(|&(i, o)| Mat::zeros(i, o))
            .collect();
        let mut i = 0;
        while i < n {
            let take = self.extractor.batch.min(n - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let batch = self.extractor.run(self.rt, self.params, self.train, &idx)?;
            for (l, lg) in batch.layers.iter().enumerate() {
                let (di, doo) = self.layer_dims[l];
                for ex in 0..take {
                    let g = Mat::from_vec(di, doo, lg.g.row(ex).to_vec());
                    let rot = self.ekfac.rotate(l, &g);
                    for (a, r) in acc[l].data.iter_mut().zip(&rot.data) {
                        *a += r * r;
                    }
                }
            }
            i += take;
        }
        for (l, mut m) in acc.into_iter().enumerate() {
            m.scale(1.0 / n as f32);
            self.ekfac.set_corrections(l, m, lambda_factor);
        }
        Ok(())
    }
}

impl Scorer for EkfacScorer<'_> {
    fn name(&self) -> &'static str {
        "ekfac"
    }

    fn index_bytes(&self) -> u64 {
        // persistent artifacts: eigenbases + corrected eigenvalues
        self.ekfac
            .layers
            .iter()
            .map(|l| {
                4 * (l.q_a.data.len() + l.q_s.data.len() + l.lambda_corr.data.len()) as u64
            })
            .sum()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        let nq = queries.n_query;
        let n = self.train.len();
        let mut timer = PhaseTimer::new();

        // precondition queries (iHVP) once
        let pre: Vec<Mat> = timer.time("precondition", || {
            (0..self.layer_dims.len())
                .map(|l| {
                    let (di, doo) = self.layer_dims[l];
                    let mut out = Mat::zeros(nq, di * doo);
                    for q in 0..nq {
                        let g = Mat::from_vec(di, doo, queries.layers[l].g.row(q).to_vec());
                        let p = self.ekfac.precondition(l, &g);
                        out.row_mut(q).copy_from_slice(&p.data);
                    }
                    out
                })
                .collect()
        });

        // recompute training gradients batch-by-batch (the expensive part)
        let mut scores = Mat::zeros(nq, n);
        let mut i = 0;
        while i < n {
            let take = self.extractor.batch.min(n - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let batch = timer.time("recompute", || {
                self.extractor.run(self.rt, self.params, self.train, &idx)
            })?;
            timer.time("compute", || {
                for (l, lg) in batch.layers.iter().enumerate() {
                    // scores[q, i+ex] += <pre_q, g_ex>
                    for ex in 0..take {
                        let gt = lg.g.row(ex);
                        for q in 0..nq {
                            let s: f32 = pre[l]
                                .row(q)
                                .iter()
                                .zip(gt)
                                .map(|(a, b)| a * b)
                                .sum();
                            *scores.at_mut(q, i + ex) += s;
                        }
                    }
                }
            });
            i += take;
        }
        Ok(ScoreReport::full(scores, timer, 0))
    }
}

//! Shared streaming executor for all store-backed scorers.
//!
//! Every attribution method over a gradient store reduces to the same
//! shape: precondition the query batch once, then stream the store in
//! chunks and score each chunk against the preconditioned queries.
//! `ChunkKernel` captures exactly that pair of operations; `execute`
//! owns everything around it — store-kind validation, the per-shard
//! worker loop (`query::parallel::map_shards`), the prefetch heuristic,
//! chunk iteration, and the load/compute phase accounting — so a new
//! scorer is one kernel in one file, and hot-path improvements land
//! once instead of once per method.
//!
//! The kernel's output flows into a `ScoreSink`.  `FullMatrixSink`
//! materializes the classic `(n_query, n_train)` matrix (eval, LDS, and
//! the figure benches need the whole thing); `StreamingTopK` folds each
//! `(B, n_query)` block into per-query bounded heaps, so a top-k query
//! holds O(Nq·k) score elements per shard no matter how large the store
//! is — the memory model that lets the engine, server, and CLI serve
//! top-k proponents against stores far larger than RAM.

use std::time::{Duration, Instant};

use super::{QueryGrads, ScoreOutput, ScoreReport, SinkSpec};
use crate::linalg::Mat;
use crate::query::parallel::{self, ShardScores, TopK};
use crate::store::{Chunk, ShardSet, StoreKind, StoreMeta, StoreReader};
use crate::util::pool;
use crate::util::timer::PhaseTimer;

/// Reusable per-worker scratch buffer (e.g. for gradient reconstruction
/// on the faithful Woodbury path).  Kernels may resize it freely; the
/// executor keeps it alive across chunks so the allocation is paid once
/// per shard, not once per chunk.
pub struct Scratch {
    pub mat: Mat,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { mat: Mat::zeros(0, 0) }
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

/// One attribution method on the streaming hot path.
///
/// `precondition` runs once per query batch (timed under the
/// "precondition" phase); `score_chunk` runs once per decoded chunk on
/// the shard workers and must ACCUMULATE (`+=`) into `out`, a zeroed
/// `(chunk.count, n_query)` block — row `b` holds the scores of
/// training example `chunk.start + b` against every query.
pub trait ChunkKernel: Sync {
    fn name(&self) -> &'static str;

    /// Store kind this kernel consumes (validated by the executor).
    fn store_kind(&self) -> StoreKind;

    /// Validate the query batch against the store and precondition the
    /// query side, stashing prepared state in `self`.
    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()>;

    /// Score one decoded chunk against the preconditioned queries.
    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()>;
}

/// Where a scorer pass puts its scores.  Implementations consume
/// `(B, n_query)` blocks in stream order within a shard; one sink
/// instance exists per shard, merged by the executor afterwards.
pub trait ScoreSink: Send {
    /// Consume the score block for training examples
    /// `[start, start + block.rows)`.
    fn consume(&mut self, start: usize, block: &Mat);

    /// Score elements this sink currently holds (memory accounting; the
    /// streaming-top-k O(Nq·k) guarantee is asserted through this).
    fn allocated_elems(&self) -> usize;
}

/// Materializes this shard's `(n_query, shard_count)` column block.
pub struct FullMatrixSink {
    pub start: usize,
    pub scores: Mat,
}

impl FullMatrixSink {
    pub fn new(nq: usize, start: usize, count: usize) -> FullMatrixSink {
        FullMatrixSink { start, scores: Mat::zeros(nq, count) }
    }
}

impl ScoreSink for FullMatrixSink {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let col = start - self.start + b;
            let row = block.row(b);
            for (q, &s) in row.iter().enumerate() {
                *self.scores.at_mut(q, col) = s;
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.scores.rows * self.scores.cols
    }
}

/// Folds score blocks into per-query bounded top-k heaps: O(Nq·k)
/// memory per shard, independent of the store size.
pub struct StreamingTopK {
    pub heaps: Vec<TopK>,
}

impl StreamingTopK {
    pub fn new(nq: usize, k: usize) -> StreamingTopK {
        StreamingTopK { heaps: (0..nq).map(|_| TopK::new(k)).collect() }
    }
}

impl ScoreSink for StreamingTopK {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let row = block.row(b);
            for (q, heap) in self.heaps.iter_mut().enumerate() {
                heap.push(start + b, row[q]);
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.heaps.iter().map(TopK::len).sum()
    }
}

/// Streaming knobs shared by every store scorer.
pub struct ExecOptions {
    pub chunk_size: usize,
    pub prefetch: bool,
    /// worker threads for shard scoring (0 = all cores)
    pub threads: usize,
}

struct ShardRun<S> {
    sink: S,
    io: Duration,
    compute: Duration,
    bytes: u64,
    /// peak score elements the sink held during this shard's pass
    peak: usize,
}

/// Run `kernel` over every shard of `set`, folding scores into the
/// requested sink.  This is the single streaming scaffold behind all
/// store scorers: kind validation, preconditioning, the worker loop,
/// prefetch gating, and phase-time merging live here and only here.
pub fn execute<K: ChunkKernel>(
    set: &ShardSet,
    opts: &ExecOptions,
    kernel: &mut K,
    queries: &QueryGrads,
    sink: SinkSpec,
) -> anyhow::Result<ScoreReport> {
    anyhow::ensure!(
        set.meta.kind == kernel.store_kind(),
        "{} scorer needs a {} store",
        kernel.name(),
        kernel.store_kind().as_str()
    );
    anyhow::ensure!(
        queries.n_layers() == set.meta.layers.len(),
        "query batch has {} layers, store has {}",
        queries.n_layers(),
        set.meta.layers.len()
    );
    let n = set.meta.n_examples;
    let nq = queries.n_query;
    let mut timer = PhaseTimer::new();
    timer.time("precondition", || kernel.precondition(&set.meta, queries))?;

    // with multiple shard workers the workers themselves overlap I/O
    // and compute, so per-shard prefetch threads would only
    // oversubscribe the cores; prefetch only on the 1-worker path
    let workers = pool::effective_threads(opts.threads).min(set.n_shards());
    let prefetch = opts.prefetch && workers <= 1;
    let kernel: &K = kernel;

    match sink {
        SinkSpec::Full => {
            let runs = run_shards(set, opts, prefetch, kernel, queries, |r| {
                FullMatrixSink::new(nq, r.start, r.count)
            })?;
            let peak: usize = runs.iter().map(|r| r.peak).sum();
            let parts: Vec<ShardScores> = runs
                .into_iter()
                .map(|r| ShardScores {
                    start: r.sink.start,
                    scores: r.sink.scores,
                    io: r.io,
                    compute: r.compute,
                    bytes: r.bytes,
                })
                .collect();
            let (scores, shard_timer, bytes) = parallel::merge_scores(nq, n, parts);
            timer.merge(&shard_timer);
            Ok(ScoreReport {
                output: ScoreOutput::Full(scores),
                n_train: n,
                timer,
                bytes_read: bytes,
                peak_sink_elems: peak,
            })
        }
        SinkSpec::TopK(k) => {
            let runs =
                run_shards(set, opts, prefetch, kernel, queries, |_| StreamingTopK::new(nq, k))?;
            let mut io = Duration::ZERO;
            let mut compute = Duration::ZERO;
            let mut bytes = 0u64;
            let mut peak = 0usize;
            let mut shard_heaps = Vec::with_capacity(runs.len());
            for r in runs {
                io += r.io;
                compute += r.compute;
                bytes += r.bytes;
                peak += r.peak;
                shard_heaps.push(r.sink.heaps);
            }
            let heaps = parallel::merge_topk(nq, k, shard_heaps);
            timer.add("load", io);
            timer.add("compute", compute);
            Ok(ScoreReport {
                output: ScoreOutput::TopK(heaps),
                n_train: n,
                timer,
                bytes_read: bytes,
                peak_sink_elems: peak,
            })
        }
    }
}

/// The one worker loop: stream each shard in chunks, score, sink.
fn run_shards<K, S, F>(
    set: &ShardSet,
    opts: &ExecOptions,
    prefetch: bool,
    kernel: &K,
    queries: &QueryGrads,
    make_sink: F,
) -> anyhow::Result<Vec<ShardRun<S>>>
where
    K: ChunkKernel,
    S: ScoreSink,
    F: Fn(&StoreReader) -> S + Sync,
{
    let nq = queries.n_query;
    parallel::map_shards(set, opts.threads, |_, reader| {
        let mut sink = make_sink(&reader);
        let mut compute = Duration::ZERO;
        let mut scratch = Scratch::new();
        let mut block = Mat::zeros(0, 0);
        let mut peak = 0usize;
        let (io, bytes) = reader.stream(opts.chunk_size, prefetch, |chunk| {
            let t0 = Instant::now();
            if block.rows != chunk.count || block.cols != nq {
                block = Mat::zeros(chunk.count, nq);
            } else {
                block.data.iter_mut().for_each(|x| *x = 0.0);
            }
            kernel.score_chunk(&chunk, queries, &mut block, &mut scratch)?;
            sink.consume(chunk.start, &block);
            peak = peak.max(sink.allocated_elems());
            compute += t0.elapsed();
            Ok(())
        })?;
        Ok(ShardRun { sink, io, compute, bytes, peak })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_matrix_sink_places_blocks_in_shard_coordinates() {
        let mut sink = FullMatrixSink::new(2, 10, 5);
        // two blocks: global [10, 13) and [13, 15)
        let b1 = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b2 = Mat::from_vec(2, 2, vec![7.0, 8.0, 9.0, 10.0]);
        sink.consume(10, &b1);
        sink.consume(13, &b2);
        assert_eq!(sink.scores.row(0), &[1.0, 3.0, 5.0, 7.0, 9.0]);
        assert_eq!(sink.scores.row(1), &[2.0, 4.0, 6.0, 8.0, 10.0]);
        assert_eq!(sink.allocated_elems(), 10);
    }

    #[test]
    fn streaming_topk_sink_is_bounded() {
        let nq = 3;
        let k = 4;
        let mut sink = StreamingTopK::new(nq, k);
        let mut rng = crate::util::prng::Rng::new(7);
        let mut at = 0usize;
        let mut peak = 0usize;
        for _ in 0..20 {
            let block = Mat::random_normal(8, nq, 1.0, &mut rng);
            sink.consume(at, &block);
            at += 8;
            peak = peak.max(sink.allocated_elems());
        }
        assert!(peak <= nq * k, "peak {peak} > {}", nq * k);
        for heap in &sink.heaps {
            assert_eq!(heap.len(), k);
        }
    }
}

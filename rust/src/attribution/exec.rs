//! Shared streaming executor for all store-backed scorers.
//!
//! Every attribution method over a gradient store reduces to the same
//! shape: precondition the query batch once, then stream the store in
//! chunks and score each chunk against the preconditioned queries.
//! `ChunkKernel` captures exactly that pair of operations; `execute`
//! owns everything around it — store-kind validation, the per-shard
//! worker loop (`query::parallel::map_shards`), the prefetch heuristic,
//! chunk iteration, and the load/compute phase accounting — so a new
//! scorer is one kernel in one file, and hot-path improvements land
//! once instead of once per method.
//!
//! The kernel's output flows into a `ScoreSink`.  `FullMatrixSink`
//! materializes the classic `(n_query, n_train)` matrix (eval, LDS, and
//! the figure benches need the whole thing); `StreamingTopK` folds each
//! `(B, n_query)` block into per-query bounded heaps, so a top-k query
//! holds O(Nq·k) score elements per shard no matter how large the store
//! is — the memory model that lets the engine, server, and CLI serve
//! top-k proponents against stores far larger than RAM.
//!
//! On top of the sinks sits chunk pruning (`crate::sketch`): when the
//! store carries a v3 summary sidecar, the sink is a top-k heap, and
//! `--prune` is on, the executor walks the summary grid with a
//! skip-aware cursor.  A chunk is read only if some query's
//! Cauchy–Schwarz upper bound (`ChunkKernel::upper_bound`) could still
//! beat that query's current k-th best (`ScoreSink::threshold`);
//! otherwise the cursor seeks past it, and the saved I/O is reported as
//! `bytes_skipped`/`chunks_skipped` on the `ScoreReport`.  Exact mode
//! is provably identical to a full scan (see `sketch::prune`).

use std::time::{Duration, Instant};

use super::{QueryGrads, ScoreOutput, ScoreReport, SinkSpec};
use crate::linalg::Mat;
use crate::query::parallel::{self, ShardScores, TopK};
use crate::sketch::{ChunkPruner, ChunkSummary, PruneMode};
use crate::store::{
    Chunk, QuantScore, QuantScratch, ShardSet, StoreKind, StoreMeta, StoreReader, StreamStats,
};
use crate::util::pool;
use crate::util::timer::PhaseTimer;

/// Reusable per-worker scratch buffer (e.g. for gradient reconstruction
/// on the faithful Woodbury path).  Kernels may resize it freely; the
/// executor keeps it alive across chunks so the allocation is paid once
/// per shard, not once per chunk.
pub struct Scratch {
    pub mat: Mat,
    /// decode/unpack buffers for quantized-domain scoring
    /// (`store::codec::quant`)
    pub quant: QuantScratch,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { mat: Mat::zeros(0, 0), quant: QuantScratch::new() }
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

/// One attribution method on the streaming hot path.
///
/// `precondition` runs once per query batch (timed under the
/// "precondition" phase); `score_chunk` runs once per decoded chunk on
/// the shard workers and must ACCUMULATE (`+=`) into `out`, a zeroed
/// `(chunk.count, n_query)` block — row `b` holds the scores of
/// training example `chunk.start + b` against every query.
pub trait ChunkKernel: Sync {
    fn name(&self) -> &'static str;

    /// Store kind this kernel consumes (validated by the executor).
    fn store_kind(&self) -> StoreKind;

    /// Validate the query batch against the store and precondition the
    /// query side, stashing prepared state in `self`.
    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()>;

    /// Score one chunk against the preconditioned queries.  The chunk
    /// is decoded unless this kernel advertised `supports_encoded` and
    /// quantized-domain scoring is active for the query.
    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()>;

    /// Whether `score_chunk` can consume ENCODED chunks
    /// (`Chunk::encoded` raw record bytes) in addition to decoded ones.
    /// Kernels that return `true` here must branch on `chunk.encoded`
    /// inside `score_chunk`; the executor decides per query whether to
    /// stream encoded chunks (`ExecOptions::quant` × the store codec,
    /// see `QuantScore::active`).
    fn supports_encoded(&self) -> bool {
        false
    }

    /// SOUND upper bound on the score this kernel could produce for ANY
    /// example of a chunk with summary `s`, against query `q` — i.e.
    /// never less than any value `score_chunk` would write for that
    /// chunk.  `None` opts the kernel out of pruning (the chunk is then
    /// always read).  Called after `precondition`, only on the pruned
    /// path; kernels typically answer from a `sketch::QueryBounds` over
    /// their preconditioned query blocks.
    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        let _ = (s, q);
        None
    }
}

/// Where a scorer pass puts its scores.  Implementations consume
/// `(B, n_query)` blocks in stream order within a shard; one sink
/// instance exists per shard, merged by the executor afterwards.
pub trait ScoreSink: Send {
    /// Consume the score block for training examples
    /// `[start, start + block.rows)`.
    fn consume(&mut self, start: usize, block: &Mat);

    /// Score elements this sink currently holds (memory accounting; the
    /// streaming-top-k O(Nq·k) guarantee is asserted through this).
    fn allocated_elems(&self) -> usize;

    /// The score a NEW candidate at a higher index must EXCEED to
    /// change this sink's output for query `q`, or `None` when the sink
    /// still needs every score.  The default (`None`) makes pruning
    /// inert for full-matrix passes.
    fn threshold(&self, q: usize) -> Option<f32> {
        let _ = q;
        None
    }
}

/// Materializes this shard's `(n_query, shard_count)` column block.
pub struct FullMatrixSink {
    pub start: usize,
    pub scores: Mat,
}

impl FullMatrixSink {
    pub fn new(nq: usize, start: usize, count: usize) -> FullMatrixSink {
        FullMatrixSink { start, scores: Mat::zeros(nq, count) }
    }
}

impl ScoreSink for FullMatrixSink {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let col = start - self.start + b;
            let row = block.row(b);
            for (q, &s) in row.iter().enumerate() {
                *self.scores.at_mut(q, col) = s;
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.scores.rows * self.scores.cols
    }
}

/// Folds score blocks into per-query bounded top-k heaps: O(Nq·k)
/// memory per shard, independent of the store size.
pub struct StreamingTopK {
    pub heaps: Vec<TopK>,
}

impl StreamingTopK {
    pub fn new(nq: usize, k: usize) -> StreamingTopK {
        StreamingTopK { heaps: (0..nq).map(|_| TopK::new(k)).collect() }
    }
}

impl ScoreSink for StreamingTopK {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let row = block.row(b);
            for (q, heap) in self.heaps.iter_mut().enumerate() {
                heap.push(start + b, row[q]);
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.heaps.iter().map(TopK::len).sum()
    }

    fn threshold(&self, q: usize) -> Option<f32> {
        self.heaps[q].threshold()
    }
}

/// Streaming knobs shared by every store scorer.
pub struct ExecOptions {
    pub chunk_size: usize,
    pub prefetch: bool,
    /// worker threads for shard scoring (0 = all cores)
    pub threads: usize,
    /// prefetch queue depth in chunks (>= 1; `--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the store's v3 summary sidecar — inert on
    /// full-matrix passes and on stores without a sidecar
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`): stream raw encoded
    /// chunks to kernels that support them instead of decoding to f32
    pub quant: QuantScore,
}

struct ShardRun<S> {
    sink: S,
    io: Duration,
    compute: Duration,
    /// byte/chunk/cache accounting of this shard's pass
    stats: StreamStats,
    /// peak score elements the sink held during this shard's pass
    peak: usize,
}

/// Run `kernel` over every shard of `set`, folding scores into the
/// requested sink.  This is the single streaming scaffold behind all
/// store scorers: kind validation, preconditioning, the worker loop,
/// prefetch gating, chunk pruning, and phase-time merging live here and
/// only here.
pub fn execute<K: ChunkKernel>(
    set: &ShardSet,
    opts: &ExecOptions,
    kernel: &mut K,
    queries: &QueryGrads,
    sink: SinkSpec,
) -> anyhow::Result<ScoreReport> {
    anyhow::ensure!(
        set.meta.kind == kernel.store_kind(),
        "{} scorer needs a {} store",
        kernel.name(),
        kernel.store_kind().as_str()
    );
    anyhow::ensure!(
        queries.n_layers() == set.meta.layers.len(),
        "query batch has {} layers, store has {}",
        queries.n_layers(),
        set.meta.layers.len()
    );
    let n = set.meta.n_examples;
    let nq = queries.n_query;
    let mut timer = PhaseTimer::new();
    timer.time("precondition", || kernel.precondition(&set.meta, queries))?;

    // with multiple shard workers the workers themselves overlap I/O
    // and compute, so per-shard prefetch threads would only
    // oversubscribe the cores; prefetch only on the 1-worker path
    let workers = pool::effective_threads(opts.threads).min(set.n_shards());
    let prefetch = opts.prefetch && workers <= 1;
    let kernel: &K = kernel;

    // pruning applies only to top-k passes (a full-matrix sink needs
    // every score) over stores that carry the summary sidecar, and only
    // when the kernel actually offers bounds (probed on the first
    // summary chunk, post-precondition) — otherwise the gated
    // no-prefetch cursor walk would cost I/O overlap for zero skips
    let pruner = match (sink, opts.prune.slack()) {
        (SinkSpec::TopK(_), Some(slack)) => set
            .summaries()
            .filter(|s| {
                nq > 0
                    && s.chunks
                        .first()
                        .map_or(false, |c| kernel.upper_bound(c, 0).is_some())
            })
            .map(|s| ChunkPruner { summaries: s, slack }),
        _ => None,
    };
    let pruner = pruner.as_ref();

    match sink {
        SinkSpec::Full => {
            let runs = run_shards(set, opts, prefetch, pruner, kernel, queries, |r| {
                FullMatrixSink::new(nq, r.start, r.count)
            })?;
            let peak: usize = runs.iter().map(|r| r.peak).sum();
            let mut agg = StreamStats::default();
            let parts: Vec<ShardScores> = runs
                .into_iter()
                .map(|r| {
                    agg.merge(&r.stats);
                    ShardScores {
                        start: r.sink.start,
                        scores: r.sink.scores,
                        io: r.io,
                        compute: r.compute,
                        bytes: r.stats.bytes_read,
                    }
                })
                .collect();
            let (scores, shard_timer, bytes) = parallel::merge_scores(nq, n, parts);
            debug_assert_eq!(bytes, agg.bytes_read);
            timer.merge(&shard_timer);
            Ok(ScoreReport {
                output: ScoreOutput::Full(scores),
                n_train: n,
                timer,
                bytes_read: agg.bytes_read,
                bytes_skipped: agg.bytes_skipped,
                chunks_skipped: agg.chunks_skipped,
                cache_hits: agg.cache_hits,
                cache_misses: agg.cache_misses,
                bytes_from_cache: agg.bytes_from_cache,
                peak_sink_elems: peak,
            })
        }
        SinkSpec::TopK(k) => {
            let runs = run_shards(set, opts, prefetch, pruner, kernel, queries, |_| {
                StreamingTopK::new(nq, k)
            })?;
            let mut io = Duration::ZERO;
            let mut compute = Duration::ZERO;
            let mut agg = StreamStats::default();
            let mut peak = 0usize;
            let mut shard_heaps = Vec::with_capacity(runs.len());
            for r in runs {
                io += r.io;
                compute += r.compute;
                agg.merge(&r.stats);
                peak += r.peak;
                shard_heaps.push(r.sink.heaps);
            }
            let heaps = parallel::merge_topk(nq, k, shard_heaps);
            timer.add("load", io);
            timer.add("compute", compute);
            Ok(ScoreReport {
                output: ScoreOutput::TopK(heaps),
                n_train: n,
                timer,
                bytes_read: agg.bytes_read,
                bytes_skipped: agg.bytes_skipped,
                chunks_skipped: agg.chunks_skipped,
                cache_hits: agg.cache_hits,
                cache_misses: agg.cache_misses,
                bytes_from_cache: agg.bytes_from_cache,
                peak_sink_elems: peak,
            })
        }
    }
}

/// The one worker loop: stream each shard in chunks, score, sink.  With
/// a pruner, the shard is walked on the summary grid with a skip-aware
/// cursor; a chunk is read only if some query's bound still clears its
/// heap threshold.
fn run_shards<K, S, F>(
    set: &ShardSet,
    opts: &ExecOptions,
    prefetch: bool,
    pruner: Option<&ChunkPruner<'_>>,
    kernel: &K,
    queries: &QueryGrads,
    make_sink: F,
) -> anyhow::Result<Vec<ShardRun<S>>>
where
    K: ChunkKernel,
    S: ScoreSink,
    F: Fn(&StoreReader) -> S + Sync,
{
    let nq = queries.n_query;
    // quantized-domain scoring: hand the kernel raw encoded chunks (it
    // declared it can score them) instead of paying decode + 4-byte f32
    // residency per value.  Resolved once per query; part of the cache
    // key, so decoded and encoded forms of a span never alias.
    let encoded = opts.quant.active(kernel.supports_encoded(), set.meta.codec);
    parallel::map_shards(set, opts.threads, |_, mut reader| {
        reader.prefetch_depth = opts.prefetch_depth.max(1);
        reader.encoded = encoded;
        let mut sink = make_sink(&reader);
        let mut compute = Duration::ZERO;
        let mut scratch = Scratch::new();
        let mut block = Mat::zeros(0, 0);
        let mut peak = 0usize;
        let score_one = |chunk: &Chunk,
                         sink: &mut S,
                         block: &mut Mat,
                         scratch: &mut Scratch|
         -> anyhow::Result<Duration> {
            let t0 = Instant::now();
            if block.rows != chunk.count || block.cols != nq {
                *block = Mat::zeros(chunk.count, nq);
            } else {
                block.data.iter_mut().for_each(|x| *x = 0.0);
            }
            kernel.score_chunk(chunk, queries, block, scratch)?;
            sink.consume(chunk.start, block);
            Ok(t0.elapsed())
        };
        if let Some(pr) = pruner {
            // skip-aware pass on the summary grid (no prefetch thread:
            // skip decisions depend on the heap state fed back per
            // chunk).  The skip test runs BEFORE any cache lookup, so a
            // resident chunk never changes a pruning decision and skips
            // never populate the cache.
            let mut cur = reader.chunks(pr.chunk_size())?;
            while let Some((start, count)) = cur.peek() {
                let skippable = nq > 0
                    && pr.summary_for(start, count).map_or(false, |s| {
                        (0..nq).all(|q| {
                            match (sink.threshold(q), kernel.upper_bound(s, q)) {
                                (Some(t), Some(u)) => pr.deflate(u) <= t,
                                _ => false,
                            }
                        })
                    });
                if skippable {
                    cur.skip()?;
                    continue;
                }
                let chunk = cur.read()?;
                compute += score_one(&chunk, &mut sink, &mut block, &mut scratch)?;
                peak = peak.max(sink.allocated_elems());
            }
            let stats = cur.stats().clone();
            Ok(ShardRun { sink, io: cur.io_time(), compute, stats, peak })
        } else {
            let (io, stats) = reader.stream(opts.chunk_size, prefetch, |chunk| {
                compute += score_one(chunk, &mut sink, &mut block, &mut scratch)?;
                peak = peak.max(sink.allocated_elems());
                Ok(())
            })?;
            Ok(ShardRun { sink, io, compute, stats, peak })
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_matrix_sink_places_blocks_in_shard_coordinates() {
        let mut sink = FullMatrixSink::new(2, 10, 5);
        // two blocks: global [10, 13) and [13, 15)
        let b1 = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b2 = Mat::from_vec(2, 2, vec![7.0, 8.0, 9.0, 10.0]);
        sink.consume(10, &b1);
        sink.consume(13, &b2);
        assert_eq!(sink.scores.row(0), &[1.0, 3.0, 5.0, 7.0, 9.0]);
        assert_eq!(sink.scores.row(1), &[2.0, 4.0, 6.0, 8.0, 10.0]);
        assert_eq!(sink.allocated_elems(), 10);
        // a full-matrix sink never exposes a pruning threshold
        assert_eq!(sink.threshold(0), None);
    }

    #[test]
    fn streaming_topk_sink_is_bounded() {
        let nq = 3;
        let k = 4;
        let mut sink = StreamingTopK::new(nq, k);
        let mut rng = crate::util::prng::Rng::new(7);
        let mut at = 0usize;
        let mut peak = 0usize;
        for _ in 0..20 {
            let block = Mat::random_normal(8, nq, 1.0, &mut rng);
            sink.consume(at, &block);
            at += 8;
            peak = peak.max(sink.allocated_elems());
        }
        assert!(peak <= nq * k, "peak {peak} > {}", nq * k);
        for heap in &sink.heaps {
            assert_eq!(heap.len(), k);
        }
    }

    #[test]
    fn streaming_topk_threshold_appears_when_full() {
        let mut sink = StreamingTopK::new(1, 2);
        assert_eq!(sink.threshold(0), None, "empty heap: no threshold");
        sink.consume(0, &Mat::from_vec(1, 1, vec![3.0]));
        assert_eq!(sink.threshold(0), None, "half-full heap: no threshold");
        sink.consume(1, &Mat::from_vec(1, 1, vec![1.0]));
        assert_eq!(sink.threshold(0), Some(1.0), "k-th best once full");
        sink.consume(2, &Mat::from_vec(1, 1, vec![2.0]));
        assert_eq!(sink.threshold(0), Some(2.0), "threshold rises");
    }
}

//! Shared streaming executor for all store-backed scorers.
//!
//! Every attribution method over a gradient store reduces to the same
//! shape: precondition the query batch once, then stream the store in
//! chunks and score each chunk against the preconditioned queries.
//! `ChunkKernel` captures exactly that pair of operations; `execute`
//! owns everything around it — store-kind validation, the per-shard
//! worker loop (`query::parallel::map_shards`), the prefetch heuristic,
//! chunk iteration, and the load/compute phase accounting — so a new
//! scorer is one kernel in one file, and hot-path improvements land
//! once instead of once per method.
//!
//! The kernel's output flows into a `ScoreSink`.  `FullMatrixSink`
//! materializes the classic `(n_query, n_train)` matrix (eval, LDS, and
//! the figure benches need the whole thing); `StreamingTopK` folds each
//! `(B, n_query)` block into per-query bounded heaps, so a top-k query
//! holds O(Nq·k) score elements per shard no matter how large the store
//! is — the memory model that lets the engine, server, and CLI serve
//! top-k proponents against stores far larger than RAM.
//!
//! On top of the sinks sits chunk pruning (`crate::sketch`): when the
//! store carries a v3 summary sidecar, the sink is a top-k heap, and
//! `--prune` is on, the executor visits the summary grid BEST-FIRST —
//! chunks ranked by their best query bound (`ChunkKernel::upper_bound`)
//! and walked in that order with a seeking `ChunkCursor`.  A chunk is
//! read only if some query's bound could still beat that query's
//! current k-th best (`ScoreSink::threshold`, tightened across shard
//! workers by `query::parallel::SharedThreshold`); the pass stops as
//! soon as every remaining bound is strictly below every threshold, and
//! everything unvisited is reported as `bytes_skipped`/`chunks_skipped`
//! on the `ScoreReport` (the ledger `bytes_read + bytes_skipped ==
//! full-scan bytes` always balances).  Exact mode is provably identical
//! to a full scan (see `sketch::prune`); `--prune recall=x` adds a
//! per-shard early stop once `ceil(x·k)` heap entries are provably
//! final.  On a clustered (v5) store the sinks map storage positions
//! back through the recorded permutation, so results stay in caller
//! coordinates and the best-first order is invisible except in bytes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{QueryGrads, ScoreOutput, ScoreReport, SinkSpec};
use crate::linalg::Mat;
use crate::query::parallel::{self, ShardScores, SharedThreshold, TopK};
use crate::sketch::{ChunkPruner, ChunkSummary, PruneMode};
use crate::store::{
    Chunk, QuantScore, QuantScratch, ShardSet, StoreKind, StoreMeta, StoreReader, StreamStats,
};
use crate::util::pool;
use crate::util::timer::PhaseTimer;

/// Reusable per-worker scratch buffer (e.g. for gradient reconstruction
/// on the faithful Woodbury path).  Kernels may resize it freely; the
/// executor keeps it alive across chunks so the allocation is paid once
/// per shard, not once per chunk.
pub struct Scratch {
    pub mat: Mat,
    /// decode/unpack buffers for quantized-domain scoring
    /// (`store::codec::quant`)
    pub quant: QuantScratch,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch { mat: Mat::zeros(0, 0), quant: QuantScratch::new() }
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

/// One attribution method on the streaming hot path.
///
/// `precondition` runs once per query batch (timed under the
/// "precondition" phase); `score_chunk` runs once per decoded chunk on
/// the shard workers and must ACCUMULATE (`+=`) into `out`, a zeroed
/// `(chunk.count, n_query)` block — row `b` holds the scores of
/// training example `chunk.start + b` against every query.
pub trait ChunkKernel: Sync {
    fn name(&self) -> &'static str;

    /// Store kind this kernel consumes (validated by the executor).
    fn store_kind(&self) -> StoreKind;

    /// Validate the query batch against the store and precondition the
    /// query side, stashing prepared state in `self`.
    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()>;

    /// Score one chunk against the preconditioned queries.  The chunk
    /// is decoded unless this kernel advertised `supports_encoded` and
    /// quantized-domain scoring is active for the query.
    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()>;

    /// Whether `score_chunk` can consume ENCODED chunks
    /// (`Chunk::encoded` raw record bytes) in addition to decoded ones.
    /// Kernels that return `true` here must branch on `chunk.encoded`
    /// inside `score_chunk`; the executor decides per query whether to
    /// stream encoded chunks (`ExecOptions::quant` × the store codec,
    /// see `QuantScore::active`).
    fn supports_encoded(&self) -> bool {
        false
    }

    /// SOUND upper bound on the score this kernel could produce for ANY
    /// example of a chunk with summary `s`, against query `q` — i.e.
    /// never less than any value `score_chunk` would write for that
    /// chunk.  `None` opts the kernel out of pruning (the chunk is then
    /// always read).  Called after `precondition`, only on the pruned
    /// path; kernels typically answer from a `sketch::QueryBounds` over
    /// their preconditioned query blocks.
    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        let _ = (s, q);
        None
    }

    /// Bound evaluations performed since `precondition` — kernels that
    /// answer `upper_bound` from a `sketch::QueryBounds` report its
    /// counter; the default (kernels that opt out of pruning) is 0.
    /// The executor publishes this as `lorif_prune_bound_evals_total`,
    /// so the metric reflects the evaluations that actually happened
    /// rather than a derived chunks × queries estimate.
    fn bound_evals(&self) -> u64 {
        0
    }
}

/// Where a scorer pass puts its scores.  Implementations consume
/// `(B, n_query)` blocks in stream order within a shard; one sink
/// instance exists per shard, merged by the executor afterwards.
pub trait ScoreSink: Send {
    /// Consume the score block for training examples
    /// `[start, start + block.rows)`.
    fn consume(&mut self, start: usize, block: &Mat);

    /// Score elements this sink currently holds (memory accounting; the
    /// streaming-top-k O(Nq·k) guarantee is asserted through this).
    fn allocated_elems(&self) -> usize;

    /// The current k-th best score for query `q` — the pruning
    /// threshold — or `None` when the sink still needs every score.
    /// The executor skips a chunk only when its bound is STRICTLY below
    /// this (see the exactness argument in `sketch::prune`: strictness
    /// is what keeps the skip sound under best-first visit order, where
    /// a skipped chunk may hold lower original indices than resident
    /// entries).  The default (`None`) makes pruning inert for
    /// full-matrix passes.
    fn threshold(&self, q: usize) -> Option<f32> {
        let _ = q;
        None
    }

    /// How many of this sink's entries for query `q` are provably FINAL
    /// given that every unseen score is at most `bound`: entries whose
    /// score strictly exceeds `bound` can never be displaced.  Drives
    /// the `--prune recall=x` early stop; the default (0) makes it
    /// inert for sinks without bounded entries.
    fn certified(&self, q: usize, bound: f32) -> usize {
        let _ = (q, bound);
        0
    }
}

/// Materializes this shard's `(n_query, shard_count)` column block.
pub struct FullMatrixSink {
    pub start: usize,
    pub scores: Mat,
}

impl FullMatrixSink {
    pub fn new(nq: usize, start: usize, count: usize) -> FullMatrixSink {
        FullMatrixSink { start, scores: Mat::zeros(nq, count) }
    }
}

impl ScoreSink for FullMatrixSink {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let col = start - self.start + b;
            let row = block.row(b);
            for (q, &s) in row.iter().enumerate() {
                *self.scores.at_mut(q, col) = s;
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.scores.rows * self.scores.cols
    }
}

/// Folds score blocks into per-query bounded top-k heaps: O(Nq·k)
/// memory per shard, independent of the store size.
pub struct StreamingTopK {
    pub heaps: Vec<TopK>,
    /// storage→original index map of a clustered (v5) store, shared
    /// across shard workers; `None` for identity layouts
    perm: Option<Arc<Vec<u32>>>,
}

impl StreamingTopK {
    pub fn new(nq: usize, k: usize) -> StreamingTopK {
        StreamingTopK::with_perm(nq, k, None)
    }

    /// Like `new`, but every pushed storage position is first mapped
    /// back through `perm`, so heap entries — and the (score, index)
    /// tie-breaks that decide the k-th slot — live in the caller's
    /// original coordinates regardless of the on-disk order.
    pub fn with_perm(nq: usize, k: usize, perm: Option<Arc<Vec<u32>>>) -> StreamingTopK {
        StreamingTopK { heaps: (0..nq).map(|_| TopK::new(k)).collect(), perm }
    }
}

impl ScoreSink for StreamingTopK {
    fn consume(&mut self, start: usize, block: &Mat) {
        for b in 0..block.rows {
            let row = block.row(b);
            let idx = match &self.perm {
                Some(p) => p[start + b] as usize,
                None => start + b,
            };
            for (q, heap) in self.heaps.iter_mut().enumerate() {
                heap.push(idx, row[q]);
            }
        }
    }

    fn allocated_elems(&self) -> usize {
        self.heaps.iter().map(TopK::len).sum()
    }

    fn threshold(&self, q: usize) -> Option<f32> {
        self.heaps[q].threshold()
    }

    fn certified(&self, q: usize, bound: f32) -> usize {
        // entries are sorted descending by score; everything strictly
        // above `bound` can never be displaced by an unseen example
        // (whose score is at most `bound`), under any tie-break
        self.heaps[q].entries().partition_point(|&(s, _)| s > bound)
    }
}

/// Streaming knobs shared by every store scorer.
pub struct ExecOptions {
    pub chunk_size: usize,
    pub prefetch: bool,
    /// worker threads for shard scoring (0 = all cores)
    pub threads: usize,
    /// prefetch queue depth in chunks (>= 1; `--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the store's v3 summary sidecar — inert on
    /// full-matrix passes and on stores without a sidecar
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`): stream raw encoded
    /// chunks to kernels that support them instead of decoding to f32
    pub quant: QuantScore,
}

struct ShardRun<S> {
    sink: S,
    io: Duration,
    compute: Duration,
    /// byte/chunk/cache accounting of this shard's pass
    stats: StreamStats,
    /// peak score elements the sink held during this shard's pass
    peak: usize,
}

/// Publish one completed pass into the scoped metrics registry
/// (`telemetry::current_registry`) — the aggregation point where the
/// per-pass working ledgers (`StreamStats`, the phase timer) become
/// registry counters.  Publishing the already-merged totals once per
/// pass keeps the chunk hot path free of shared counters and makes the
/// registry's `lorif_store_bytes_read_total +
/// lorif_store_bytes_skipped_total` preserve the full-scan ledger
/// bit-for-bit (property-tested in `tests/prop.rs`).
fn publish_pass(agg: &StreamStats, timer: &PhaseTimer, peak: usize, bound_evals: u64) {
    let reg = crate::telemetry::current_registry();
    agg.publish(&reg);
    crate::sketch::prune::publish_prune_outcome(
        &reg,
        bound_evals,
        agg.chunks_skipped as u64,
        agg.bytes_skipped,
    );
    reg.exec_passes.inc();
    reg.exec_load_seconds.add_secs(timer.get("load").as_secs_f64());
    reg.exec_compute_seconds.add_secs(timer.get("compute").as_secs_f64());
    reg.exec_precondition_seconds.add_secs(timer.get("precondition").as_secs_f64());
    reg.exec_peak_sink_elems.max(peak as u64);
}

/// Run `kernel` over every shard of `set`, folding scores into the
/// requested sink.  This is the single streaming scaffold behind all
/// store scorers: kind validation, preconditioning, the worker loop,
/// prefetch gating, chunk pruning, and phase-time merging live here and
/// only here.
pub fn execute<K: ChunkKernel>(
    set: &ShardSet,
    opts: &ExecOptions,
    kernel: &mut K,
    queries: &QueryGrads,
    sink: SinkSpec,
) -> anyhow::Result<ScoreReport> {
    anyhow::ensure!(
        set.meta.kind == kernel.store_kind(),
        "{} scorer needs a {} store",
        kernel.name(),
        kernel.store_kind().as_str()
    );
    anyhow::ensure!(
        queries.n_layers() == set.meta.layers.len(),
        "query batch has {} layers, store has {}",
        queries.n_layers(),
        set.meta.layers.len()
    );
    let n = set.meta.n_examples;
    let nq = queries.n_query;
    // seed the cache residency gauges into the scoped registry up front:
    // a configured but still-cold cache must scrape with its real
    // capacity, not wait for the first insert to publish it
    if let Some(cache) = set.cache() {
        cache.publish_gauges(&crate::telemetry::current_registry());
    }
    let mut timer = PhaseTimer::new();
    timer.time("precondition", || {
        let _sp = crate::telemetry::trace::span("precondition");
        kernel.precondition(&set.meta, queries)
    })?;

    // with multiple shard workers the workers themselves overlap I/O
    // and compute, so per-shard prefetch threads would only
    // oversubscribe the cores; prefetch only on the 1-worker path
    let workers = pool::effective_threads(opts.threads).min(set.n_shards());
    let prefetch = opts.prefetch && workers <= 1;
    let kernel: &K = kernel;

    // pruning applies only to top-k passes (a full-matrix sink needs
    // every score) over stores that carry the summary sidecar, and only
    // when the kernel actually offers bounds (probed on the first
    // summary chunk, post-precondition) — otherwise the gated
    // no-prefetch cursor walk would cost I/O overlap for zero skips
    let pruner = match (sink, opts.prune.slack()) {
        (SinkSpec::TopK(_), Some(slack)) => set
            .summaries()
            .filter(|s| {
                nq > 0
                    && s.chunks
                        .first()
                        .map_or(false, |c| kernel.upper_bound(c, 0).is_some())
            })
            .map(|s| ChunkPruner { summaries: s, slack }),
        _ => None,
    };
    let pruner = pruner.as_ref();

    match sink {
        SinkSpec::Full => {
            let runs = run_shards(set, opts, prefetch, pruner, None, None, kernel, queries, |r| {
                FullMatrixSink::new(nq, r.start, r.count)
            })?;
            let peak: usize = runs.iter().map(|r| r.peak).sum();
            // read back from the kernel's own counter (incremented inside
            // `upper_bound`) so the published metric cannot diverge from
            // the evaluations that actually ran; 0 here — a full-matrix
            // sink never prunes
            let bound_evals = kernel.bound_evals();
            let mut agg = StreamStats::default();
            let parts: Vec<ShardScores> = runs
                .into_iter()
                .map(|r| {
                    agg.merge(&r.stats);
                    ShardScores {
                        start: r.sink.start,
                        scores: r.sink.scores,
                        io: r.io,
                        compute: r.compute,
                        bytes: r.stats.bytes_read,
                    }
                })
                .collect();
            let (scores, shard_timer, bytes) = parallel::merge_scores(nq, n, parts);
            debug_assert_eq!(bytes, agg.bytes_read);
            // clustered (v5) store: the merged matrix is in storage
            // order; put columns back in the caller's original
            // coordinates so the reordering stays invisible
            let scores = match set.cluster() {
                Some(c) => {
                    let mut out = Mat::zeros(nq, n);
                    for q in 0..nq {
                        let src = scores.row(q);
                        for (storage, &orig) in c.perm.iter().enumerate() {
                            *out.at_mut(q, orig as usize) = src[storage];
                        }
                    }
                    out
                }
                None => scores,
            };
            timer.merge(&shard_timer);
            publish_pass(&agg, &timer, peak, bound_evals);
            Ok(ScoreReport {
                output: ScoreOutput::Full(scores),
                n_train: n,
                timer,
                bytes_read: agg.bytes_read,
                bytes_skipped: agg.bytes_skipped,
                chunks_skipped: agg.chunks_skipped,
                cache_hits: agg.cache_hits,
                cache_misses: agg.cache_misses,
                bytes_from_cache: agg.bytes_from_cache,
                peak_sink_elems: peak,
            })
        }
        SinkSpec::TopK(k) => {
            // clustered (v5) store: sinks push ORIGINAL indices, so the
            // (score, index) tie-break — and hence the top-k — matches
            // an unclustered scan bit for bit
            let perm: Option<Arc<Vec<u32>>> = set.cluster().map(|c| Arc::new(c.perm.clone()));
            // cross-worker threshold exchange: each worker publishes its
            // k-th best after every scored chunk, every worker skips
            // against max(local, shared)
            let shared = SharedThreshold::new(nq);
            // `--prune recall=x` early-stop target: entries that must be
            // provably final per query before a shard may stop
            let need = opts
                .prune
                .recall()
                .map(|x| (x * k.min(n.max(1)) as f32).ceil().max(1.0) as usize);
            let runs = run_shards(set, opts, prefetch, pruner, Some(&shared), need, kernel, queries, |_| {
                StreamingTopK::with_perm(nq, k, perm.clone())
            })?;
            let mut io = Duration::ZERO;
            let mut compute = Duration::ZERO;
            let mut agg = StreamStats::default();
            let mut peak = 0usize;
            let mut shard_heaps = Vec::with_capacity(runs.len());
            for r in runs {
                io += r.io;
                compute += r.compute;
                agg.merge(&r.stats);
                peak += r.peak;
                shard_heaps.push(r.sink.heaps);
            }
            // the kernel's `QueryBounds` counter is the single source of
            // truth for bound evaluations: it covers the eligibility
            // probe above plus every per-(chunk, query) bound the shard
            // workers computed while building their visit orders
            let bound_evals = kernel.bound_evals();
            let heaps = parallel::merge_topk(nq, k, shard_heaps);
            timer.add("load", io);
            timer.add("compute", compute);
            publish_pass(&agg, &timer, peak, bound_evals);
            Ok(ScoreReport {
                output: ScoreOutput::TopK(heaps),
                n_train: n,
                timer,
                bytes_read: agg.bytes_read,
                bytes_skipped: agg.bytes_skipped,
                chunks_skipped: agg.chunks_skipped,
                cache_hits: agg.cache_hits,
                cache_misses: agg.cache_misses,
                bytes_from_cache: agg.bytes_from_cache,
                peak_sink_elems: peak,
            })
        }
    }
}

/// The one worker loop: stream each shard in chunks, score, sink.  With
/// a pruner, the shard is walked on the summary grid BEST-FIRST — in
/// descending order of each chunk's best query bound, with a seeking
/// cursor — so the heap thresholds tighten as fast as the bounds allow
/// and the weak tail is skipped (or, under a recall target, not visited
/// at all) instead of being streamed past.
#[allow(clippy::too_many_arguments)]
fn run_shards<K, S, F>(
    set: &ShardSet,
    opts: &ExecOptions,
    prefetch: bool,
    pruner: Option<&ChunkPruner<'_>>,
    shared: Option<&SharedThreshold>,
    need: Option<usize>,
    kernel: &K,
    queries: &QueryGrads,
    make_sink: F,
) -> anyhow::Result<Vec<ShardRun<S>>>
where
    K: ChunkKernel,
    S: ScoreSink,
    F: Fn(&StoreReader) -> S + Sync,
{
    let nq = queries.n_query;
    // quantized-domain scoring: hand the kernel raw encoded chunks (it
    // declared it can score them) instead of paying decode + 4-byte f32
    // residency per value.  Resolved once per query; part of the cache
    // key, so decoded and encoded forms of a span never alias.
    let encoded = opts.quant.active(kernel.supports_encoded(), set.meta.codec);
    parallel::map_shards(set, opts.threads, |si, mut reader| {
        reader.prefetch_depth = opts.prefetch_depth.max(1);
        reader.encoded = encoded;
        // trace lane 1 + shard: this shard's chunk visits render on
        // their own Perfetto track within the query's track group
        let lane = si as u32 + 1;
        let mut shard_span = crate::telemetry::trace::span_on("shard", lane);
        if let Some(s) = shard_span.as_mut() {
            s.arg("shard", si);
            s.arg("start", reader.start);
            s.arg("count", reader.count);
        }
        let mut sink = make_sink(&reader);
        let mut compute = Duration::ZERO;
        let mut scratch = Scratch::new();
        let mut block = Mat::zeros(0, 0);
        let mut peak = 0usize;
        let score_one = |chunk: &Chunk,
                         sink: &mut S,
                         block: &mut Mat,
                         scratch: &mut Scratch|
         -> anyhow::Result<Duration> {
            let t0 = Instant::now();
            let mut sp = crate::telemetry::trace::span_on("score_chunk", lane);
            if let Some(s) = sp.as_mut() {
                s.arg("start", chunk.start);
                s.arg("count", chunk.count);
            }
            if block.rows != chunk.count || block.cols != nq {
                *block = Mat::zeros(chunk.count, nq);
            } else {
                block.data.iter_mut().for_each(|x| *x = 0.0);
            }
            kernel.score_chunk(chunk, queries, block, scratch)?;
            sink.consume(chunk.start, block);
            Ok(t0.elapsed())
        };
        if let Some(pr) = pruner {
            // best-first pass on the summary grid (no prefetch thread:
            // the visit order is data-driven and skip decisions depend
            // on heap state fed back per chunk).  The skip test runs
            // BEFORE any cache lookup, so a resident chunk never
            // changes a pruning decision and skips never populate the
            // cache.
            let mut cur = reader.chunks(pr.chunk_size())?;
            let (lo, hi) = (reader.start, reader.start + reader.count);
            // this shard's summary chunks — the grid tiles every shard
            // exactly (StoreSummaries::validate ran at open), so the
            // sidecar IS the chunk list
            let chunks: Vec<&ChunkSummary> = pr
                .summaries
                .chunks
                .iter()
                .filter(|s| s.start >= lo && s.start < hi)
                .collect();
            // per (chunk, query) bounds, +inf where the kernel offers
            // none (such chunks sort first and are always read)
            let bounds: Vec<Vec<f32>> = chunks
                .iter()
                .map(|s| {
                    (0..nq)
                        .map(|q| kernel.upper_bound(s, q).unwrap_or(f32::INFINITY))
                        .collect()
                })
                .collect();
            // visit order: descending best-over-queries bound under
            // total_cmp (NaN ranks above +inf, so non-finite chunks
            // lead), ties toward the lower start for determinism
            let best = |b: &[f32]| {
                b.iter().copied().max_by(f32::total_cmp).unwrap_or(f32::INFINITY)
            };
            let mut order: Vec<usize> = (0..chunks.len()).collect();
            order.sort_by(|&a, &b| {
                best(&bounds[b])
                    .total_cmp(&best(&bounds[a]))
                    .then(chunks[a].start.cmp(&chunks[b].start))
            });
            // rem[i][q]: best bound any chunk in order[i..] still holds
            // for query q — the ceiling on every unseen score once the
            // first i chunks of the order are dealt with
            let mut rem = vec![vec![f32::NEG_INFINITY; nq]; order.len() + 1];
            for i in (0..order.len()).rev() {
                for q in 0..nq {
                    let u = bounds[order[i]][q];
                    let prev = rem[i + 1][q];
                    rem[i][q] = if u.total_cmp(&prev).is_gt() { u } else { prev };
                }
            }
            // skip threshold: the shard's own k-th best, tightened by
            // the best k-th best any worker has published (sound for
            // the MERGED output: a score below another shard's k-th
            // best is below the merged k-th best a fortiori)
            let thr = |q: usize, sink: &S| -> Option<f32> {
                match (sink.threshold(q), shared.and_then(|s| s.get(q))) {
                    (Some(a), Some(b)) => Some(a.max(b)),
                    (a, None) => a,
                    (None, b) => b,
                }
            };
            for (i, &ci) in order.iter().enumerate() {
                // exact bulk stop: every query's best remaining bound is
                // strictly below its threshold — nothing unvisited can
                // enter any heap.  recall stop: every query already
                // holds `need` entries no unvisited chunk can displace.
                let done = (0..nq).all(|q| match thr(q, &sink) {
                    Some(t) => pr.deflate(rem[i][q]) < t,
                    None => false,
                }) || need.map_or(false, |need| {
                    (0..nq).all(|q| sink.certified(q, rem[i][q]) >= need)
                });
                if done {
                    crate::telemetry::trace::instant_on(
                        "prune_stop",
                        lane,
                        &[("chunks_left", (order.len() - i).to_string())],
                    );
                    for &cj in &order[i..] {
                        cur.account_skip(chunks[cj].count);
                    }
                    break;
                }
                // per-chunk test, STRICT (`<`): under best-first order a
                // skipped chunk may hold lower original indices than
                // resident entries, so only strict inferiority is sound
                // (see sketch::prune)
                let skip = (0..nq).all(|q| match thr(q, &sink) {
                    Some(t) => pr.deflate(bounds[ci][q]) < t,
                    None => false,
                });
                if skip {
                    crate::telemetry::trace::instant_on(
                        "prune_skip",
                        lane,
                        &[("start", chunks[ci].start.to_string())],
                    );
                    cur.account_skip(chunks[ci].count);
                    continue;
                }
                cur.goto(chunks[ci].start)?;
                let chunk = {
                    let hits0 = cur.stats().cache_hits;
                    let mut sp = crate::telemetry::trace::span_on("read_chunk", lane);
                    let chunk = cur.read()?;
                    if let Some(s) = sp.as_mut() {
                        s.arg("start", chunk.start);
                        s.arg("cache_hit", u8::from(cur.stats().cache_hits > hits0));
                    }
                    chunk
                };
                compute += score_one(&chunk, &mut sink, &mut block, &mut scratch)?;
                peak = peak.max(sink.allocated_elems());
                if let Some(sh) = shared {
                    for q in 0..nq {
                        if let Some(t) = sink.threshold(q) {
                            sh.publish(q, t);
                        }
                    }
                }
            }
            let stats = cur.stats().clone();
            Ok(ShardRun { sink, io: cur.io_time(), compute, stats, peak })
        } else {
            let (io, stats) = reader.stream(opts.chunk_size, prefetch, |chunk| {
                compute += score_one(chunk, &mut sink, &mut block, &mut scratch)?;
                peak = peak.max(sink.allocated_elems());
                Ok(())
            })?;
            Ok(ShardRun { sink, io, compute, stats, peak })
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_matrix_sink_places_blocks_in_shard_coordinates() {
        let mut sink = FullMatrixSink::new(2, 10, 5);
        // two blocks: global [10, 13) and [13, 15)
        let b1 = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b2 = Mat::from_vec(2, 2, vec![7.0, 8.0, 9.0, 10.0]);
        sink.consume(10, &b1);
        sink.consume(13, &b2);
        assert_eq!(sink.scores.row(0), &[1.0, 3.0, 5.0, 7.0, 9.0]);
        assert_eq!(sink.scores.row(1), &[2.0, 4.0, 6.0, 8.0, 10.0]);
        assert_eq!(sink.allocated_elems(), 10);
        // a full-matrix sink never exposes a pruning threshold
        assert_eq!(sink.threshold(0), None);
    }

    #[test]
    fn streaming_topk_sink_is_bounded() {
        let nq = 3;
        let k = 4;
        let mut sink = StreamingTopK::new(nq, k);
        let mut rng = crate::util::prng::Rng::new(7);
        let mut at = 0usize;
        let mut peak = 0usize;
        for _ in 0..20 {
            let block = Mat::random_normal(8, nq, 1.0, &mut rng);
            sink.consume(at, &block);
            at += 8;
            peak = peak.max(sink.allocated_elems());
        }
        assert!(peak <= nq * k, "peak {peak} > {}", nq * k);
        for heap in &sink.heaps {
            assert_eq!(heap.len(), k);
        }
    }

    #[test]
    fn streaming_topk_maps_storage_positions_through_the_permutation() {
        // clustered layout [2, 0, 3, 1]: storage position p holds the
        // example originally indexed perm[p]
        let perm = Arc::new(vec![2u32, 0, 3, 1]);
        let mut sink = StreamingTopK::with_perm(1, 4, Some(perm));
        sink.consume(0, &Mat::from_vec(4, 1, vec![4.0, 3.0, 2.0, 1.0]));
        assert_eq!(
            sink.heaps[0].entries(),
            &[(4.0, 2), (3.0, 0), (2.0, 3), (1.0, 1)],
            "entries carry original coordinates"
        );
    }

    #[test]
    fn certified_counts_only_strictly_dominating_entries() {
        let mut sink = StreamingTopK::new(1, 3);
        sink.consume(0, &Mat::from_vec(3, 1, vec![5.0, 3.0, 1.0]));
        assert_eq!(sink.certified(0, 0.5), 3);
        assert_eq!(sink.certified(0, 1.0), 2, "a tied entry is displaceable");
        assert_eq!(sink.certified(0, 3.0), 1);
        assert_eq!(sink.certified(0, 9.0), 0);
        // full-matrix sinks never certify anything
        let full = FullMatrixSink::new(1, 0, 3);
        assert_eq!(full.certified(0, -1.0), 0);
    }

    #[test]
    fn streaming_topk_threshold_appears_when_full() {
        let mut sink = StreamingTopK::new(1, 2);
        assert_eq!(sink.threshold(0), None, "empty heap: no threshold");
        sink.consume(0, &Mat::from_vec(1, 1, vec![3.0]));
        assert_eq!(sink.threshold(0), None, "half-full heap: no threshold");
        sink.consume(1, &Mat::from_vec(1, 1, vec![1.0]));
        assert_eq!(sink.threshold(0), Some(1.0), "k-th best once full");
        sink.consume(2, &Mat::from_vec(1, 1, vec![2.0]));
        assert_eq!(sink.threshold(0), Some(2.0), "threshold rises");
    }
}

//! Ablation scorers for Table 8 ("Separating the Two Low-Rank
//! Components"):
//!
//!  * `DenseWoodburyScorer`  — "LoRIF w/o rank factorization": dense
//!    projected gradients scored with the truncated-SVD + Woodbury
//!    curvature.  Isolates the curvature approximation (should track
//!    LoGRA closely for adequate r).
//!  * `FactoredDenseKScorer` — "LoRIF w/o truncated SVD": rank-c factors
//!    scored against the dense Cholesky curvature (requires O(D^2)
//!    memory — trips the same OOM guard as LoGRA at large D).  Isolates
//!    the factorization error.
//!
//! Both ride the shared streaming executor (`attribution::exec`), so
//! they score shards on the worker pool and support the streaming
//! top-k sink exactly like the headline methods.

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::curvature::{reconstruct_row, DenseCurvature, TruncatedCurvature};
use crate::linalg::{matmul_nt_acc, Mat};
use crate::sketch::PruneMode;
use crate::store::{
    Chunk, ChunkLayer, QuantScore, ShardSet, StoreKind, StoreMeta, DEFAULT_PREFETCH_DEPTH,
};

pub struct DenseWoodburyScorer {
    pub shards: ShardSet,
    pub curv: TruncatedCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// accepted for interface parity; the ablation kernels keep the
    /// default `upper_bound` opt-out, so chunks are never skipped
    pub prune: PruneMode,
}

impl DenseWoodburyScorer {
    pub fn new(shards: ShardSet, curv: TruncatedCurvature) -> Self {
        DenseWoodburyScorer {
            shards,
            curv,
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
        }
    }
}

/// Dense gradients against the Woodbury-form truncated curvature.
struct DenseWoodburyKernel<'a> {
    curv: &'a TruncatedCurvature,
    /// per layer (Nq, r): query projections with Woodbury weights folded
    gqw: Vec<Mat>,
}

impl ChunkKernel for DenseWoodburyKernel<'_> {
    fn name(&self) -> &'static str {
        "lorif-no-fact"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, _meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        self.gqw = (0..queries.n_layers())
            .map(|l| {
                let mut proj = queries.layers[l].g.matmul(&self.curv.layers[l].v);
                for row in 0..proj.rows {
                    for (x, w) in proj.row_mut(row).iter_mut().zip(&self.curv.weights[l]) {
                        *x *= w;
                    }
                }
                proj
            })
            .collect();
        Ok(())
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        _scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        for l in 0..queries.n_layers() {
            let g = match &chunk.layers[l] {
                ChunkLayer::Dense { g } => g,
                _ => anyhow::bail!("expected dense chunk"),
            };
            let inv_lambda = 1.0 / self.curv.lambdas[l];
            let proj = g.matmul(&self.curv.layers[l].v); // (B, r)
            // both Eq.-(9) terms accumulate straight into `out`
            matmul_nt_acc(out, g, &queries.layers[l].g, inv_lambda);
            matmul_nt_acc(out, &proj, &self.gqw[l], -1.0);
        }
        Ok(())
    }
}

impl Scorer for DenseWoodburyScorer {
    fn name(&self) -> &'static str {
        "lorif-no-fact"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = DenseWoodburyKernel { curv: &self.curv, gqw: Vec::new() };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            // ablation kernels keep the default supports_encoded opt-out
            quant: QuantScore::Off,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

pub struct FactoredDenseKScorer {
    pub shards: ShardSet,
    pub curv: DenseCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// accepted for interface parity; this kernel keeps the default
    /// `upper_bound` opt-out, so chunks are never skipped
    pub prune: PruneMode,
}

impl FactoredDenseKScorer {
    pub fn new(shards: ShardSet, curv: DenseCurvature) -> Self {
        FactoredDenseKScorer {
            shards,
            curv,
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
        }
    }
}

/// Rank-c factors reconstructed per chunk against the dense Cholesky
/// curvature.
struct FactoredDenseKKernel<'a> {
    curv: &'a DenseCurvature,
    layer_dims: Vec<(usize, usize)>,
    c: usize,
    /// per layer (Nq, D): K^{-1} g_q
    pre: Vec<Mat>,
}

impl ChunkKernel for FactoredDenseKKernel<'_> {
    fn name(&self) -> &'static str {
        "lorif-no-svd"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Factored
    }

    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        self.layer_dims = meta.layers.clone();
        self.c = meta.c;
        self.pre = (0..queries.n_layers())
            .map(|l| self.curv.chols[l].solve_rows(&queries.layers[l].g))
            .collect();
        Ok(())
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        let nq = out.cols;
        for l in 0..queries.n_layers() {
            let (d1, d2) = self.layer_dims[l];
            let (u, v) = match &chunk.layers[l] {
                ChunkLayer::Factored { u, v } => (u, v),
                _ => anyhow::bail!("expected factored chunk"),
            };
            let rec = &mut scratch.mat;
            if rec.rows != 1 || rec.cols != d1 * d2 {
                *rec = Mat::zeros(1, d1 * d2);
            }
            for nn in 0..chunk.count {
                reconstruct_row(u.row(nn), v.row(nn), d1, d2, self.c, rec.row_mut(0));
                for q in 0..nq {
                    let s = crate::linalg::mat::dot(rec.row(0), self.pre[l].row(q));
                    *out.at_mut(nn, q) += s;
                }
            }
        }
        Ok(())
    }
}

impl Scorer for FactoredDenseKScorer {
    fn name(&self) -> &'static str {
        "lorif-no-svd"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = FactoredDenseKKernel {
            curv: &self.curv,
            layer_dims: Vec::new(),
            c: 0,
            pre: Vec::new(),
        };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            // ablation kernels keep the default supports_encoded opt-out
            quant: QuantScore::Off,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::logra::LograScorer;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn dense_woodbury_tracks_logra_at_full_rank() {
        // with r ~= min(N, D) the Woodbury route must equal the dense
        // Cholesky route (the algebraic identity behind §3.2)
        let fx = make_fixture(20, 2, &[(4, 4)], 1, StoreKind::Dense, "abl_full_rank");
        let set = crate::store::ShardSet::open(&fx.base).unwrap();
        let tsvd = TruncatedCurvature::build(&set, 15, 5, 4, 0.1, 0).unwrap();
        let lambda_t = tsvd.lambdas[0];
        let mut a = DenseWoodburyScorer::new(crate::store::ShardSet::open(&fx.base).unwrap(), tsvd);
        let ra = a.score(&fx.queries).unwrap();

        // dense reference with the SAME lambda
        let dense =
            DenseCurvature::build(&crate::store::ShardSet::open(&fx.base).unwrap(), 0.1).unwrap();
        // rebuild with matched lambda: reconstruct Gram from store
        let chunk = crate::store::ShardSet::open(&fx.base).unwrap().read_range(0, 20).unwrap();
        let g = chunk.layers[0].dense().clone();
        let mut gram = g.matmul_tn(&g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda_t;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            let kq = ch.solve(fx.queries.layers[0].g.row(q));
            for t in 0..20 {
                let want: f32 = g.row(t).iter().zip(&kq).map(|(a, b)| a * b).sum();
                let got = ra.scores().at(q, t);
                assert!(
                    (got - want).abs() < 0.03 * scale + 1e-4,
                    "q{q} t{t}: {got} vs {want}"
                );
            }
        }
        let _ = dense; // silence: dense built only to assert it CAN build
    }

    #[test]
    fn factored_dense_k_matches_direct_formula() {
        // internal consistency: the scorer must equal the direct formula
        // reconstruct(u_t v_t^T) . K^{-1} g_q computed from the SAME
        // stored (bf16) factors.  Cross-method agreement (vs LoGRA) is
        // data-dependent — the damped-GN inverse amplifies whatever the
        // factorization drops — and is *measured* by the Table 8 bench,
        // not asserted here.
        let fx = make_fixture(25, 2, &[(5, 6)], 2, StoreKind::Factored, "abl_fdk");
        let curv =
            DenseCurvature::build(&crate::store::ShardSet::open(&fx.base).unwrap(), 0.1).unwrap();
        let lambda = curv.lambdas[0];
        let mut fdk = FactoredDenseKScorer::new(crate::store::ShardSet::open(&fx.base).unwrap(), curv);
        fdk.chunk_size = 7;
        let ra = fdk.score(&fx.queries).unwrap();

        // direct reference from the stored factors
        let set = crate::store::ShardSet::open(&fx.base).unwrap();
        let chunk = set.read_range(0, 25).unwrap();
        let (u, v) = chunk.layers[0].factors();
        let mut g = Mat::zeros(25, 30);
        for t in 0..25 {
            reconstruct_row(u.row(t), v.row(t), 5, 6, 2, g.row_mut(t));
        }
        let mut gram = g.matmul_tn(&g);
        // NB: the scorer's K came from the same factored store, so the
        // Gram matches; rebuild with the scorer's lambda
        for i in 0..30 {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for q in 0..2 {
            let kq = ch.solve(fx.queries.layers[0].g.row(q));
            for t in 0..25 {
                let want: f32 = g.row(t).iter().zip(&kq).map(|(a, b)| a * b).sum();
                let got = ra.scores().at(q, t);
                assert!((got - want).abs() < 0.01 * scale + 1e-4, "{got} vs {want}");
            }
        }
        let _ = LograScorer::new; // keep the import meaningful
    }

    #[test]
    fn ablation_scorers_support_streaming_topk() {
        // ablations ride the same executor, so the streaming sink must
        // agree with the full argsort for both of them
        let fx = make_fixture(16, 2, &[(4, 4)], 1, StoreKind::Dense, "abl_sink_dw");
        let set = crate::store::ShardSet::open(&fx.base).unwrap();
        let tsvd = TruncatedCurvature::build(&set, 8, 5, 3, 0.1, 0).unwrap();
        let mut dw = DenseWoodburyScorer::new(crate::store::ShardSet::open(&fx.base).unwrap(), tsvd);
        dw.chunk_size = 5;
        let full = dw.score(&fx.queries).unwrap();
        let streamed = dw.score_sink(&fx.queries, SinkSpec::TopK(3)).unwrap();
        assert_eq!(streamed.topk(3), full.topk(3));

        let fx2 = make_fixture(16, 2, &[(4, 4)], 1, StoreKind::Factored, "abl_sink_fdk");
        let curv =
            DenseCurvature::build(&crate::store::ShardSet::open(&fx2.base).unwrap(), 0.1).unwrap();
        let mut fdk =
            FactoredDenseKScorer::new(crate::store::ShardSet::open(&fx2.base).unwrap(), curv);
        fdk.chunk_size = 5;
        let full = fdk.score(&fx2.queries).unwrap();
        let streamed = fdk.score_sink(&fx2.queries, SinkSpec::TopK(3)).unwrap();
        assert_eq!(streamed.topk(3), full.topk(3));
    }
}

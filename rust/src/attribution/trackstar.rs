//! TrackStar baseline (Chang et al. 2024): dense projected gradients with
//! curvature preconditioning plus unit normalization.
//!
//! TrackStar's headline changes over LoGRA are a second-moment curvature
//! estimate and *unit-norm correction* of gradients.  We implement the
//! normalization faithfully — score = <K^{-1} g_q, g_t / ||g_t||> with
//! the query side also normalized — on top of the same damped GN
//! curvature; the full per-example K^{-1}-norm would need one solve per
//! training example and is noted as a divergence in DESIGN.md.
//!
//! The train-side norm is purely chunk-local (every layer of an example
//! sits in the same store record), so the whole method is one
//! `ChunkKernel`: the shared executor in `attribution::exec` streams it,
//! and the normalized blocks feed either sink unchanged — the
//! normalization happens *before* top-k selection, as it must.

use std::sync::Arc;

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::curvature::DenseCurvature;
use crate::linalg::{matmul_nt_acc, sumsq, Mat};
use crate::sketch::{ChunkSummary, PruneMode, QueryBounds};
use crate::store::codec::quant;
use crate::store::{
    Chunk, ChunkLayer, QuantPlan, QuantScore, ShardSet, StoreKind, StoreMeta,
    DEFAULT_PREFETCH_DEPTH,
};

pub struct TrackStarScorer {
    /// `Arc`-shared so a pool of serving workers can score against one
    /// opened store (and one decoded-chunk cache)
    pub shards: Arc<ShardSet>,
    pub curv: Arc<DenseCurvature>,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the summary sidecar (`--prune`)
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`)
    pub quant: QuantScore,
}

impl TrackStarScorer {
    pub fn new(
        shards: impl Into<Arc<ShardSet>>,
        curv: impl Into<Arc<DenseCurvature>>,
    ) -> TrackStarScorer {
        TrackStarScorer {
            shards: shards.into(),
            curv: curv.into(),
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
            quant: QuantScore::Auto,
        }
    }
}

/// The TrackStar `ChunkKernel`: preconditioned + query-normalized dots,
/// divided by the train-side gradient norm within the chunk.
struct TrackStarKernel<'a> {
    curv: &'a DenseCurvature,
    /// per layer (Nq, D) `K⁻¹ g_q` blocks, unit-normalized per query,
    /// stored once inside the bound state.  The bound over them covers
    /// the NUMERATOR of the TrackStar score; `upper_bound` divides by
    /// the chunk's record-norm window.
    bounds: Option<QueryBounds>,
    /// encoded-segment addressing for quantized-domain scoring
    plan: Option<QuantPlan>,
}

impl ChunkKernel for TrackStarKernel<'_> {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        let pre: Vec<Mat> = (0..queries.n_layers())
            .map(|l| {
                let mut p = self.curv.chols[l].solve_rows(&queries.layers[l].g);
                for q in 0..p.rows {
                    let row = p.row_mut(q);
                    let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
                    for x in row.iter_mut() {
                        *x /= norm;
                    }
                }
                p
            })
            .collect();
        self.bounds = Some(QueryBounds::new(pre));
        self.plan = Some(QuantPlan::dense(meta)?);
        Ok(())
    }

    fn supports_encoded(&self) -> bool {
        true
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        _queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        let pre = &self.bounds.as_ref().expect("precondition ran").blocks;
        // per-example squared norms across all layers, for the
        // train-side unit normalization
        let mut norms2 = vec![0.0f32; chunk.count];
        if let Some(raw) = &chunk.encoded {
            // quantized-domain path: numerator dots AND the record
            // norm² both fold the group scales out of the integer codes
            let plan = self.plan.as_ref().expect("precondition builds the quant plan");
            for (l, pre_l) in pre.iter().enumerate() {
                for (ex, n2) in norms2.iter_mut().enumerate() {
                    let (seg, n) = plan.seg(raw, ex, l);
                    quant::accum_row_scores(
                        plan.codec(),
                        seg,
                        n,
                        pre_l,
                        out.row_mut(ex),
                        &mut scratch.quant,
                    );
                    *n2 += quant::seg_norm2(plan.codec(), seg, n, &mut scratch.quant);
                }
            }
        } else {
            for (l, pre_l) in pre.iter().enumerate() {
                let g = match &chunk.layers[l] {
                    ChunkLayer::Dense { g } => g,
                    _ => anyhow::bail!("expected dense chunk"),
                };
                matmul_nt_acc(out, g, pre_l, 1.0);
                for (nn, n2) in norms2.iter_mut().enumerate() {
                    *n2 += sumsq(g.row(nn));
                }
            }
        }
        for nn in 0..chunk.count {
            let inv = 1.0 / norms2[nn].sqrt().max(1e-12);
            for x in out.row_mut(nn) {
                *x *= inv;
            }
        }
        Ok(())
    }

    /// score = ⟨g_t, pre_q⟩ / ‖g_t‖.  Bound the numerator `U` with the
    /// linear machinery, then divide by the end of the chunk's record
    /// norm window that maximizes the quotient: the (deflated) min norm
    /// when `U > 0`, the (inflated) max norm when `U <= 0` — both sides
    /// carry their safety margins from the summarizer, so the result
    /// stays an upper bound in f32.
    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        let u = self.bounds.as_ref()?.upper_bound(s, q);
        if u.is_nan() {
            return Some(u);
        }
        Some(if u > 0.0 {
            u / s.min_norm.max(1e-12)
        } else {
            u / s.max_norm.max(1e-12)
        })
    }

    fn bound_evals(&self) -> u64 {
        self.bounds.as_ref().map_or(0, |b| b.evals())
    }
}

impl Scorer for TrackStarScorer {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = TrackStarKernel { curv: self.curv.as_ref(), bounds: None, plan: None };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            quant: self.quant,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn scores_are_scale_invariant_on_train_side() {
        // scaling a training gradient must not change its TrackStar score
        // (unit normalization) — verify via the formula on the fixture
        let fx = make_fixture(12, 1, &[(4, 4)], 1, StoreKind::Dense, "trackstar");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        let report = scorer.score(&fx.queries).unwrap();
        // direct check: score = <pre_q, g_t>/||g_t||
        let g = &fx.train_g[0];
        let lambda = scorer.curv.lambdas[0];
        let mut gram = g.matmul_tn(g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let mut kq = ch.solve(fx.queries.layers[0].g.row(0));
        let qn = kq.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in kq.iter_mut() {
            *x /= qn;
        }
        for t in 0..12 {
            let gt = g.row(t);
            let norm = gt.iter().map(|x| x * x).sum::<f32>().sqrt();
            let want: f32 = gt.iter().zip(&kq).map(|(a, b)| a * b).sum::<f32>() / norm;
            let got = report.scores().at(0, t);
            assert!((got - want).abs() < 0.1 * want.abs().max(0.05), "{got} vs {want}");
        }
    }

    #[test]
    fn streaming_topk_sees_normalized_scores() {
        // the unit normalization changes the ranking, so it must happen
        // inside the kernel, before either sink — the streamed top-k has
        // to match the full-matrix argsort exactly
        let fx = make_fixture(18, 2, &[(4, 4), (3, 3)], 1, StoreKind::Dense, "trackstar_sink");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        scorer.chunk_size = 5;
        let full = scorer.score(&fx.queries).unwrap();
        let streamed = scorer.score_sink(&fx.queries, SinkSpec::TopK(6)).unwrap();
        assert_eq!(streamed.topk(6), full.topk(6));
        assert!(streamed.peak_sink_elems <= 2 * 6);
    }

    #[test]
    fn pruning_respects_the_unit_normalization() {
        // TrackStar is scale-invariant on the train side, so magnitude
        // clustering alone cannot justify a skip — DIRECTION must.  The
        // first chunk is aligned with the query, later chunks are
        // anti-aligned; their normalized scores are near -1 and the
        // bound (numerator / record-norm window) proves it.
        use crate::attribution::QueryLayer;
        use crate::runtime::{ExtractBatch, LayerGrads};
        use crate::store::{StoreMeta, StoreWriter};
        use crate::util::prng::Rng;

        let dir = std::env::temp_dir().join("lorif_attr_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("trackstar_prune");
        let (n, d, chunk) = (40usize, 16usize, 8usize);
        let mut rng = Rng::new(47);
        let mut g = Mat::zeros(n, d);
        for t in 0..n {
            let sign = if t < chunk { 1.0 } else { -1.0 };
            // magnitude varies per CHUNK (scale-invariance: it must not
            // matter) while direction stays coherent within a chunk
            let scale = 0.5 + (t / chunk) as f32;
            for x in g.row_mut(t) {
                *x = sign * scale * (1.0 + 0.02 * rng.normal() as f32);
            }
        }
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(4, 4)],
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let mut w = StoreWriter::create(&base, meta).unwrap();
        w.set_summary_chunk(chunk).unwrap();
        w.append(&ExtractBatch {
            losses: vec![0.0; n],
            layers: vec![LayerGrads {
                g: g.clone(),
                u: Mat::zeros(n, 4),
                v: Mat::zeros(n, 4),
            }],
            valid: n,
        })
        .unwrap();
        w.finalize().unwrap();

        let queries = crate::attribution::QueryGrads {
            n_query: 1,
            c: 1,
            proj_dims: vec![(4, 4)],
            layers: vec![QueryLayer {
                g: Mat::from_vec(1, d, vec![1.0; d]),
                u: Mat::zeros(1, 4),
                v: Mat::zeros(1, 4),
            }],
        };

        let set = ShardSet::open(&base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&base).unwrap(), curv);
        let full = scorer.score(&queries).unwrap();
        let pruned = scorer.score_sink(&queries, SinkSpec::TopK(3)).unwrap();
        assert_eq!(pruned.topk(3), full.topk(3));
        assert!(pruned.chunks_skipped >= 1, "anti-aligned chunks should be skipped");
        assert_eq!(pruned.bytes_read + pruned.bytes_skipped, full.bytes_read);
    }
}

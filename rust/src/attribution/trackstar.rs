//! TrackStar baseline (Chang et al. 2024): dense projected gradients with
//! curvature preconditioning plus unit normalization.
//!
//! TrackStar's headline changes over LoGRA are a second-moment curvature
//! estimate and *unit-norm correction* of gradients.  We implement the
//! normalization faithfully — score = <K^{-1} g_q, g_t / ||g_t||> with
//! the query side also normalized — on top of the same damped GN
//! curvature; the full per-example K^{-1}-norm would need one solve per
//! training example and is noted as a divergence in DESIGN.md.
//!
//! The train-side norm is purely chunk-local (every layer of an example
//! sits in the same store record), so the whole method is one
//! `ChunkKernel`: the shared executor in `attribution::exec` streams it,
//! and the normalized blocks feed either sink unchanged — the
//! normalization happens *before* top-k selection, as it must.

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::curvature::DenseCurvature;
use crate::linalg::Mat;
use crate::store::{Chunk, ChunkLayer, ShardSet, StoreKind, StoreMeta};

pub struct TrackStarScorer {
    pub shards: ShardSet,
    pub curv: DenseCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
}

impl TrackStarScorer {
    pub fn new(shards: ShardSet, curv: DenseCurvature) -> TrackStarScorer {
        TrackStarScorer { shards, curv, prefetch: true, chunk_size: 512, score_threads: 0 }
    }
}

/// The TrackStar `ChunkKernel`: preconditioned + query-normalized dots,
/// divided by the train-side gradient norm within the chunk.
struct TrackStarKernel<'a> {
    curv: &'a DenseCurvature,
    /// per layer (Nq, D): K^{-1} g_q, unit-normalized per query
    pre: Vec<Mat>,
}

impl ChunkKernel for TrackStarKernel<'_> {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Dense
    }

    fn precondition(&mut self, _meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        self.pre = (0..queries.n_layers())
            .map(|l| {
                let mut p = self.curv.chols[l].solve_rows(&queries.layers[l].g);
                for q in 0..p.rows {
                    let row = p.row_mut(q);
                    let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
                    for x in row.iter_mut() {
                        *x /= norm;
                    }
                }
                p
            })
            .collect();
        Ok(())
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        _queries: &QueryGrads,
        out: &mut Mat,
        _scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        // per-example squared norms across all layers, for the
        // train-side unit normalization
        let mut norms2 = vec![0.0f32; chunk.count];
        for (l, pre_l) in self.pre.iter().enumerate() {
            let g = match &chunk.layers[l] {
                ChunkLayer::Dense { g } => g,
                _ => anyhow::bail!("expected dense chunk"),
            };
            let part = g.matmul_nt(pre_l); // (B, Nq)
            for (o, p) in out.data.iter_mut().zip(&part.data) {
                *o += p;
            }
            for (nn, n2) in norms2.iter_mut().enumerate() {
                *n2 += g.row(nn).iter().map(|x| x * x).sum::<f32>();
            }
        }
        for nn in 0..chunk.count {
            let inv = 1.0 / norms2[nn].sqrt().max(1e-12);
            for x in out.row_mut(nn) {
                *x *= inv;
            }
        }
        Ok(())
    }
}

impl Scorer for TrackStarScorer {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = TrackStarKernel { curv: &self.curv, pre: Vec::new() };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn scores_are_scale_invariant_on_train_side() {
        // scaling a training gradient must not change its TrackStar score
        // (unit normalization) — verify via the formula on the fixture
        let fx = make_fixture(12, 1, &[(4, 4)], 1, StoreKind::Dense, "trackstar");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        let report = scorer.score(&fx.queries).unwrap();
        // direct check: score = <pre_q, g_t>/||g_t||
        let g = &fx.train_g[0];
        let lambda = scorer.curv.lambdas[0];
        let mut gram = g.matmul_tn(g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let mut kq = ch.solve(fx.queries.layers[0].g.row(0));
        let qn = kq.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in kq.iter_mut() {
            *x /= qn;
        }
        for t in 0..12 {
            let gt = g.row(t);
            let norm = gt.iter().map(|x| x * x).sum::<f32>().sqrt();
            let want: f32 = gt.iter().zip(&kq).map(|(a, b)| a * b).sum::<f32>() / norm;
            let got = report.scores().at(0, t);
            assert!((got - want).abs() < 0.1 * want.abs().max(0.05), "{got} vs {want}");
        }
    }

    #[test]
    fn streaming_topk_sees_normalized_scores() {
        // the unit normalization changes the ranking, so it must happen
        // inside the kernel, before either sink — the streamed top-k has
        // to match the full-matrix argsort exactly
        let fx = make_fixture(18, 2, &[(4, 4), (3, 3)], 1, StoreKind::Dense, "trackstar_sink");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        scorer.chunk_size = 5;
        let full = scorer.score(&fx.queries).unwrap();
        let streamed = scorer.score_sink(&fx.queries, SinkSpec::TopK(6)).unwrap();
        assert_eq!(streamed.topk(6), full.topk(6));
        assert!(streamed.peak_sink_elems <= 2 * 6);
    }
}

//! TrackStar baseline (Chang et al. 2024): dense projected gradients with
//! curvature preconditioning plus unit normalization.
//!
//! TrackStar's headline changes over LoGRA are a second-moment curvature
//! estimate and *unit-norm correction* of gradients.  We implement the
//! normalization faithfully — score = <K^{-1} g_q, g_t / ||g_t||> with
//! the query side also normalized — on top of the same damped GN
//! curvature; the full per-example K^{-1}-norm would need one solve per
//! training example and is noted as a divergence in DESIGN.md.

use super::{QueryGrads, ScoreReport, Scorer};
use crate::curvature::DenseCurvature;
use crate::linalg::Mat;
use crate::store::{ChunkLayer, StoreKind, StoreReader};
use crate::util::timer::PhaseTimer;

pub struct TrackStarScorer {
    pub reader: StoreReader,
    pub curv: DenseCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
}

impl TrackStarScorer {
    pub fn new(reader: StoreReader, curv: DenseCurvature) -> TrackStarScorer {
        TrackStarScorer { reader, curv, prefetch: true, chunk_size: 512 }
    }
}

impl Scorer for TrackStarScorer {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn index_bytes(&self) -> u64 {
        self.reader.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(
            self.reader.meta.kind == StoreKind::Dense,
            "TrackStar scorer needs a dense store"
        );
        let n = self.reader.meta.n_examples;
        let nq = queries.n_query;
        let n_layers = queries.n_layers();
        let mut timer = PhaseTimer::new();

        // precondition + normalize query side
        let pre: Vec<Mat> = timer.time("precondition", || {
            (0..n_layers)
                .map(|l| {
                    let mut p = self.curv.chols[l].solve_rows(&queries.layers[l].g);
                    for q in 0..p.rows {
                        let row = p.row_mut(q);
                        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
                        for x in row.iter_mut() {
                            *x /= norm;
                        }
                    }
                    p
                })
                .collect()
        });

        let mut scores = Mat::zeros(nq, n);
        // accumulate per-example squared norms across all layers for the
        // train-side unit normalization
        let mut norms2 = vec![0.0f32; n];
        let mut partial = Mat::zeros(nq, n);
        let mut compute = std::time::Duration::ZERO;
        let (io_time, bytes) = self.reader.stream(self.chunk_size, self.prefetch, |chunk| {
            let t0 = std::time::Instant::now();
            for l in 0..n_layers {
                let g = match &chunk.layers[l] {
                    ChunkLayer::Dense { g } => g,
                    _ => anyhow::bail!("expected dense chunk"),
                };
                let part = g.matmul_nt(&pre[l]); // (B, Nq)
                for nn in 0..chunk.count {
                    let global = chunk.start + nn;
                    let row = part.row(nn);
                    for q in 0..nq {
                        *partial.at_mut(q, global) += row[q];
                    }
                    norms2[global] += g.row(nn).iter().map(|x| x * x).sum::<f32>();
                }
            }
            compute += t0.elapsed();
            Ok(())
        })?;
        // final normalization by the train-side gradient norm
        for q in 0..nq {
            for t in 0..n {
                *scores.at_mut(q, t) = partial.at(q, t) / norms2[t].sqrt().max(1e-12);
            }
        }
        timer.add("load", io_time);
        timer.add("compute", compute);
        Ok(ScoreReport { scores, timer, bytes_read: bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn scores_are_scale_invariant_on_train_side() {
        // scaling a training gradient must not change its TrackStar score
        // (unit normalization) — verify via the formula on the fixture
        let fx = make_fixture(12, 1, &[(4, 4)], 1, StoreKind::Dense, "trackstar");
        let reader = StoreReader::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&reader, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(StoreReader::open(&fx.base).unwrap(), curv);
        let report = scorer.score(&fx.queries).unwrap();
        // direct check: score = <pre_q, g_t>/||g_t||
        let g = &fx.train_g[0];
        let lambda = scorer.curv.lambdas[0];
        let mut gram = g.matmul_tn(g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let mut kq = ch.solve(fx.queries.layers[0].g.row(0));
        let qn = kq.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in kq.iter_mut() {
            *x /= qn;
        }
        for t in 0..12 {
            let gt = g.row(t);
            let norm = gt.iter().map(|x| x * x).sum::<f32>().sqrt();
            let want: f32 = gt.iter().zip(&kq).map(|(a, b)| a * b).sum::<f32>() / norm;
            let got = report.scores.at(0, t);
            assert!((got - want).abs() < 0.1 * want.abs().max(0.05), "{got} vs {want}");
        }
    }
}

//! TrackStar baseline (Chang et al. 2024): dense projected gradients with
//! curvature preconditioning plus unit normalization.
//!
//! TrackStar's headline changes over LoGRA are a second-moment curvature
//! estimate and *unit-norm correction* of gradients.  We implement the
//! normalization faithfully — score = <K^{-1} g_q, g_t / ||g_t||> with
//! the query side also normalized — on top of the same damped GN
//! curvature; the full per-example K^{-1}-norm would need one solve per
//! training example and is noted as a divergence in DESIGN.md.
//!
//! The streaming pass runs per shard on the worker pool; each shard also
//! returns its slice of the train-side squared norms, merged before the
//! final normalization.

use super::{QueryGrads, ScoreReport, Scorer};
use crate::curvature::DenseCurvature;
use crate::linalg::Mat;
use crate::query::parallel::{self, ShardScores};
use crate::store::{ChunkLayer, ShardSet, StoreKind};
use crate::util::timer::PhaseTimer;

pub struct TrackStarScorer {
    pub shards: ShardSet,
    pub curv: DenseCurvature,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
}

impl TrackStarScorer {
    pub fn new(shards: ShardSet, curv: DenseCurvature) -> TrackStarScorer {
        TrackStarScorer { shards, curv, prefetch: true, chunk_size: 512, score_threads: 0 }
    }
}

impl Scorer for TrackStarScorer {
    fn name(&self) -> &'static str {
        "trackstar"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(
            self.shards.meta.kind == StoreKind::Dense,
            "TrackStar scorer needs a dense store"
        );
        let n = self.shards.meta.n_examples;
        let nq = queries.n_query;
        let n_layers = queries.n_layers();
        let mut timer = PhaseTimer::new();

        // precondition + normalize query side
        let pre: Vec<Mat> = timer.time("precondition", || {
            (0..n_layers)
                .map(|l| {
                    let mut p = self.curv.chols[l].solve_rows(&queries.layers[l].g);
                    for q in 0..p.rows {
                        let row = p.row_mut(q);
                        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
                        for x in row.iter_mut() {
                            *x /= norm;
                        }
                    }
                    p
                })
                .collect()
        });

        let chunk_size = self.chunk_size;
        // with multiple shard workers the workers themselves overlap I/O
        // and compute, so per-shard prefetch threads would only
        // oversubscribe the cores; prefetch only on the 1-worker path
        let workers =
            crate::util::pool::effective_threads(self.score_threads).min(self.shards.n_shards());
        let prefetch = self.prefetch && workers <= 1;
        let parts = parallel::map_shards(&self.shards, self.score_threads, |_, reader| {
            let shard_start = reader.start;
            let mut local = Mat::zeros(nq, reader.count);
            // per-example squared norms across all layers, for the
            // train-side unit normalization
            let mut norms2 = vec![0.0f32; reader.count];
            let mut compute = std::time::Duration::ZERO;
            let (io, bytes) = reader.stream(chunk_size, prefetch, |chunk| {
                let t0 = std::time::Instant::now();
                for (l, pre_l) in pre.iter().enumerate() {
                    let g = match &chunk.layers[l] {
                        ChunkLayer::Dense { g } => g,
                        _ => anyhow::bail!("expected dense chunk"),
                    };
                    let part = g.matmul_nt(pre_l); // (B, Nq)
                    for nn in 0..chunk.count {
                        let col = chunk.start - shard_start + nn;
                        let row = part.row(nn);
                        for q in 0..nq {
                            *local.at_mut(q, col) += row[q];
                        }
                        norms2[col] += g.row(nn).iter().map(|x| x * x).sum::<f32>();
                    }
                }
                compute += t0.elapsed();
                Ok(())
            })?;
            Ok((
                ShardScores { start: shard_start, scores: local, io, compute, bytes },
                norms2,
            ))
        })?;

        let mut norms2 = vec![0.0f32; n];
        let mut score_parts = Vec::with_capacity(parts.len());
        for (p, local_norms) in parts {
            norms2[p.start..p.start + local_norms.len()].copy_from_slice(&local_norms);
            score_parts.push(p);
        }
        let (partial, shard_timer, bytes) = parallel::merge_scores(nq, n, score_parts);
        timer.merge(&shard_timer);

        // final normalization by the train-side gradient norm
        let mut scores = Mat::zeros(nq, n);
        for q in 0..nq {
            for t in 0..n {
                *scores.at_mut(q, t) = partial.at(q, t) / norms2[t].sqrt().max(1e-12);
            }
        }
        Ok(ScoreReport { scores, timer, bytes_read: bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::make_fixture;

    #[test]
    fn scores_are_scale_invariant_on_train_side() {
        // scaling a training gradient must not change its TrackStar score
        // (unit normalization) — verify via the formula on the fixture
        let fx = make_fixture(12, 1, &[(4, 4)], 1, StoreKind::Dense, "trackstar");
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        let mut scorer = TrackStarScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        let report = scorer.score(&fx.queries).unwrap();
        // direct check: score = <pre_q, g_t>/||g_t||
        let g = &fx.train_g[0];
        let lambda = scorer.curv.lambdas[0];
        let mut gram = g.matmul_tn(g);
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let ch = crate::linalg::Chol::factor(&gram).unwrap();
        let mut kq = ch.solve(fx.queries.layers[0].g.row(0));
        let qn = kq.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in kq.iter_mut() {
            *x /= qn;
        }
        for t in 0..12 {
            let gt = g.row(t);
            let norm = gt.iter().map(|x| x * x).sum::<f32>().sqrt();
            let want: f32 = gt.iter().zip(&kq).map(|(a, b)| a * b).sum::<f32>() / norm;
            let got = report.scores.at(0, t);
            assert!((got - want).abs() < 0.1 * want.abs().max(0.05), "{got} vs {want}");
        }
    }
}

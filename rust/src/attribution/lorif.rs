//! LoRIF scorer — the paper's method (Eq. 9) on the streaming hot path.
//!
//! Per layer, per store chunk:
//!   1. factor dots: S1[n, q] = <u_q v_q^T, u_n v_n^T>_F computed from
//!      the (c x c) inner-product blocks — O(c^2 (d1+d2)) per pair;
//!   2. Woodbury correction: project train gradients into the r-dim
//!      subspace (faithful mode reconstructs + GEMMs with V_r at query
//!      time, exactly like the paper; cached mode reuses the stage-2
//!      train projections) and subtract `sum_i w_i g'_q,i g'_n,i`;
//!   3. scores[q, n] += S1/lambda_l - corr.
//!
//! All heavy steps are GEMMs on the chunk — the compute half of Fig 3.
//! The streaming pass itself (shard workers, prefetch gating, chunk
//! iteration, sinks) is the shared executor in `attribution::exec`;
//! this file only supplies the LoRIF `ChunkKernel`.

use std::sync::Arc;

use super::exec::{self, ChunkKernel, ExecOptions, Scratch};
use super::{QueryGrads, ScoreReport, Scorer, SinkSpec};
use crate::curvature::{reconstruct_row, TruncatedCurvature};
use crate::linalg::{matmul_nt_acc, Mat};
use crate::sketch::{ChunkSummary, PruneMode, QueryBounds};
use crate::store::{
    Chunk, ChunkLayer, QuantScore, ShardSet, StoreKind, StoreMeta, DEFAULT_PREFETCH_DEPTH,
};

pub struct LorifScorer {
    /// `Arc`-shared so a pool of serving workers can score against one
    /// opened store (and one decoded-chunk cache)
    pub shards: Arc<ShardSet>,
    pub curv: Arc<TruncatedCurvature>,
    /// use stage-2 train projections instead of query-time projection
    /// (extension; the paper recomputes at query time)
    pub cached_projections: bool,
    pub prefetch: bool,
    pub chunk_size: usize,
    /// worker threads for shard scoring (0 = all cores)
    pub score_threads: usize,
    /// prefetch queue depth in chunks (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// chunk pruning against the summary sidecar (`--prune`); only the
    /// faithful (non-cached) projection path prunes — see the kernel
    pub prune: PruneMode,
    /// quantized-domain scoring (`--quant-score`).  Factored records
    /// interleave u/v segments, so the LoRIF kernel scores encoded
    /// chunks by decoding them in-kernel — same math bit-for-bit, but
    /// the shared chunk cache holds the 2–4× denser ENCODED bytes.
    pub quant: QuantScore,
}

impl LorifScorer {
    pub fn new(
        shards: impl Into<Arc<ShardSet>>,
        curv: impl Into<Arc<TruncatedCurvature>>,
    ) -> LorifScorer {
        LorifScorer {
            shards: shards.into(),
            curv: curv.into(),
            cached_projections: false,
            prefetch: true,
            chunk_size: 512,
            score_threads: 0,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            prune: PruneMode::Exact,
            quant: QuantScore::Auto,
        }
    }
}

/// Batched factor dot: S1[n, q] = sum_{k,l} (u_q^T u_n)[k,l] (v_q^T v_n)[k,l].
///
/// u_chunk (B, d1*c) row-major-(d1, c) per row; uq (Nq, d1*c) likewise.
/// Implemented as two GEMMs over "factor-column expanded" matrices:
/// rows (n, l) x cols (q, k), then a (c x c)-block reduction.
pub fn factor_dots(
    u_chunk: &Mat,
    v_chunk: &Mat,
    uq: &Mat,
    vq: &Mat,
    d1: usize,
    d2: usize,
    c: usize,
) -> Mat {
    let b = u_chunk.rows;
    let nq = uq.rows;
    if c == 1 {
        // fast path: S1 = (U u_q^T) .* (V v_q^T), two plain GEMMs
        let a = u_chunk.matmul_nt(uq); // (B, Nq)
        let bb = v_chunk.matmul_nt(vq); // (B, Nq)
        let mut s = a;
        for (x, y) in s.data.iter_mut().zip(&bb.data) {
            *x *= y;
        }
        return s;
    }
    // general c: expand rows to (B*c) x d1 with row (n, l) = u_n[:, l]
    let expand = |m: &Mat, d: usize| -> Mat {
        let mut out = Mat::zeros(m.rows * c, d);
        for n in 0..m.rows {
            let row = m.row(n); // (d, c) row-major
            for l in 0..c {
                let dst = out.row_mut(n * c + l);
                for a in 0..d {
                    dst[a] = row[a * c + l];
                }
            }
        }
        out
    };
    let u2 = expand(u_chunk, d1); // (B*c, d1)
    let uq2 = expand(uq, d1); // (Nq*c, d1)
    let v2 = expand(v_chunk, d2);
    let vq2 = expand(vq, d2);
    let a2 = u2.matmul_nt(&uq2); // (B*c, Nq*c): [(n,l),(q,k)]
    let b2 = v2.matmul_nt(&vq2);
    let mut s = Mat::zeros(b, nq);
    for n in 0..b {
        for l in 0..c {
            let arow = a2.row(n * c + l);
            let brow = b2.row(n * c + l);
            for q in 0..nq {
                let mut acc = 0.0f32;
                for k in 0..c {
                    acc += arow[q * c + k] * brow[q * c + k];
                }
                *s.at_mut(n, q) += acc;
            }
        }
    }
    s
}

/// The LoRIF `ChunkKernel`: Eq. (9) per chunk, preconditioned queries
/// held in `gqw`.
struct LorifKernel<'a> {
    curv: &'a TruncatedCurvature,
    /// reuse stage-2 train projections instead of query-time projection
    cached: bool,
    layer_dims: Vec<(usize, usize)>,
    c: usize,
    /// per layer (Nq, r): g'_q = V_r^T g~_q with Woodbury weights folded
    gqw: Vec<Mat>,
    /// Pruning-bound state over the EFFECTIVE query vectors
    /// `y_q = g~_q/λ − V_r ĝ'_q`: both Eq. (9) terms are linear in the
    /// reconstructed train gradient, so score = ⟨g~_t, y_q⟩ and the
    /// factored summaries (which bound exactly that reconstruction)
    /// apply.  `None` in cached mode — the stage-2 projections are a
    /// different train representation, so the bound would not be
    /// provably sound there and the kernel opts out of pruning.
    bounds: Option<QueryBounds>,
    /// store meta for in-kernel decode of encoded chunks
    meta: Option<StoreMeta>,
}

impl ChunkKernel for LorifKernel<'_> {
    fn name(&self) -> &'static str {
        "lorif"
    }

    fn store_kind(&self) -> StoreKind {
        StoreKind::Factored
    }

    fn precondition(&mut self, meta: &StoreMeta, queries: &QueryGrads) -> anyhow::Result<()> {
        anyhow::ensure!(queries.proj_dims == meta.layers, "layer dims mismatch");
        anyhow::ensure!(queries.c == meta.c, "factor rank mismatch");
        self.layer_dims = meta.layers.clone();
        self.c = meta.c;
        self.meta = Some(meta.clone());
        let (c, nq) = (self.c, queries.n_query);

        // precondition queries: g'_q = V_r^T g~_q, folded with Woodbury
        // weights -> gqw (per layer: (Nq, r)).
        //
        // CONSISTENCY NOTE: g~_q is the *factor-reconstructed* query
        // gradient, not the exact one.  Both terms of Eq. (9) must see
        // the same query representation: the factor-dot term only
        // carries the rank-c part of g_q, so projecting the exact g_q
        // into the curvature subspace over-subtracts the dominant
        // directions and anti-correlates the scores (see the component
        // diagnosis in EXPERIMENTS.md §Debugging).
        let mut gqw = Vec::with_capacity(queries.n_layers());
        let mut bound_blocks = Vec::with_capacity(queries.n_layers());
        for l in 0..queries.n_layers() {
            let (d1, d2) = self.layer_dims[l];
            let svd = &self.curv.layers[l];
            let ql = &queries.layers[l];
            let mut rec = Mat::zeros(nq, d1 * d2);
            for q in 0..nq {
                reconstruct_row(ql.u.row(q), ql.v.row(q), d1, d2, c, rec.row_mut(q));
            }
            let mut proj = rec.matmul(&svd.v); // (Nq, r)
            let w = &self.curv.weights[l];
            for row in 0..proj.rows {
                let r = proj.row_mut(row);
                for (x, wi) in r.iter_mut().zip(w) {
                    *x *= wi;
                }
            }
            if !self.cached {
                // effective query vector for the pruning bound:
                // score = ⟨g~_t, g~_q⟩/λ − ⟨V_rᵀ g~_t, ĝ'_q⟩
                //       = ⟨g~_t, g~_q/λ − V_r ĝ'_q⟩
                let mut y = rec;
                y.scale(1.0 / self.curv.lambdas[l]);
                let back = proj.matmul_nt(&svd.v); // (Nq, D)
                for (a, b) in y.data.iter_mut().zip(&back.data) {
                    *a -= b;
                }
                bound_blocks.push(y);
            }
            gqw.push(proj);
        }
        self.gqw = gqw;
        self.bounds = (!self.cached).then(|| QueryBounds::new(bound_blocks));
        Ok(())
    }

    fn supports_encoded(&self) -> bool {
        true
    }

    fn score_chunk(
        &self,
        chunk: &Chunk,
        queries: &QueryGrads,
        out: &mut Mat,
        scratch: &mut Scratch,
    ) -> anyhow::Result<()> {
        // encoded chunks arrive when `--quant-score on` pins the shared
        // cache to the denser encoded form; the factored u/v interleave
        // has no segment-linear score, so decode here — the SAME decode
        // the reader would have run, hence bit-identical scores
        let decoded;
        let chunk = if let Some(raw) = &chunk.encoded {
            let meta = self.meta.as_ref().expect("precondition stashes the meta");
            decoded = crate::store::reader::decode_chunk(meta, chunk.start, raw)?;
            &decoded
        } else {
            chunk
        };
        let c = self.c;
        for l in 0..queries.n_layers() {
            let (d1, d2) = self.layer_dims[l];
            let (u, v) = match &chunk.layers[l] {
                ChunkLayer::Factored { u, v } => (u, v),
                _ => anyhow::bail!("expected factored chunk"),
            };
            let ql = &queries.layers[l];
            // term 1: factor dots / lambda
            let s1 = factor_dots(u, v, &ql.u, &ql.v, d1, d2, c);
            let inv_lambda = 1.0 / self.curv.lambdas[l];
            // term 2: Woodbury correction
            let gt: Mat = if self.cached {
                let idx: Vec<usize> = (chunk.start..chunk.start + chunk.count).collect();
                self.curv.layers[l].train_proj.select_rows(&idx)
            } else {
                // faithful: reconstruct rows and project at query time
                let rec = &mut scratch.mat;
                if rec.rows != chunk.count || rec.cols != d1 * d2 {
                    *rec = Mat::zeros(chunk.count, d1 * d2);
                }
                for ex in 0..chunk.count {
                    reconstruct_row(u.row(ex), v.row(ex), d1, d2, c, rec.row_mut(ex));
                }
                rec.matmul(&self.curv.layers[l].v) // (B, r)
            };
            for (o, &a) in out.data.iter_mut().zip(&s1.data) {
                *o += a * inv_lambda;
            }
            // Woodbury correction folded straight into `out` — no
            // per-chunk (B, Nq) `corr` temporary
            matmul_nt_acc(out, &gt, &self.gqw[l], -1.0);
        }
        Ok(())
    }

    fn upper_bound(&self, s: &ChunkSummary, q: usize) -> Option<f32> {
        self.bounds.as_ref().map(|b| b.upper_bound(s, q))
    }

    fn bound_evals(&self) -> u64 {
        self.bounds.as_ref().map_or(0, |b| b.evals())
    }
}

impl Scorer for LorifScorer {
    fn name(&self) -> &'static str {
        "lorif"
    }

    fn index_bytes(&self) -> u64 {
        self.shards.meta.total_bytes()
    }

    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        self.score_sink(queries, SinkSpec::Full)
    }

    fn score_sink(&mut self, queries: &QueryGrads, sink: SinkSpec) -> anyhow::Result<ScoreReport> {
        let mut kernel = LorifKernel {
            curv: self.curv.as_ref(),
            cached: self.cached_projections,
            layer_dims: Vec::new(),
            c: 0,
            gqw: Vec::new(),
            bounds: None,
            meta: None,
        };
        let opts = ExecOptions {
            chunk_size: self.chunk_size,
            prefetch: self.prefetch,
            threads: self.score_threads,
            prefetch_depth: self.prefetch_depth,
            prune: self.prune,
            quant: self.quant,
        };
        exec::execute(&self.shards, &opts, &mut kernel, queries, sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::testutil::{make_fixture, make_fixture_sharded};
    use crate::store::StoreKind;

    fn build_scorer(
        name: &str,
        r: usize,
        cached: bool,
    ) -> (LorifScorer, crate::attribution::testutil::Fixture) {
        let fx = make_fixture(40, 3, &[(6, 8), (5, 5)], 2, StoreKind::Factored, name);
        let set = ShardSet::open(&fx.base).unwrap();
        let curv = TruncatedCurvature::build(&set, r, 8, 3, 0.1, 0).unwrap();
        let mut s = LorifScorer::new(ShardSet::open(&fx.base).unwrap(), curv);
        s.cached_projections = cached;
        s.chunk_size = 13;
        (s, fx)
    }

    /// Dense reference for Eq. (9) with the same truncated curvature.
    fn dense_reference(
        fx: &crate::attribution::testutil::Fixture,
        curv: &TruncatedCurvature,
        c: usize,
    ) -> Mat {
        let nq = fx.queries.n_query;
        let n = fx.train_g[0].rows;
        let mut scores = Mat::zeros(nq, n);
        for l in 0..fx.layer_dims.len() {
            let (d1, d2) = fx.layer_dims[l];
            let lambda = curv.lambdas[l];
            let w = &curv.weights[l];
            for q in 0..nq {
                // reconstruct query from ITS factors (the scorer never
                // sees the exact query gradient on the factor-dot path)
                let uq = fx.queries.layers[l].u.row(q);
                let vq = fx.queries.layers[l].v.row(q);
                let mut gq = vec![0.0f32; d1 * d2];
                reconstruct_row(uq, vq, d1, d2, c, &mut gq);
                let gq_r = curv.layers[l].v.matvec_t(&gq);
                for t in 0..n {
                    let ut = |ex: usize| -> Vec<f32> {
                        let mut g = vec![0.0f32; d1 * d2];
                        // train side: reconstruct from factors (bf16-free
                        // here; the store adds bf16 noise)
                        let gm = Mat::from_vec(d1, d2, fx.train_g[l].row(ex).to_vec());
                        let (u, v) = crate::grads::factorize::poweriter(&gm, c, 16);
                        reconstruct_row(&u.data, &v.data, d1, d2, c, &mut g);
                        g
                    };
                    let gt = ut(t);
                    let dot: f32 = gq.iter().zip(&gt).map(|(a, b)| a * b).sum();
                    let gt_r = curv.layers[l].v.matvec_t(&gt);
                    let corr: f32 = (0..w.len()).map(|i| w[i] * gq_r[i] * gt_r[i]).sum();
                    *scores.at_mut(q, t) += dot / lambda - corr;
                }
            }
        }
        scores
    }

    #[test]
    fn matches_dense_reference() {
        let (mut scorer, fx) = build_scorer("lorif_ref", 12, false);
        let report = scorer.score(&fx.queries).unwrap();
        let want = dense_reference(&fx, &scorer.curv, 2);
        let scale = want.data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in report.scores().data.iter().zip(&want.data) {
            assert!((a - b).abs() < 0.05 * scale + 1e-4, "{a} vs {b} (scale {scale})");
        }
    }

    #[test]
    fn cached_projections_close_to_faithful() {
        let (mut s1, fx) = build_scorer("lorif_cached_a", 12, false);
        let (mut s2, _) = build_scorer("lorif_cached_a", 12, true);
        let r1 = s1.score(&fx.queries).unwrap();
        let r2 = s2.score(&fx.queries).unwrap();
        let scale = r1.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in r1.scores().data.iter().zip(&r2.scores().data) {
            // cached projections come from the rSVD of the *bf16* store,
            // faithful from query-time reconstruction: close but not equal
            assert!((a - b).abs() < 0.1 * scale + 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sharded_store_matches_monolithic() {
        // same records, one store monolithic and one in 4 shards scored
        // on 3 workers: Eq. (9) scores must agree to float round-off
        let (mut mono, fx) = build_scorer("lorif_shard_mono", 10, false);
        let sharded_fx = make_fixture_sharded(
            40,
            3,
            &[(6, 8), (5, 5)],
            2,
            StoreKind::Factored,
            4,
            "lorif_shard_split",
        );
        let set = ShardSet::open(&sharded_fx.base).unwrap();
        assert_eq!(set.n_shards(), 4);
        let curv = TruncatedCurvature::build(
            &ShardSet::open(&fx.base).unwrap(),
            10,
            8,
            3,
            0.1,
            0,
        )
        .unwrap();
        let mut sharded = LorifScorer::new(set, curv);
        sharded.chunk_size = 13;
        sharded.score_threads = 3;
        let ra = mono.score(&fx.queries).unwrap();
        let rb = sharded.score(&fx.queries).unwrap();
        let scale = ra.scores().data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (a, b) in ra.scores().data.iter().zip(&rb.scores().data) {
            assert!((a - b).abs() <= 1e-5 * scale.max(1.0), "{a} vs {b}");
        }
        assert_eq!(rb.scores().rows, 3);
        assert_eq!(rb.scores().cols, 40);
        assert!(rb.bytes_read == ra.bytes_read, "same records, same bytes");

        // streaming top-k sink over the sharded store: identical top-k
        // indices to the full-matrix argsort, without the (Nq, N) matrix
        let rt = sharded.score_sink(&fx.queries, SinkSpec::TopK(7)).unwrap();
        assert_eq!(rt.topk(7), rb.topk(7));
        assert!(
            rt.peak_sink_elems <= 3 * 7 * 4,
            "streaming sink held {} score elements (> Nq*k*shards)",
            rt.peak_sink_elems
        );
        assert!(rb.peak_sink_elems >= 3 * 40, "full sink materializes Nq*N");
    }

    #[test]
    fn factor_dots_c1_matches_general() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(5);
        let (b, nq, d1, d2) = (7, 3, 5, 6);
        let u = Mat::random_normal(b, d1, 1.0, &mut rng);
        let v = Mat::random_normal(b, d2, 1.0, &mut rng);
        let uq = Mat::random_normal(nq, d1, 1.0, &mut rng);
        let vq = Mat::random_normal(nq, d2, 1.0, &mut rng);
        let fast = factor_dots(&u, &v, &uq, &vq, d1, d2, 1);
        // brute force
        for n in 0..b {
            for q in 0..nq {
                let du: f32 = u.row(n).iter().zip(uq.row(q)).map(|(a, b)| a * b).sum();
                let dv: f32 = v.row(n).iter().zip(vq.row(q)).map(|(a, b)| a * b).sum();
                assert!((fast.at(n, q) - du * dv).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn factored_pruning_skips_weak_chunks_exactly() {
        use crate::runtime::{ExtractBatch, LayerGrads};
        use crate::store::{StoreMeta, StoreWriter};
        use crate::util::prng::Rng;

        // factored store, rank-1: the first summary chunk holds strong
        // factors aligned with the query, later chunks hold eps-scaled
        // factors whose reconstructed Frobenius norm (bounded via the
        // factor Grams, never materialized at write time) proves them
        // unreachable
        let dir = std::env::temp_dir().join("lorif_attr_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("lorif_prune");
        let (n, d1, d2, chunk) = (48usize, 5usize, 6usize, 8usize);
        let mut rng = Rng::new(53);
        let mut u = Mat::zeros(n, d1);
        let mut v = Mat::zeros(n, d2);
        let mut g = Mat::zeros(n, d1 * d2);
        for t in 0..n {
            let scale = if t < chunk { 2.0 } else { 0.01 };
            for x in u.row_mut(t) {
                *x = scale * (1.0 + 0.05 * rng.normal() as f32);
            }
            for x in v.row_mut(t) {
                *x = 1.0 + 0.05 * rng.normal() as f32;
            }
            crate::curvature::reconstruct_row(u.row(t), v.row(t), d1, d2, 1, g.row_mut(t));
        }
        let meta = StoreMeta {
            kind: StoreKind::Factored,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(d1, d2)],
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let mut w = StoreWriter::create(&base, meta).unwrap();
        w.set_summary_chunk(chunk).unwrap();
        w.append(&ExtractBatch {
            losses: vec![0.0; n],
            layers: vec![LayerGrads { g, u: u.clone(), v: v.clone() }],
            valid: n,
        })
        .unwrap();
        w.finalize().unwrap();

        // queries = the first two strong examples (positive self-influence)
        let queries = crate::attribution::QueryGrads {
            n_query: 2,
            c: 1,
            proj_dims: vec![(d1, d2)],
            layers: vec![crate::attribution::QueryLayer {
                g: Mat::zeros(2, d1 * d2),
                u: u.select_rows(&[0, 1]),
                v: v.select_rows(&[0, 1]),
            }],
        };

        let set = ShardSet::open(&base).unwrap();
        let curv = TruncatedCurvature::build(&set, 6, 6, 3, 0.1, 0).unwrap();
        let mut scorer = LorifScorer::new(ShardSet::open(&base).unwrap(), curv);
        let full = scorer.score(&queries).unwrap();
        let pruned = scorer.score_sink(&queries, SinkSpec::TopK(3)).unwrap();
        assert_eq!(pruned.topk(3), full.topk(3), "exact pruning changed LoRIF top-k");
        assert!(pruned.chunks_skipped >= 4, "weak chunks should be skipped");
        assert_eq!(pruned.bytes_read + pruned.bytes_skipped, full.bytes_read);

        // cached projections are a different train representation: the
        // kernel opts out of pruning and reads everything
        scorer.cached_projections = true;
        let cached = scorer.score_sink(&queries, SinkSpec::TopK(3)).unwrap();
        assert_eq!(cached.chunks_skipped, 0);
        assert_eq!(cached.bytes_read, full.bytes_read);
    }

    #[test]
    fn report_phases_populated() {
        let (mut scorer, fx) = build_scorer("lorif_phases", 8, false);
        let report = scorer.score(&fx.queries).unwrap();
        assert!(report.bytes_read > 0);
        assert!(report.timer.get("load") > std::time::Duration::ZERO);
        assert!(report.timer.get("compute") > std::time::Duration::ZERO);
        let tk = report.topk(5);
        assert_eq!(tk.len(), 3);
        assert_eq!(tk[0].len(), 5);
    }
}

//! RepSim baseline (Hanawa et al. 2020): cosine similarity of final
//! hidden states (last token, last layer) — the representation-retrieval
//! contextual baseline of Tables 1–2 and the App. F.2 comparison.

use std::io::{Read, Write};
use std::path::Path;

use super::{QueryGrads, ScoreReport, Scorer};
use crate::linalg::Mat;
use crate::util::timer::PhaseTimer;

/// Embedding store: a plain (N, d) f32 matrix on disk.
pub struct EmbedStore;

impl EmbedStore {
    pub fn save(path: &Path, emb: &Mat) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"LORIFEM1")?;
        f.write_all(&(emb.rows as u64).to_le_bytes())?;
        f.write_all(&(emb.cols as u64).to_le_bytes())?;
        let mut buf = Vec::with_capacity(emb.data.len() * 4);
        for &x in &emb.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Mat> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"LORIFEM1", "bad embed-store magic");
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let rows = u64::from_le_bytes(b8) as usize;
        f.read_exact(&mut b8)?;
        let cols = u64::from_le_bytes(b8) as usize;
        let mut buf = vec![0u8; rows * cols * 4];
        f.read_exact(&mut buf)?;
        let data = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Mat::from_vec(rows, cols, data))
    }
}

pub struct RepSimScorer {
    path: std::path::PathBuf,
    /// query embeddings (Nq, d), set before scoring
    pub query_emb: Mat,
    bytes: u64,
}

impl RepSimScorer {
    pub fn new(path: &Path, query_emb: Mat) -> anyhow::Result<RepSimScorer> {
        let bytes = std::fs::metadata(path)?.len();
        Ok(RepSimScorer { path: path.to_path_buf(), query_emb, bytes })
    }
}

fn normalize_rows(m: &mut Mat) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let n = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        for x in row.iter_mut() {
            *x /= n;
        }
    }
}

impl Scorer for RepSimScorer {
    fn name(&self) -> &'static str {
        "repsim"
    }

    fn index_bytes(&self) -> u64 {
        self.bytes
    }

    /// `queries` is unused (RepSim is not gradient-based) but kept for the
    /// uniform engine interface; its n_query must match query_emb.
    fn score(&mut self, queries: &QueryGrads) -> anyhow::Result<ScoreReport> {
        anyhow::ensure!(queries.n_query == self.query_emb.rows, "query count mismatch");
        let mut timer = PhaseTimer::new();
        let mut train = timer.time("load", || EmbedStore::load(&self.path))?;
        let scores = timer.time("compute", || {
            normalize_rows(&mut train);
            let mut q = self.query_emb.clone();
            normalize_rows(&mut q);
            q.matmul_nt(&train) // (Nq, N) cosine similarities
        });
        Ok(ScoreReport::full(scores, timer, self.bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn store_roundtrip_and_cosine() {
        let dir = std::env::temp_dir().join("lorif_repsim_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("emb.bin");
        let mut rng = Rng::new(1);
        let train = Mat::random_normal(10, 6, 1.0, &mut rng);
        EmbedStore::save(&path, &train).unwrap();
        let q = train.select_rows(&[3]); // query identical to train ex 3
        let mut scorer = RepSimScorer::new(&path, q).unwrap();
        let queries = QueryGrads {
            n_query: 1,
            c: 1,
            proj_dims: vec![],
            layers: vec![],
        };
        let report = scorer.score(&queries).unwrap();
        // cosine with itself = 1, and it's the argmax
        assert!((report.scores().at(0, 3) - 1.0).abs() < 1e-4);
        let top = report.topk(1);
        assert_eq!(top[0][0], 3);
        std::fs::remove_file(path).ok();
    }
}

//! Store writers: append per-example records during stage 1.
//!
//! `StoreWriter` produces the v1 single-file layout; `ShardedWriter`
//! splits the same record stream into `S` contiguous shard files plus a
//! v2 manifest, so the query path can score shards on parallel workers.
//! Both share one record encoder, so a sharded store holds bit-identical
//! records to its monolithic counterpart.
//!
//! Records are encoded segment by segment through the store's codec
//! (`super::codec`, from `StoreMeta::codec`): bf16 by default, int8 /
//! int4 for v4 quantized stores.  `append_chunk` re-encodes a DECODED
//! chunk from any source store, which is the streaming primitive
//! behind `lorif store recode`.
//!
//! Both writers also build the v3 chunk-summary pruning sidecar
//! (`crate::sketch`) as records stream through: per summary chunk
//! (default grid [`DEFAULT_SUMMARY_CHUNK`], restarting at every shard
//! roll) the codec-decoded records are folded into max-norm / centroid
//! / radius bounds, written to `<base>.summaries` at finalize.  Disable
//! (or resize the grid) with [`StoreWriter::set_summary_chunk`] /
//! [`ShardedWriter::set_summary_chunk`] before the first append.

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use super::codec::Codec;
use super::format::{StoreKind, StoreMeta};
use super::reader::{Chunk, ChunkLayer};
use crate::runtime::ExtractBatch;
use crate::sketch::{SummaryBuilder, DEFAULT_SUMMARY_CHUNK};

/// Encode example `ex` of an extract batch into `out` (appends),
/// segment by segment through the store's codec.
fn encode_batch_example(
    meta: &StoreMeta,
    batch: &ExtractBatch,
    ex: usize,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    let codec = meta.codec.get();
    for (l, lg) in batch.layers.iter().enumerate() {
        let (d1, d2) = meta.layers[l];
        match meta.kind {
            StoreKind::Dense => {
                let row = lg.g.row(ex);
                anyhow::ensure!(row.len() == d1 * d2, "dense row len");
                codec.encode(row, out);
            }
            StoreKind::Factored => {
                let u = lg.u.row(ex);
                let v = lg.v.row(ex);
                anyhow::ensure!(
                    u.len() == d1 * meta.c && v.len() == d2 * meta.c,
                    "factor row len"
                );
                codec.encode(u, out);
                codec.encode(v, out);
            }
        }
    }
    Ok(())
}

/// Encode one dense example given raw per-layer f32 slices (appends).
fn encode_dense_row(
    meta: &StoreMeta,
    per_layer: &[&[f32]],
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    anyhow::ensure!(meta.kind == StoreKind::Dense);
    let codec = meta.codec.get();
    for (l, row) in per_layer.iter().enumerate() {
        let (d1, d2) = meta.layers[l];
        anyhow::ensure!(row.len() == d1 * d2, "dense row len");
        codec.encode(row, out);
    }
    Ok(())
}

/// Encode example `ex` of a DECODED chunk into `out` (appends) — the
/// re-encode primitive behind `store::recode`: a decoded chunk from any
/// source store is written back out under this writer's codec.
fn encode_chunk_example(
    meta: &StoreMeta,
    chunk: &Chunk,
    ex: usize,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        chunk.layers.len() == meta.layers.len(),
        "chunk has {} layers, store has {}",
        chunk.layers.len(),
        meta.layers.len()
    );
    anyhow::ensure!(ex < chunk.count, "example {ex} out of chunk range");
    let codec = meta.codec.get();
    for (l, layer) in chunk.layers.iter().enumerate() {
        let (d1, d2) = meta.layers[l];
        match (meta.kind, layer) {
            (StoreKind::Dense, ChunkLayer::Dense { g }) => {
                anyhow::ensure!(g.cols == d1 * d2, "dense layer {l} width");
                codec.encode(g.row(ex), out);
            }
            (StoreKind::Factored, ChunkLayer::Factored { u, v }) => {
                anyhow::ensure!(
                    u.cols == d1 * meta.c && v.cols == d2 * meta.c,
                    "factor layer {l} width"
                );
                codec.encode(u.row(ex), out);
                codec.encode(v.row(ex), out);
            }
            _ => anyhow::bail!("chunk layer {l} kind does not match the store kind"),
        }
    }
    Ok(())
}

pub struct StoreWriter {
    base: PathBuf,
    meta: StoreMeta,
    file: BufWriter<std::fs::File>,
    written: usize,
    scratch: Vec<u8>,
    summaries: Option<SummaryBuilder>,
}

impl StoreWriter {
    pub fn create(base: &Path, mut meta: StoreMeta) -> anyhow::Result<StoreWriter> {
        if let Some(parent) = base.parent() {
            std::fs::create_dir_all(parent)?;
        }
        meta.n_examples = 0;
        meta.shards = None;
        meta.summary_chunk = None;
        let file = BufWriter::new(std::fs::File::create(StoreMeta::data_path(base))?);
        let summaries = Some(SummaryBuilder::new(&meta, DEFAULT_SUMMARY_CHUNK));
        Ok(StoreWriter {
            base: base.to_path_buf(),
            meta,
            file,
            written: 0,
            scratch: Vec::new(),
            summaries,
        })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// Resize the summary grid (`0` disables the sidecar entirely,
    /// producing a pre-v3 store).  Must be called before any record is
    /// appended: the grid cannot change mid-stream.
    pub fn set_summary_chunk(&mut self, chunk: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.written == 0, "summary chunk must be set before the first record");
        self.summaries = (chunk > 0).then(|| SummaryBuilder::new(&self.meta, chunk));
        Ok(())
    }

    /// Append the valid examples of an extract batch.
    pub fn append(&mut self, batch: &ExtractBatch) -> anyhow::Result<()> {
        anyhow::ensure!(batch.layers.len() == self.meta.layers.len(), "layer count");
        for ex in 0..batch.valid {
            self.scratch.clear();
            encode_batch_example(&self.meta, batch, ex, &mut self.scratch)?;
            debug_assert_eq!(self.scratch.len(), self.meta.bytes_per_example());
            self.file.write_all(&self.scratch)?;
            if let Some(sb) = self.summaries.as_mut() {
                sb.add_record(&self.scratch)?;
            }
            self.written += 1;
        }
        Ok(())
    }

    /// Append one example given raw per-layer f32 slices (dense kind).
    pub fn append_dense_row(&mut self, per_layer: &[&[f32]]) -> anyhow::Result<()> {
        self.scratch.clear();
        encode_dense_row(&self.meta, per_layer, &mut self.scratch)?;
        self.file.write_all(&self.scratch)?;
        if let Some(sb) = self.summaries.as_mut() {
            sb.add_record(&self.scratch)?;
        }
        self.written += 1;
        Ok(())
    }

    /// Append every example of a DECODED chunk, re-encoding through this
    /// writer's codec (the `store recode` streaming path).
    pub fn append_chunk(&mut self, chunk: &Chunk) -> anyhow::Result<()> {
        for ex in 0..chunk.count {
            self.scratch.clear();
            encode_chunk_example(&self.meta, chunk, ex, &mut self.scratch)?;
            self.file.write_all(&self.scratch)?;
            if let Some(sb) = self.summaries.as_mut() {
                sb.add_record(&self.scratch)?;
            }
            self.written += 1;
        }
        Ok(())
    }

    /// Flush data and write the metadata + summary sidecars.
    pub fn finalize(mut self) -> anyhow::Result<StoreMeta> {
        self.file.flush()?;
        self.meta.n_examples = self.written;
        if let Some(sb) = self.summaries.take() {
            let sums = sb.finish()?;
            self.meta.summary_chunk = Some(sums.chunk_size);
            sums.save(&StoreMeta::summaries_path(&self.base))?;
        }
        self.meta.save(&self.base)?;
        Ok(self.meta)
    }
}

/// Writer for the v2 sharded layout: `N` examples split into at most
/// `shards` contiguous files of `ceil(n_expected / shards)` examples
/// each (the last shard absorbs any overflow if more than `n_expected`
/// examples arrive; trailing shards are dropped if fewer do).
pub struct ShardedWriter {
    base: PathBuf,
    meta: StoreMeta,
    max_shards: usize,
    per_shard: usize,
    /// explicit per-shard example counts ([`ShardedWriter::create_planned`]):
    /// roll boundaries replicate an existing layout exactly instead of
    /// the uniform ceil rule (`store recode` with the layout kept)
    plan: Option<Vec<usize>>,
    file: BufWriter<std::fs::File>,
    /// examples written per shard; the last entry is the open shard
    counts: Vec<usize>,
    scratch: Vec<u8>,
    summaries: Option<SummaryBuilder>,
}

impl ShardedWriter {
    pub fn create(
        base: &Path,
        mut meta: StoreMeta,
        shards: usize,
        n_expected: usize,
    ) -> anyhow::Result<ShardedWriter> {
        anyhow::ensure!(shards >= 1, "shards must be >= 1");
        if let Some(parent) = base.parent() {
            std::fs::create_dir_all(parent)?;
        }
        meta.n_examples = 0;
        meta.shards = None;
        meta.summary_chunk = None;
        let per_shard = ((n_expected + shards - 1) / shards).max(1);
        let file =
            BufWriter::new(std::fs::File::create(StoreMeta::shard_data_path(base, 0))?);
        let summaries = Some(SummaryBuilder::new(&meta, DEFAULT_SUMMARY_CHUNK));
        Ok(ShardedWriter {
            base: base.to_path_buf(),
            meta,
            max_shards: shards,
            per_shard,
            plan: None,
            file,
            counts: vec![0],
            scratch: Vec::new(),
            summaries,
        })
    }

    /// A writer that rolls shards at EXPLICIT example counts instead of
    /// the uniform ceil rule — `store recode` uses this to preserve a
    /// source store's shard boundaries byte-for-byte, whatever rule
    /// (or mid-extraction drops) originally produced them.  Extra
    /// examples beyond the plan's total land in the last shard;
    /// trailing planned shards are dropped if fewer arrive.
    pub fn create_planned(
        base: &Path,
        meta: StoreMeta,
        plan: Vec<usize>,
    ) -> anyhow::Result<ShardedWriter> {
        anyhow::ensure!(!plan.is_empty(), "shard plan must name at least one shard");
        anyhow::ensure!(
            plan.iter().all(|&c| c >= 1),
            "shard plan entries must be >= 1"
        );
        let mut w = ShardedWriter::create(base, meta, plan.len(), plan.iter().sum())?;
        w.plan = Some(plan);
        Ok(w)
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// Resize the summary grid (`0` disables the sidecar).  Must be
    /// called before the first append.
    pub fn set_summary_chunk(&mut self, chunk: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.counts.iter().sum::<usize>() == 0,
            "summary chunk must be set before the first record"
        );
        self.summaries = (chunk > 0).then(|| SummaryBuilder::new(&self.meta, chunk));
        Ok(())
    }

    pub fn n_shards(&self) -> usize {
        self.counts.len()
    }

    /// Number of shard files this writer produces for `n` examples at a
    /// requested shard count — the companion of `roll_if_full`'s
    /// splitting rule, used by the stage-1 cache-validity check.
    pub fn expected_shards(n: usize, shards: usize) -> usize {
        if shards <= 1 || n == 0 {
            return 1;
        }
        let per = ((n + shards - 1) / shards).max(1);
        ((n + per - 1) / per).max(1)
    }

    /// Roll to the next shard file when the open one is full (and more
    /// shards are allowed).  The summary grid restarts with the shard:
    /// a summary chunk never straddles two data files, so a skip always
    /// maps to one contiguous seek.
    fn roll_if_full(&mut self) -> anyhow::Result<()> {
        let open = self.counts.len() - 1;
        let cap = match &self.plan {
            Some(plan) => plan[open],
            None => self.per_shard,
        };
        if self.counts[open] >= cap && self.counts.len() < self.max_shards {
            self.file.flush()?;
            if let Some(sb) = self.summaries.as_mut() {
                sb.flush()?;
            }
            let next = self.counts.len();
            self.file = BufWriter::new(std::fs::File::create(StoreMeta::shard_data_path(
                &self.base, next,
            ))?);
            self.counts.push(0);
        }
        Ok(())
    }

    fn write_record(&mut self) -> anyhow::Result<()> {
        debug_assert_eq!(self.scratch.len(), self.meta.bytes_per_example());
        self.roll_if_full()?;
        self.file.write_all(&self.scratch)?;
        if let Some(sb) = self.summaries.as_mut() {
            sb.add_record(&self.scratch)?;
        }
        *self.counts.last_mut().unwrap() += 1;
        Ok(())
    }

    /// Append the valid examples of an extract batch (examples may span
    /// shard boundaries).
    pub fn append(&mut self, batch: &ExtractBatch) -> anyhow::Result<()> {
        anyhow::ensure!(batch.layers.len() == self.meta.layers.len(), "layer count");
        for ex in 0..batch.valid {
            self.scratch.clear();
            encode_batch_example(&self.meta, batch, ex, &mut self.scratch)?;
            self.write_record()?;
        }
        Ok(())
    }

    /// Append one example given raw per-layer f32 slices (dense kind).
    pub fn append_dense_row(&mut self, per_layer: &[&[f32]]) -> anyhow::Result<()> {
        self.scratch.clear();
        encode_dense_row(&self.meta, per_layer, &mut self.scratch)?;
        self.write_record()
    }

    /// Append every example of a DECODED chunk, re-encoding through this
    /// writer's codec (the `store recode` streaming path; examples may
    /// span shard boundaries).
    pub fn append_chunk(&mut self, chunk: &Chunk) -> anyhow::Result<()> {
        for ex in 0..chunk.count {
            self.scratch.clear();
            encode_chunk_example(&self.meta, chunk, ex, &mut self.scratch)?;
            self.write_record()?;
        }
        Ok(())
    }

    /// Flush data and write the manifest (v2 shard sizes, v3 when the
    /// summary sidecar is enabled) plus the `.summaries` file.
    pub fn finalize(mut self) -> anyhow::Result<StoreMeta> {
        self.file.flush()?;
        self.meta.n_examples = self.counts.iter().sum();
        self.meta.shards = Some(self.counts.clone());
        if let Some(sb) = self.summaries.take() {
            let sums = sb.finish()?;
            self.meta.summary_chunk = Some(sums.chunk_size);
            sums.save(&StoreMeta::summaries_path(&self.base))?;
        }
        self.meta.save(&self.base)?;
        Ok(self.meta)
    }
}

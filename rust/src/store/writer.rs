//! Store writer: appends per-example records during stage 1.

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use super::format::{StoreKind, StoreMeta};
use crate::runtime::ExtractBatch;
use crate::util::bf16;

pub struct StoreWriter {
    base: PathBuf,
    meta: StoreMeta,
    file: BufWriter<std::fs::File>,
    written: usize,
    scratch: Vec<u8>,
}

impl StoreWriter {
    pub fn create(base: &Path, mut meta: StoreMeta) -> anyhow::Result<StoreWriter> {
        if let Some(parent) = base.parent() {
            std::fs::create_dir_all(parent)?;
        }
        meta.n_examples = 0;
        let file = BufWriter::new(std::fs::File::create(StoreMeta::data_path(base))?);
        Ok(StoreWriter { base: base.to_path_buf(), meta, file, written: 0, scratch: Vec::new() })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// Append the valid examples of an extract batch.
    pub fn append(&mut self, batch: &ExtractBatch) -> anyhow::Result<()> {
        anyhow::ensure!(batch.layers.len() == self.meta.layers.len(), "layer count");
        for ex in 0..batch.valid {
            self.scratch.clear();
            for (l, lg) in batch.layers.iter().enumerate() {
                let (d1, d2) = self.meta.layers[l];
                match self.meta.kind {
                    StoreKind::Dense => {
                        let row = lg.g.row(ex);
                        anyhow::ensure!(row.len() == d1 * d2, "dense row len");
                        bf16::encode_slice(row, &mut self.scratch);
                    }
                    StoreKind::Factored => {
                        let u = lg.u.row(ex);
                        let v = lg.v.row(ex);
                        anyhow::ensure!(
                            u.len() == d1 * self.meta.c && v.len() == d2 * self.meta.c,
                            "factor row len"
                        );
                        bf16::encode_slice(u, &mut self.scratch);
                        bf16::encode_slice(v, &mut self.scratch);
                    }
                }
            }
            debug_assert_eq!(self.scratch.len(), self.meta.bytes_per_example());
            self.file.write_all(&self.scratch)?;
            self.written += 1;
        }
        Ok(())
    }

    /// Append one example given raw per-layer f32 slices (dense kind).
    pub fn append_dense_row(&mut self, per_layer: &[&[f32]]) -> anyhow::Result<()> {
        anyhow::ensure!(self.meta.kind == StoreKind::Dense);
        self.scratch.clear();
        for (l, row) in per_layer.iter().enumerate() {
            let (d1, d2) = self.meta.layers[l];
            anyhow::ensure!(row.len() == d1 * d2, "dense row len");
            bf16::encode_slice(row, &mut self.scratch);
        }
        self.file.write_all(&self.scratch)?;
        self.written += 1;
        Ok(())
    }

    /// Flush data and write the metadata sidecar.
    pub fn finalize(mut self) -> anyhow::Result<StoreMeta> {
        self.file.flush()?;
        self.meta.n_examples = self.written;
        self.meta.save(&self.base)?;
        Ok(self.meta)
    }
}

//! On-disk gradient store format.
//!
//! A v1 store is a pair of files:
//!   `<name>.grads`  — fixed-stride bf16 records, one per training example
//!   `<name>.json`   — metadata (kind, tier, f, c, layer dims, count)
//!
//! A v2 store shards the records into contiguous files:
//!   `<name>.shard{i}.grads` — records for examples [start_i, start_i + n_i)
//!   `<name>.json`           — v1 metadata plus `"version": 2` and
//!                             `"shards": [n_0, n_1, ...]` example counts
//!
//! The sidecar is backward compatible: a v1 reader field set (no
//! `shards` key) means a single `<name>.grads` file, and `ShardSet`
//! opens both layouts.  Sharding exists so the query hot path can score
//! shards on parallel workers (see `query::parallel`).
//!
//! A v3 store additionally carries a chunk-summary sidecar for query
//! pruning (`crate::sketch`):
//!   `<name>.summaries` — per-chunk bound statistics, grid stride
//!                        recorded as `"summary_chunk"` in the manifest
//! v3 is orthogonal to sharding (a v3 manifest may or may not have a
//! `shards` key); v1/v2 stores without the sidecar are still read
//! everywhere and simply fall back to full scans.
//!
//! A v4 store encodes its records through a non-default codec
//! (`super::codec`): the `"codec"` manifest key names it (`int8`,
//! `int4`), and every stride below is computed through the codec's
//! per-segment `encoded_len`.  No key means bf16 — every v1–v3 store
//! on disk reads unchanged.  v4 is orthogonal to sharding AND to the
//! summary sidecar; `lorif store recode` converts between all of them.
//!
//! Two kinds (paper Fig 1):
//!   * `Dense`    — per layer, the full projected gradient `d1*d2` (LoGRA,
//!                  TrackStar, GradDot baselines): O(D) per example.
//!   * `Factored` — per layer, rank-c factors `u (d1*c)` then `v (d2*c)`
//!                  (LoRIF §3.1): O(c(d1+d2)) per example.
//!
//! The record stride is constant for every codec, so batched sequential
//! reads are a single `read_exact` — the I/O path the paper's Figure 3
//! measures.

use std::path::{Path, PathBuf};

use super::codec::{Codec, CodecId};
use crate::util::json::{obj, Value};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    Dense,
    Factored,
}

impl StoreKind {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreKind::Dense => "dense",
            StoreKind::Factored => "factored",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<StoreKind> {
        match s {
            "dense" => Ok(StoreKind::Dense),
            "factored" => Ok(StoreKind::Factored),
            _ => anyhow::bail!("unknown store kind '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct StoreMeta {
    pub kind: StoreKind,
    pub tier: String,
    pub f: usize,
    pub c: usize,
    /// (d1, d2) per tracked layer
    pub layers: Vec<(usize, usize)>,
    pub n_examples: usize,
    /// `None` = v1 single-file layout; `Some(counts)` = v2 layout with
    /// one `<name>.shard{i}.grads` file of `counts[i]` examples each.
    pub shards: Option<Vec<usize>>,
    /// `Some(stride)` = a `<name>.summaries` pruning sidecar exists,
    /// built on a grid of `stride` records (restarting per shard).
    /// `None` = no sidecar; every query falls back to a full scan.
    pub summary_chunk: Option<usize>,
    /// Record codec (`super::codec`).  `Bf16` is the default and the
    /// only codec pre-v4 manifests can carry.
    pub codec: CodecId,
}

impl StoreMeta {
    /// f32 element count of one example's record.
    pub fn floats_per_example(&self) -> usize {
        self.layers
            .iter()
            .map(|&(d1, d2)| match self.kind {
                StoreKind::Dense => d1 * d2,
                StoreKind::Factored => self.c * (d1 + d2),
            })
            .sum()
    }

    /// Encoded byte stride of one record under this store's codec.
    pub fn bytes_per_example(&self) -> usize {
        let codec = self.codec.get();
        self.layers
            .iter()
            .map(|&(d1, d2)| match self.kind {
                StoreKind::Dense => codec.encoded_len(d1 * d2),
                StoreKind::Factored => {
                    codec.encoded_len(self.c * d1) + codec.encoded_len(self.c * d2)
                }
            })
            .sum()
    }

    /// Decoded in-memory bytes of one record (the f32 values scorers
    /// consume) — what the chunk cache budgets against, as opposed to
    /// the on-disk `bytes_per_example`.
    pub fn decoded_bytes_per_example(&self) -> usize {
        self.floats_per_example() * 4
    }

    /// Byte offset of layer `l` within an encoded record, plus its
    /// decoded float length.  For factored records the layer spans the
    /// `u` segment then the `v` segment (`codec.encoded_len(c*d1)` then
    /// `codec.encoded_len(c*d2)` bytes).
    pub fn layer_span(&self, l: usize) -> anyhow::Result<(usize, usize)> {
        let codec = self.codec.get();
        let mut off = 0;
        for (i, &(d1, d2)) in self.layers.iter().enumerate() {
            let (flen, blen) = match self.kind {
                StoreKind::Dense => (d1 * d2, codec.encoded_len(d1 * d2)),
                StoreKind::Factored => (
                    self.c * (d1 + d2),
                    codec.encoded_len(self.c * d1) + codec.encoded_len(self.c * d2),
                ),
            };
            if i == l {
                return Ok((off, flen));
            }
            off += blen;
        }
        anyhow::bail!("layer index {l} out of range (store has {} layers)", self.layers.len())
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_per_example() as u64 * self.n_examples as u64
    }

    /// The store-layout version this metadata serializes as: 4 with a
    /// non-default codec, 3 with a summary sidecar, 2 sharded, else 1.
    pub fn version(&self) -> usize {
        if self.codec != CodecId::Bf16 {
            4
        } else if self.summary_chunk.is_some() {
            3
        } else if self.shards.is_some() {
            2
        } else {
            1
        }
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("kind", self.kind.as_str().into()),
            ("tier", self.tier.as_str().into()),
            ("f", self.f.into()),
            ("c", self.c.into()),
            (
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|&(a, b)| Value::Arr(vec![a.into(), b.into()]))
                        .collect(),
                ),
            ),
            ("n_examples", self.n_examples.into()),
        ];
        let version = self.version();
        if version > 1 {
            fields.push(("version", version.into()));
        }
        if let Some(counts) = &self.shards {
            fields.push((
                "shards",
                Value::Arr(counts.iter().map(|&n| n.into()).collect()),
            ));
        }
        if let Some(stride) = self.summary_chunk {
            fields.push(("summary_chunk", stride.into()));
        }
        // bf16 manifests stay byte-compatible with pre-v4 readers, so a
        // `recode --codec bf16` output opens anywhere
        if self.codec != CodecId::Bf16 {
            fields.push(("codec", self.codec.as_str().into()));
        }
        obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<StoreMeta> {
        let version = v.get("version").and_then(Value::as_usize);
        if let Some(version) = version {
            anyhow::ensure!(
                version <= 5,
                "unsupported store version {version} (this build reads v1-v5)"
            );
        }
        // v5 = clustered reordering: the manifest must carry the
        // permutation (`super::cluster`), and conversely a cluster key
        // on a pre-v5 manifest is corruption, not data.  StoreMeta does
        // not hold the permutation itself — `ClusterMeta::load` does —
        // but the version gate lives here so a truncated manifest fails
        // at open, not mid-query.
        anyhow::ensure!(
            (version.unwrap_or(1) == 5) == v.get("cluster").is_some(),
            "manifest version {} inconsistent with cluster metadata (clustered stores are version 5)",
            version.unwrap_or(1)
        );
        let codec = match v.get("codec") {
            None => CodecId::Bf16,
            Some(val) => {
                let s = val.as_str().ok_or_else(|| {
                    anyhow::anyhow!("manifest 'codec' value must be a string")
                })?;
                CodecId::parse(s)?
            }
        };
        anyhow::ensure!(
            codec == CodecId::Bf16 || version.unwrap_or(1) >= 4,
            "manifest declares codec '{}' but version {} (non-bf16 codecs need version 4)",
            codec.as_str(),
            version.unwrap_or(1)
        );
        let layers = v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers not array"))?
            .iter()
            .map(|p| {
                let p = p.as_arr().ok_or_else(|| anyhow::anyhow!("layer not pair"))?;
                Ok((
                    p[0].as_usize().ok_or_else(|| anyhow::anyhow!("bad d1"))?,
                    p[1].as_usize().ok_or_else(|| anyhow::anyhow!("bad d2"))?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let shards = match v.get("shards") {
            None => None,
            Some(s) => {
                let arr = s
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("shards not array"))?;
                anyhow::ensure!(!arr.is_empty(), "empty shard list");
                Some(
                    arr.iter()
                        .map(|x| {
                            x.as_usize().ok_or_else(|| anyhow::anyhow!("bad shard count"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                )
            }
        };
        let n_examples = v.req_usize("n_examples")?;
        if let Some(counts) = &shards {
            let total: usize = counts.iter().sum();
            anyhow::ensure!(
                total == n_examples,
                "shard counts sum to {total}, expected n_examples = {n_examples}"
            );
        }
        let summary_chunk = match v.get("summary_chunk").and_then(Value::as_usize) {
            Some(0) => anyhow::bail!("summary_chunk must be >= 1"),
            other => other,
        };
        Ok(StoreMeta {
            kind: StoreKind::parse(v.req_str("kind")?)?,
            tier: v.req_str("tier")?.to_string(),
            f: v.req_usize("f")?,
            c: v.req_usize("c")?,
            layers,
            n_examples,
            shards,
            summary_chunk,
            codec,
        })
    }

    pub fn meta_path(base: &Path) -> PathBuf {
        base.with_extension("json")
    }

    pub fn data_path(base: &Path) -> PathBuf {
        base.with_extension("grads")
    }

    /// Data file of shard `i` in the v2 layout.
    pub fn shard_data_path(base: &Path, i: usize) -> PathBuf {
        base.with_extension(format!("shard{i}.grads"))
    }

    /// Chunk-summary pruning sidecar (v3 stores, `crate::sketch`).
    pub fn summaries_path(base: &Path) -> PathBuf {
        base.with_extension("summaries")
    }

    pub fn save(&self, base: &Path) -> anyhow::Result<()> {
        std::fs::write(Self::meta_path(base), self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(base: &Path) -> anyhow::Result<StoreMeta> {
        let text = std::fs::read_to_string(Self::meta_path(base))?;
        Self::from_json(&Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(kind: StoreKind) -> StoreMeta {
        StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c: 2,
            layers: vec![(16, 48), (16, 16)],
            n_examples: 100,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        }
    }

    #[test]
    fn stride_math() {
        let d = meta(StoreKind::Dense);
        assert_eq!(d.floats_per_example(), 16 * 48 + 16 * 16);
        let f = meta(StoreKind::Factored);
        assert_eq!(f.floats_per_example(), 2 * (16 + 48) + 2 * (16 + 16));
        assert_eq!(f.bytes_per_example(), f.floats_per_example() * 2);
        assert_eq!(f.decoded_bytes_per_example(), f.floats_per_example() * 4);
    }

    #[test]
    fn codec_strides_follow_encoded_len() {
        for codec in CodecId::ALL {
            for kind in [StoreKind::Dense, StoreKind::Factored] {
                let mut m = meta(kind);
                m.codec = codec;
                let c = codec.get();
                let want: usize = m
                    .layers
                    .iter()
                    .map(|&(d1, d2)| match kind {
                        StoreKind::Dense => c.encoded_len(d1 * d2),
                        StoreKind::Factored => {
                            c.encoded_len(m.c * d1) + c.encoded_len(m.c * d2)
                        }
                    })
                    .sum();
                assert_eq!(m.bytes_per_example(), want, "{codec:?}/{kind:?}");
                // quantized codecs must actually shrink the record
                if codec != CodecId::Bf16 {
                    assert!(
                        m.bytes_per_example() < meta(kind).bytes_per_example(),
                        "{codec:?}/{kind:?} did not compress"
                    );
                }
            }
        }
    }

    #[test]
    fn layer_spans_tile_record() {
        for codec in CodecId::ALL {
            let mut m = meta(StoreKind::Factored);
            m.codec = codec;
            let (o0, l0) = m.layer_span(0).unwrap();
            let (o1, l1) = m.layer_span(1).unwrap();
            assert_eq!(o0, 0, "{codec:?}");
            assert_eq!(l0, m.c * (16 + 48), "{codec:?}");
            assert_eq!(l1, m.c * (16 + 16), "{codec:?}");
            let c = codec.get();
            assert_eq!(o1, c.encoded_len(m.c * 16) + c.encoded_len(m.c * 48), "{codec:?}");
        }
        // bf16 keeps the historical 2-bytes-per-float tiling
        let m = meta(StoreKind::Factored);
        let (_, l0) = m.layer_span(0).unwrap();
        let (o1, l1) = m.layer_span(1).unwrap();
        assert_eq!(o1, l0 * 2);
        assert_eq!((l0 + l1) * 2, m.bytes_per_example());
    }

    #[test]
    fn layer_span_out_of_range_is_an_error_not_a_panic() {
        let m = meta(StoreKind::Dense);
        let err = m.layer_span(2).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
    }

    #[test]
    fn json_roundtrip() {
        let m = meta(StoreKind::Dense);
        let back = StoreMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back.kind, StoreKind::Dense);
        assert_eq!(back.layers, m.layers);
        assert_eq!(back.n_examples, 100);
        assert_eq!(back.shards, None);
        assert_eq!(back.codec, CodecId::Bf16);
    }

    #[test]
    fn json_roundtrip_v2_shards() {
        let mut m = meta(StoreKind::Factored);
        m.shards = Some(vec![40, 40, 20]);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(2));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.shards, Some(vec![40, 40, 20]));
    }

    #[test]
    fn rejects_shard_counts_not_summing_to_total() {
        let mut m = meta(StoreKind::Dense);
        m.shards = Some(vec![40, 40]); // 80 != 100
        assert!(StoreMeta::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn rejects_future_store_version() {
        let m = meta(StoreKind::Dense);
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 6usize.into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("unsupported store version"), "{err}");
    }

    #[test]
    fn version_5_requires_cluster_metadata_and_vice_versa() {
        let m = meta(StoreKind::Dense);
        // a v5 manifest with no cluster object is truncated/corrupt
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 5usize.into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("cluster"), "{err}");
        // a cluster object on a pre-v5 manifest is corruption too
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert(
                "cluster".into(),
                crate::util::json::obj([("k", 2usize.into()), ("perm", Value::Arr(vec![]))]),
            );
        }
        assert!(StoreMeta::from_json(&doc).is_err());
        // the consistent pair parses (StoreMeta ignores the payload;
        // `super::cluster` validates it)
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 5usize.into());
            fields.insert(
                "cluster".into(),
                crate::util::json::obj([("k", 2usize.into()), ("perm", Value::Arr(vec![]))]),
            );
        }
        assert_eq!(StoreMeta::from_json(&doc).unwrap().n_examples, 100);
    }

    #[test]
    fn json_roundtrip_v3_summaries() {
        // v3 = summary sidecar, orthogonal to sharding
        let mut m = meta(StoreKind::Factored);
        m.summary_chunk = Some(256);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(3));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.summary_chunk, Some(256));
        assert_eq!(back.shards, None);

        m.shards = Some(vec![60, 40]);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(3));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.summary_chunk, Some(256));
        assert_eq!(back.shards, Some(vec![60, 40]));
    }

    #[test]
    fn json_roundtrip_v4_codec() {
        // v4 = non-default codec, orthogonal to sharding and summaries
        for codec in [CodecId::Int8, CodecId::Int4] {
            let mut m = meta(StoreKind::Dense);
            m.codec = codec;
            let doc = m.to_json();
            assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(4));
            assert_eq!(
                doc.get("codec").and_then(|v| v.as_str()),
                Some(codec.as_str())
            );
            let back = StoreMeta::from_json(&doc).unwrap();
            assert_eq!(back.codec, codec);

            m.shards = Some(vec![60, 40]);
            m.summary_chunk = Some(16);
            let back = StoreMeta::from_json(&m.to_json()).unwrap();
            assert_eq!(back.codec, codec);
            assert_eq!(back.shards, Some(vec![60, 40]));
            assert_eq!(back.summary_chunk, Some(16));
        }
        // the default codec writes a pre-v4 manifest with no codec key
        let m = meta(StoreKind::Dense);
        assert_eq!(m.version(), 1);
        assert!(m.to_json().get("codec").is_none());
    }

    #[test]
    fn rejects_unknown_or_corrupt_codec_values() {
        let m = meta(StoreKind::Dense);
        // unknown codec name
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 4usize.into());
            fields.insert("codec".into(), "zip".into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("unknown store codec"), "{err}");
        // codec value of the wrong JSON type
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 4usize.into());
            fields.insert("codec".into(), 8usize.into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("must be a string"), "{err}");
        // a non-bf16 codec on a pre-v4 manifest is corruption, not data
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("codec".into(), "int8".into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("version 4"), "{err}");
        // an explicit bf16 key on an old manifest is harmless
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("codec".into(), "bf16".into());
        }
        assert_eq!(StoreMeta::from_json(&doc).unwrap().codec, CodecId::Bf16);
    }

    #[test]
    fn rejects_zero_summary_chunk() {
        let m = meta(StoreKind::Dense);
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 3usize.into());
            fields.insert("summary_chunk".into(), 0usize.into());
        }
        assert!(StoreMeta::from_json(&doc).is_err());
    }

    #[test]
    fn shard_paths_are_distinct() {
        let base = Path::new("/tmp/idx/factored");
        assert_eq!(
            StoreMeta::shard_data_path(base, 0),
            PathBuf::from("/tmp/idx/factored.shard0.grads")
        );
        assert_ne!(StoreMeta::shard_data_path(base, 1), StoreMeta::data_path(base));
    }

    #[test]
    fn compression_ratio_matches_paper() {
        // paper §3.3: ratio d1 d2 / c(d1+d2) ~= min(d1,d2)/2 for c=1
        let mut m = meta(StoreKind::Factored);
        m.c = 1;
        let dense = meta(StoreKind::Dense);
        let ratio = dense.floats_per_example() as f64 / m.floats_per_example() as f64;
        assert!(ratio > 4.0 && ratio < 16.0, "ratio {ratio}");
    }
}

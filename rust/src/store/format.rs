//! On-disk gradient store format.
//!
//! A v1 store is a pair of files:
//!   `<name>.grads`  — fixed-stride bf16 records, one per training example
//!   `<name>.json`   — metadata (kind, tier, f, c, layer dims, count)
//!
//! A v2 store shards the records into contiguous files:
//!   `<name>.shard{i}.grads` — records for examples [start_i, start_i + n_i)
//!   `<name>.json`           — v1 metadata plus `"version": 2` and
//!                             `"shards": [n_0, n_1, ...]` example counts
//!
//! The sidecar is backward compatible: a v1 reader field set (no
//! `shards` key) means a single `<name>.grads` file, and `ShardSet`
//! opens both layouts.  Sharding exists so the query hot path can score
//! shards on parallel workers (see `query::parallel`).
//!
//! Two kinds (paper Fig 1):
//!   * `Dense`    — per layer, the full projected gradient `d1*d2` (LoGRA,
//!                  TrackStar, GradDot baselines): O(D) per example.
//!   * `Factored` — per layer, rank-c factors `u (d1*c)` then `v (d2*c)`
//!                  (LoRIF §3.1): O(c(d1+d2)) per example.
//!
//! The record stride is constant, so batched sequential reads are a
//! single `read_exact` — the I/O path the paper's Figure 3 measures.

use std::path::{Path, PathBuf};

use crate::util::json::{obj, Value};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    Dense,
    Factored,
}

impl StoreKind {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreKind::Dense => "dense",
            StoreKind::Factored => "factored",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<StoreKind> {
        match s {
            "dense" => Ok(StoreKind::Dense),
            "factored" => Ok(StoreKind::Factored),
            _ => anyhow::bail!("unknown store kind '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct StoreMeta {
    pub kind: StoreKind,
    pub tier: String,
    pub f: usize,
    pub c: usize,
    /// (d1, d2) per tracked layer
    pub layers: Vec<(usize, usize)>,
    pub n_examples: usize,
    /// `None` = v1 single-file layout; `Some(counts)` = v2 layout with
    /// one `<name>.shard{i}.grads` file of `counts[i]` examples each.
    pub shards: Option<Vec<usize>>,
}

impl StoreMeta {
    /// f32 element count of one example's record.
    pub fn floats_per_example(&self) -> usize {
        self.layers
            .iter()
            .map(|&(d1, d2)| match self.kind {
                StoreKind::Dense => d1 * d2,
                StoreKind::Factored => self.c * (d1 + d2),
            })
            .sum()
    }

    /// bf16 byte stride of one record.
    pub fn bytes_per_example(&self) -> usize {
        self.floats_per_example() * 2
    }

    /// Byte offset of layer `l` within a record, plus its float length.
    pub fn layer_span(&self, l: usize) -> (usize, usize) {
        let mut off = 0;
        for (i, &(d1, d2)) in self.layers.iter().enumerate() {
            let len = match self.kind {
                StoreKind::Dense => d1 * d2,
                StoreKind::Factored => self.c * (d1 + d2),
            };
            if i == l {
                return (off * 2, len);
            }
            off += len;
        }
        panic!("layer index {l} out of range");
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_per_example() as u64 * self.n_examples as u64
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("kind", self.kind.as_str().into()),
            ("tier", self.tier.as_str().into()),
            ("f", self.f.into()),
            ("c", self.c.into()),
            (
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|&(a, b)| Value::Arr(vec![a.into(), b.into()]))
                        .collect(),
                ),
            ),
            ("n_examples", self.n_examples.into()),
        ];
        if let Some(counts) = &self.shards {
            fields.push(("version", 2usize.into()));
            fields.push((
                "shards",
                Value::Arr(counts.iter().map(|&n| n.into()).collect()),
            ));
        }
        obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<StoreMeta> {
        if let Some(version) = v.get("version").and_then(Value::as_usize) {
            anyhow::ensure!(
                version <= 2,
                "unsupported store version {version} (this build reads v1 and v2)"
            );
        }
        let layers = v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers not array"))?
            .iter()
            .map(|p| {
                let p = p.as_arr().ok_or_else(|| anyhow::anyhow!("layer not pair"))?;
                Ok((
                    p[0].as_usize().ok_or_else(|| anyhow::anyhow!("bad d1"))?,
                    p[1].as_usize().ok_or_else(|| anyhow::anyhow!("bad d2"))?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let shards = match v.get("shards") {
            None => None,
            Some(s) => {
                let arr = s
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("shards not array"))?;
                anyhow::ensure!(!arr.is_empty(), "empty shard list");
                Some(
                    arr.iter()
                        .map(|x| {
                            x.as_usize().ok_or_else(|| anyhow::anyhow!("bad shard count"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                )
            }
        };
        let n_examples = v.req_usize("n_examples")?;
        if let Some(counts) = &shards {
            let total: usize = counts.iter().sum();
            anyhow::ensure!(
                total == n_examples,
                "shard counts sum to {total}, expected n_examples = {n_examples}"
            );
        }
        Ok(StoreMeta {
            kind: StoreKind::parse(v.req_str("kind")?)?,
            tier: v.req_str("tier")?.to_string(),
            f: v.req_usize("f")?,
            c: v.req_usize("c")?,
            layers,
            n_examples,
            shards,
        })
    }

    pub fn meta_path(base: &Path) -> PathBuf {
        base.with_extension("json")
    }

    pub fn data_path(base: &Path) -> PathBuf {
        base.with_extension("grads")
    }

    /// Data file of shard `i` in the v2 layout.
    pub fn shard_data_path(base: &Path, i: usize) -> PathBuf {
        base.with_extension(format!("shard{i}.grads"))
    }

    pub fn save(&self, base: &Path) -> anyhow::Result<()> {
        std::fs::write(Self::meta_path(base), self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(base: &Path) -> anyhow::Result<StoreMeta> {
        let text = std::fs::read_to_string(Self::meta_path(base))?;
        Self::from_json(&Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(kind: StoreKind) -> StoreMeta {
        StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c: 2,
            layers: vec![(16, 48), (16, 16)],
            n_examples: 100,
            shards: None,
        }
    }

    #[test]
    fn stride_math() {
        let d = meta(StoreKind::Dense);
        assert_eq!(d.floats_per_example(), 16 * 48 + 16 * 16);
        let f = meta(StoreKind::Factored);
        assert_eq!(f.floats_per_example(), 2 * (16 + 48) + 2 * (16 + 16));
        assert_eq!(f.bytes_per_example(), f.floats_per_example() * 2);
    }

    #[test]
    fn layer_spans_tile_record() {
        let m = meta(StoreKind::Factored);
        let (o0, l0) = m.layer_span(0);
        let (o1, l1) = m.layer_span(1);
        assert_eq!(o0, 0);
        assert_eq!(o1, l0 * 2);
        assert_eq!((l0 + l1) * 2, m.bytes_per_example());
    }

    #[test]
    fn json_roundtrip() {
        let m = meta(StoreKind::Dense);
        let back = StoreMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back.kind, StoreKind::Dense);
        assert_eq!(back.layers, m.layers);
        assert_eq!(back.n_examples, 100);
        assert_eq!(back.shards, None);
    }

    #[test]
    fn json_roundtrip_v2_shards() {
        let mut m = meta(StoreKind::Factored);
        m.shards = Some(vec![40, 40, 20]);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(2));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.shards, Some(vec![40, 40, 20]));
    }

    #[test]
    fn rejects_shard_counts_not_summing_to_total() {
        let mut m = meta(StoreKind::Dense);
        m.shards = Some(vec![40, 40]); // 80 != 100
        assert!(StoreMeta::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn rejects_future_store_version() {
        let m = meta(StoreKind::Dense);
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 3usize.into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("unsupported store version"), "{err}");
    }

    #[test]
    fn shard_paths_are_distinct() {
        let base = Path::new("/tmp/idx/factored");
        assert_eq!(
            StoreMeta::shard_data_path(base, 0),
            PathBuf::from("/tmp/idx/factored.shard0.grads")
        );
        assert_ne!(StoreMeta::shard_data_path(base, 1), StoreMeta::data_path(base));
    }

    #[test]
    fn compression_ratio_matches_paper() {
        // paper §3.3: ratio d1 d2 / c(d1+d2) ~= min(d1,d2)/2 for c=1
        let mut m = meta(StoreKind::Factored);
        m.c = 1;
        let dense = meta(StoreKind::Dense);
        let ratio = dense.floats_per_example() as f64 / m.floats_per_example() as f64;
        assert!(ratio > 4.0 && ratio < 16.0, "ratio {ratio}");
    }
}

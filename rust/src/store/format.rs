//! On-disk gradient store format.
//!
//! A v1 store is a pair of files:
//!   `<name>.grads`  — fixed-stride bf16 records, one per training example
//!   `<name>.json`   — metadata (kind, tier, f, c, layer dims, count)
//!
//! A v2 store shards the records into contiguous files:
//!   `<name>.shard{i}.grads` — records for examples [start_i, start_i + n_i)
//!   `<name>.json`           — v1 metadata plus `"version": 2` and
//!                             `"shards": [n_0, n_1, ...]` example counts
//!
//! The sidecar is backward compatible: a v1 reader field set (no
//! `shards` key) means a single `<name>.grads` file, and `ShardSet`
//! opens both layouts.  Sharding exists so the query hot path can score
//! shards on parallel workers (see `query::parallel`).
//!
//! A v3 store additionally carries a chunk-summary sidecar for query
//! pruning (`crate::sketch`):
//!   `<name>.summaries` — per-chunk bound statistics, grid stride
//!                        recorded as `"summary_chunk"` in the manifest
//! v3 is orthogonal to sharding (a v3 manifest may or may not have a
//! `shards` key); v1/v2 stores without the sidecar are still read
//! everywhere and simply fall back to full scans.
//!
//! Two kinds (paper Fig 1):
//!   * `Dense`    — per layer, the full projected gradient `d1*d2` (LoGRA,
//!                  TrackStar, GradDot baselines): O(D) per example.
//!   * `Factored` — per layer, rank-c factors `u (d1*c)` then `v (d2*c)`
//!                  (LoRIF §3.1): O(c(d1+d2)) per example.
//!
//! The record stride is constant, so batched sequential reads are a
//! single `read_exact` — the I/O path the paper's Figure 3 measures.

use std::path::{Path, PathBuf};

use crate::util::json::{obj, Value};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreKind {
    Dense,
    Factored,
}

impl StoreKind {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreKind::Dense => "dense",
            StoreKind::Factored => "factored",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<StoreKind> {
        match s {
            "dense" => Ok(StoreKind::Dense),
            "factored" => Ok(StoreKind::Factored),
            _ => anyhow::bail!("unknown store kind '{s}'"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct StoreMeta {
    pub kind: StoreKind,
    pub tier: String,
    pub f: usize,
    pub c: usize,
    /// (d1, d2) per tracked layer
    pub layers: Vec<(usize, usize)>,
    pub n_examples: usize,
    /// `None` = v1 single-file layout; `Some(counts)` = v2 layout with
    /// one `<name>.shard{i}.grads` file of `counts[i]` examples each.
    pub shards: Option<Vec<usize>>,
    /// `Some(stride)` = a `<name>.summaries` pruning sidecar exists,
    /// built on a grid of `stride` records (restarting per shard).
    /// `None` = no sidecar; every query falls back to a full scan.
    pub summary_chunk: Option<usize>,
}

impl StoreMeta {
    /// f32 element count of one example's record.
    pub fn floats_per_example(&self) -> usize {
        self.layers
            .iter()
            .map(|&(d1, d2)| match self.kind {
                StoreKind::Dense => d1 * d2,
                StoreKind::Factored => self.c * (d1 + d2),
            })
            .sum()
    }

    /// bf16 byte stride of one record.
    pub fn bytes_per_example(&self) -> usize {
        self.floats_per_example() * 2
    }

    /// Byte offset of layer `l` within a record, plus its float length.
    pub fn layer_span(&self, l: usize) -> anyhow::Result<(usize, usize)> {
        let mut off = 0;
        for (i, &(d1, d2)) in self.layers.iter().enumerate() {
            let len = match self.kind {
                StoreKind::Dense => d1 * d2,
                StoreKind::Factored => self.c * (d1 + d2),
            };
            if i == l {
                return Ok((off * 2, len));
            }
            off += len;
        }
        anyhow::bail!("layer index {l} out of range (store has {} layers)", self.layers.len())
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_per_example() as u64 * self.n_examples as u64
    }

    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("kind", self.kind.as_str().into()),
            ("tier", self.tier.as_str().into()),
            ("f", self.f.into()),
            ("c", self.c.into()),
            (
                "layers",
                Value::Arr(
                    self.layers
                        .iter()
                        .map(|&(a, b)| Value::Arr(vec![a.into(), b.into()]))
                        .collect(),
                ),
            ),
            ("n_examples", self.n_examples.into()),
        ];
        let version: usize = if self.summary_chunk.is_some() {
            3
        } else if self.shards.is_some() {
            2
        } else {
            1
        };
        if version > 1 {
            fields.push(("version", version.into()));
        }
        if let Some(counts) = &self.shards {
            fields.push((
                "shards",
                Value::Arr(counts.iter().map(|&n| n.into()).collect()),
            ));
        }
        if let Some(stride) = self.summary_chunk {
            fields.push(("summary_chunk", stride.into()));
        }
        obj(fields)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<StoreMeta> {
        if let Some(version) = v.get("version").and_then(Value::as_usize) {
            anyhow::ensure!(
                version <= 3,
                "unsupported store version {version} (this build reads v1-v3)"
            );
        }
        let layers = v
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("layers not array"))?
            .iter()
            .map(|p| {
                let p = p.as_arr().ok_or_else(|| anyhow::anyhow!("layer not pair"))?;
                Ok((
                    p[0].as_usize().ok_or_else(|| anyhow::anyhow!("bad d1"))?,
                    p[1].as_usize().ok_or_else(|| anyhow::anyhow!("bad d2"))?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let shards = match v.get("shards") {
            None => None,
            Some(s) => {
                let arr = s
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("shards not array"))?;
                anyhow::ensure!(!arr.is_empty(), "empty shard list");
                Some(
                    arr.iter()
                        .map(|x| {
                            x.as_usize().ok_or_else(|| anyhow::anyhow!("bad shard count"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?,
                )
            }
        };
        let n_examples = v.req_usize("n_examples")?;
        if let Some(counts) = &shards {
            let total: usize = counts.iter().sum();
            anyhow::ensure!(
                total == n_examples,
                "shard counts sum to {total}, expected n_examples = {n_examples}"
            );
        }
        let summary_chunk = match v.get("summary_chunk").and_then(Value::as_usize) {
            Some(0) => anyhow::bail!("summary_chunk must be >= 1"),
            other => other,
        };
        Ok(StoreMeta {
            kind: StoreKind::parse(v.req_str("kind")?)?,
            tier: v.req_str("tier")?.to_string(),
            f: v.req_usize("f")?,
            c: v.req_usize("c")?,
            layers,
            n_examples,
            shards,
            summary_chunk,
        })
    }

    pub fn meta_path(base: &Path) -> PathBuf {
        base.with_extension("json")
    }

    pub fn data_path(base: &Path) -> PathBuf {
        base.with_extension("grads")
    }

    /// Data file of shard `i` in the v2 layout.
    pub fn shard_data_path(base: &Path, i: usize) -> PathBuf {
        base.with_extension(format!("shard{i}.grads"))
    }

    /// Chunk-summary pruning sidecar (v3 stores, `crate::sketch`).
    pub fn summaries_path(base: &Path) -> PathBuf {
        base.with_extension("summaries")
    }

    pub fn save(&self, base: &Path) -> anyhow::Result<()> {
        std::fs::write(Self::meta_path(base), self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(base: &Path) -> anyhow::Result<StoreMeta> {
        let text = std::fs::read_to_string(Self::meta_path(base))?;
        Self::from_json(&Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(kind: StoreKind) -> StoreMeta {
        StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c: 2,
            layers: vec![(16, 48), (16, 16)],
            n_examples: 100,
            shards: None,
            summary_chunk: None,
        }
    }

    #[test]
    fn stride_math() {
        let d = meta(StoreKind::Dense);
        assert_eq!(d.floats_per_example(), 16 * 48 + 16 * 16);
        let f = meta(StoreKind::Factored);
        assert_eq!(f.floats_per_example(), 2 * (16 + 48) + 2 * (16 + 16));
        assert_eq!(f.bytes_per_example(), f.floats_per_example() * 2);
    }

    #[test]
    fn layer_spans_tile_record() {
        let m = meta(StoreKind::Factored);
        let (o0, l0) = m.layer_span(0).unwrap();
        let (o1, l1) = m.layer_span(1).unwrap();
        assert_eq!(o0, 0);
        assert_eq!(o1, l0 * 2);
        assert_eq!((l0 + l1) * 2, m.bytes_per_example());
    }

    #[test]
    fn layer_span_out_of_range_is_an_error_not_a_panic() {
        let m = meta(StoreKind::Dense);
        let err = m.layer_span(2).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
    }

    #[test]
    fn json_roundtrip() {
        let m = meta(StoreKind::Dense);
        let back = StoreMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back.kind, StoreKind::Dense);
        assert_eq!(back.layers, m.layers);
        assert_eq!(back.n_examples, 100);
        assert_eq!(back.shards, None);
    }

    #[test]
    fn json_roundtrip_v2_shards() {
        let mut m = meta(StoreKind::Factored);
        m.shards = Some(vec![40, 40, 20]);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(2));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.shards, Some(vec![40, 40, 20]));
    }

    #[test]
    fn rejects_shard_counts_not_summing_to_total() {
        let mut m = meta(StoreKind::Dense);
        m.shards = Some(vec![40, 40]); // 80 != 100
        assert!(StoreMeta::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn rejects_future_store_version() {
        let m = meta(StoreKind::Dense);
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 4usize.into());
        }
        let err = StoreMeta::from_json(&doc).unwrap_err();
        assert!(format!("{err}").contains("unsupported store version"), "{err}");
    }

    #[test]
    fn json_roundtrip_v3_summaries() {
        // v3 = summary sidecar, orthogonal to sharding
        let mut m = meta(StoreKind::Factored);
        m.summary_chunk = Some(256);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(3));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.summary_chunk, Some(256));
        assert_eq!(back.shards, None);

        m.shards = Some(vec![60, 40]);
        let doc = m.to_json();
        assert_eq!(doc.get("version").and_then(|v| v.as_usize()), Some(3));
        let back = StoreMeta::from_json(&doc).unwrap();
        assert_eq!(back.summary_chunk, Some(256));
        assert_eq!(back.shards, Some(vec![60, 40]));
    }

    #[test]
    fn rejects_zero_summary_chunk() {
        let m = meta(StoreKind::Dense);
        let mut doc = m.to_json();
        if let Value::Obj(fields) = &mut doc {
            fields.insert("version".into(), 3usize.into());
            fields.insert("summary_chunk".into(), 0usize.into());
        }
        assert!(StoreMeta::from_json(&doc).is_err());
    }

    #[test]
    fn shard_paths_are_distinct() {
        let base = Path::new("/tmp/idx/factored");
        assert_eq!(
            StoreMeta::shard_data_path(base, 0),
            PathBuf::from("/tmp/idx/factored.shard0.grads")
        );
        assert_ne!(StoreMeta::shard_data_path(base, 1), StoreMeta::data_path(base));
    }

    #[test]
    fn compression_ratio_matches_paper() {
        // paper §3.3: ratio d1 d2 / c(d1+d2) ~= min(d1,d2)/2 for c=1
        let mut m = meta(StoreKind::Factored);
        m.c = 1;
        let dense = meta(StoreKind::Dense);
        let ratio = dense.floats_per_example() as f64 / m.floats_per_example() as f64;
        assert!(ratio > 4.0 && ratio < 16.0, "ratio {ratio}");
    }
}

//! Quantized-domain scoring: dot products straight off encoded segment
//! bytes, without materializing decoded f32 chunks.
//!
//! The decode-then-score hot path turns every 1-byte (int8) or half-byte
//! (int4) stored value into a 4-byte f32 before the inner dot product
//! ever runs — 4–8× the memory traffic of the bytes actually read from
//! disk.  Both int codecs are linear maps (`x̂_i = q_i · s_{g(i)}`), so
//! the dot against a query row factors exactly:
//!
//! ```text
//!   <x̂, y> = Σ_g  s_g · Σ_{i ∈ g}  q_i · y_i
//! ```
//!
//! — an integer-code dot per scale group plus ONE scale multiply per
//! group (one per segment for int8, one per [`INT4_GROUP`] values for
//! int4).  This module implements that fold plus the matching norm²
//! identity `‖x̂‖² = Σ_g s_g² · Σ q_i²` (the trackstar kernel's per-row
//! norm), over segments addressed by a [`QuantPlan`].
//!
//! **Equivalence contract** (checked by unit tests here and the
//! `prop_codec_quant_*` property tests):
//!
//! * bf16 is not a linear-code codec, so its "quantized" path decodes
//!   the segment into scratch and reuses `linalg::mat::dot`/`sumsq` —
//!   the SAME kernels, in the SAME association order, as the decoded
//!   path.  Scores are **bit-identical**.
//! * int8/int4 differ from decode-then-score only by f32 rounding and
//!   the re-association of the scale multiply — orders of magnitude
//!   below the codec's own `max_rel_error()` quantization error.
//! * NaN poisoning is preserved: a non-finite scale (the codec's
//!   marker for a group that held NaN/Inf) multiplies into the group's
//!   partial sum, so every score touching that group is NaN, exactly as
//!   when the decoded all-NaN values flow through `dot`.  A zero scale
//!   (all-zero group) contributes exactly 0.0 on both paths.
//!
//! Which kernels take this path is decided per query by [`QuantScore`]
//! (the `--quant-score` knob) in `attribution::exec`.

use super::{CodecId, INT4_GROUP};
use crate::linalg::{dot, sumsq, Mat};
use crate::store::format::{StoreKind, StoreMeta};

/// The `--quant-score` knob: when kernels score encoded bytes directly
/// instead of decoded f32 chunks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantScore {
    /// Quantized-domain scoring for kernels that support it, on stores
    /// where it changes the math for the better (int8/int4); bf16
    /// stores keep the decoded path, whose cached-chunk layout is the
    /// better residency trade for 2-byte codes.
    #[default]
    Auto,
    /// Always score encoded bytes when the kernel supports it — on bf16
    /// stores this is the bit-identical decode-into-scratch path (the
    /// equivalence tests' anchor).
    On,
    /// Always decode chunks to f32 first (the pre-quant behaviour).
    Off,
}

impl QuantScore {
    pub fn as_str(self) -> &'static str {
        match self {
            QuantScore::Auto => "auto",
            QuantScore::On => "on",
            QuantScore::Off => "off",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<QuantScore> {
        match s {
            "auto" => Ok(QuantScore::Auto),
            "on" => Ok(QuantScore::On),
            "off" => Ok(QuantScore::Off),
            _ => anyhow::bail!("unknown quant-score mode '{s}' (on|off|auto)"),
        }
    }

    /// Resolve the knob against a kernel's capability and the store's
    /// codec — the single place the on/off/auto policy lives.
    pub fn active(self, kernel_supports_encoded: bool, codec: CodecId) -> bool {
        match self {
            QuantScore::Off => false,
            QuantScore::On => kernel_supports_encoded,
            QuantScore::Auto => kernel_supports_encoded && codec != CodecId::Bf16,
        }
    }
}

/// How to address one example's layer segment inside a raw encoded
/// chunk (`Chunk::encoded`): per-layer byte offsets within the fixed
/// record stride.  Built once per query at kernel precondition time.
#[derive(Clone, Debug)]
pub struct QuantPlan {
    codec: CodecId,
    /// `StoreMeta::bytes_per_example()` — encoded record stride.
    stride: usize,
    /// Per layer: (byte offset within a record, decoded float length).
    segs: Vec<(usize, usize)>,
}

impl QuantPlan {
    /// Plan for a dense store: one codec segment per layer.  (Factored
    /// records interleave `u`/`v` segments per layer; the only factored
    /// kernel, LoRIF, decodes in-kernel instead of taking this path.)
    pub fn dense(meta: &StoreMeta) -> anyhow::Result<QuantPlan> {
        anyhow::ensure!(
            meta.kind == StoreKind::Dense,
            "QuantPlan::dense on a {} store",
            meta.kind.as_str()
        );
        let segs = (0..meta.layers.len())
            .map(|l| meta.layer_span(l))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(QuantPlan { codec: meta.codec, stride: meta.bytes_per_example(), segs })
    }

    pub fn codec(&self) -> CodecId {
        self.codec
    }

    pub fn n_layers(&self) -> usize {
        self.segs.len()
    }

    /// Number of whole records in `raw`.
    pub fn examples(&self, raw: &[u8]) -> usize {
        debug_assert_eq!(raw.len() % self.stride, 0, "ragged encoded chunk");
        raw.len() / self.stride
    }

    /// Example `ex`'s layer-`l` segment bytes plus its decoded float
    /// length.
    pub fn seg<'a>(&self, raw: &'a [u8], ex: usize, l: usize) -> (&'a [u8], usize) {
        let (off, n) = self.segs[l];
        let base = ex * self.stride + off;
        let blen = self.codec.get().encoded_len(n);
        (&raw[base..base + blen], n)
    }
}

/// Reusable per-worker buffers so the hot loop never allocates: decoded
/// floats (bf16 path), unpacked signed codes, and group scales (int4).
#[derive(Default)]
pub struct QuantScratch {
    f32buf: Vec<f32>,
    codes: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantScratch {
    pub fn new() -> QuantScratch {
        QuantScratch::default()
    }
}

/// `out[q] += <decode(seg), queries.row(q)>` for every query row,
/// without decoding to f32 for the int codecs (see module docs).
/// `queries` is `(Nq, n)` row-major; `out` is one example's score row.
pub fn accum_row_scores(
    codec: CodecId,
    seg: &[u8],
    n: usize,
    queries: &Mat,
    out: &mut [f32],
    scratch: &mut QuantScratch,
) {
    debug_assert_eq!(queries.cols, n, "query/segment width mismatch");
    debug_assert_eq!(queries.rows, out.len(), "query/out row mismatch");
    match codec {
        CodecId::Bf16 => {
            decode_to_scratch(codec, seg, n, scratch);
            for (q, o) in out.iter_mut().enumerate() {
                *o += dot(&scratch.f32buf, queries.row(q));
            }
        }
        CodecId::Int8 => {
            let scale = le_f32(&seg[..4]);
            unpack_i8(&seg[4..], scratch);
            for (q, o) in out.iter_mut().enumerate() {
                *o += scale * dot_i8(&scratch.codes, queries.row(q));
            }
        }
        CodecId::Int4 => {
            unpack_i4(seg, n, scratch);
            for (q, o) in out.iter_mut().enumerate() {
                let y = queries.row(q);
                let mut acc = 0.0f32;
                for (k, &s) in scratch.scales.iter().enumerate() {
                    let lo = k * INT4_GROUP;
                    let hi = (lo + INT4_GROUP).min(n);
                    acc += s * dot_i8(&scratch.codes[lo..hi], &y[lo..hi]);
                }
                *o += acc;
            }
        }
    }
}

/// `‖decode(seg)‖²` via the same scale fold (`Σ_g s_g² Σ q²`); bf16
/// decodes and reuses [`sumsq`] so the trackstar norm stays
/// bit-identical to the decoded path.
pub fn seg_norm2(codec: CodecId, seg: &[u8], n: usize, scratch: &mut QuantScratch) -> f32 {
    match codec {
        CodecId::Bf16 => {
            decode_to_scratch(codec, seg, n, scratch);
            sumsq(&scratch.f32buf)
        }
        CodecId::Int8 => {
            let scale = le_f32(&seg[..4]);
            unpack_i8(&seg[4..], scratch);
            scale * scale * sumsq_i8(&scratch.codes)
        }
        CodecId::Int4 => {
            unpack_i4(seg, n, scratch);
            let mut acc = 0.0f32;
            for (k, &s) in scratch.scales.iter().enumerate() {
                let lo = k * INT4_GROUP;
                let hi = (lo + INT4_GROUP).min(n);
                acc += s * s * sumsq_i8(&scratch.codes[lo..hi]);
            }
            acc
        }
    }
}

#[inline]
fn le_f32(b: &[u8]) -> f32 {
    f32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn decode_to_scratch(codec: CodecId, seg: &[u8], n: usize, scratch: &mut QuantScratch) {
    scratch.f32buf.resize(n, 0.0);
    codec.get().decode(seg, &mut scratch.f32buf);
}

/// Reinterpret the raw int8 payload as signed codes (amortized over all
/// `Nq` query dots against this segment).
fn unpack_i8(payload: &[u8], scratch: &mut QuantScratch) {
    scratch.codes.clear();
    scratch.codes.extend(payload.iter().map(|&b| b as i8));
}

/// Split an int4 segment into its group scales and sign-extended
/// nibble codes (low nibble first — the `Int4Codec` layout).
fn unpack_i4(seg: &[u8], n: usize, scratch: &mut QuantScratch) {
    let n_groups = (n + INT4_GROUP - 1) / INT4_GROUP;
    scratch.scales.clear();
    for g in 0..n_groups {
        scratch.scales.push(le_f32(&seg[g * 4..g * 4 + 4]));
    }
    let data = &seg[n_groups * 4..];
    scratch.codes.clear();
    scratch.codes.reserve(n);
    for i in 0..n {
        let b = data[i / 2];
        let nib = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
        scratch.codes.push(((nib as i8) << 4) >> 4);
    }
}

/// Σ codesᵢ · yᵢ — the integer-code inner kernel, blocked 8-wide like
/// [`dot`] (explicit `std::simd` under the `simd` feature, 8-lane
/// scalar accumulators otherwise).
#[inline]
pub fn dot_i8(codes: &[i8], y: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), y.len());
    let blocks = codes.len() / 8 * 8;
    let mut s = dot_i8_blocks(&codes[..blocks], &y[..blocks]);
    for i in blocks..codes.len() {
        s += codes[i] as f32 * y[i];
    }
    s
}

#[cfg(feature = "simd")]
#[inline]
fn dot_i8_blocks(codes: &[i8], y: &[f32]) -> f32 {
    use std::simd::{f32x8, i8x8};
    let mut acc = f32x8::splat(0.0);
    for (c, v) in codes.chunks_exact(8).zip(y.chunks_exact(8)) {
        acc += i8x8::from_slice(c).cast::<f32>() * f32x8::from_slice(v);
    }
    let v = acc.to_array();
    ((v[0] + v[4]) + (v[1] + v[5])) + ((v[2] + v[6]) + (v[3] + v[7]))
}

#[cfg(not(feature = "simd"))]
#[inline]
fn dot_i8_blocks(codes: &[i8], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    for (c, v) in codes.chunks_exact(8).zip(y.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += c[l] as f32 * v[l];
        }
    }
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// Σ codesᵢ² — small integers, so single-f32 accumulation with the same
/// blocking as [`dot_i8`].
#[inline]
fn sumsq_i8(codes: &[i8]) -> f32 {
    let blocks = codes.len() / 8 * 8;
    let mut acc = [0.0f32; 8];
    for c in codes[..blocks].chunks_exact(8) {
        for l in 0..8 {
            acc[l] += (c[l] as f32) * (c[l] as f32);
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for &c in &codes[blocks..] {
        s += (c as f32) * (c as f32);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn encode(codec: CodecId, src: &[f32]) -> Vec<u8> {
        let mut bytes = Vec::new();
        codec.get().encode(src, &mut bytes);
        bytes
    }

    fn decode(codec: CodecId, seg: &[u8], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        codec.get().decode(seg, &mut out);
        out
    }

    /// decode-then-score reference, through the SAME `dot` kernel the
    /// decoded scoring path uses.
    fn reference_scores(codec: CodecId, seg: &[u8], n: usize, queries: &Mat) -> Vec<f32> {
        let vals = decode(codec, seg, n);
        (0..queries.rows).map(|q| dot(&vals, queries.row(q))).collect()
    }

    #[test]
    fn quant_scores_match_decode_then_score() {
        let mut rng = Rng::new(41);
        for codec in CodecId::ALL {
            for n in [1usize, 7, 8, 31, 32, 33, 96, 200] {
                let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
                let seg = encode(codec, &src);
                let queries = Mat::random_normal(5, n, 1.0, &mut rng);
                let want = reference_scores(codec, &seg, n, &queries);
                let mut got = vec![0.0f32; 5];
                let mut scratch = QuantScratch::new();
                accum_row_scores(codec, &seg, n, &queries, &mut got, &mut scratch);
                for (q, (a, b)) in got.iter().zip(&want).enumerate() {
                    if codec == CodecId::Bf16 {
                        // decode-into-scratch + the shared dot kernel:
                        // bit-identical, not merely close
                        assert_eq!(a, b, "{codec:?} n={n} q={q}");
                    } else {
                        // same quantized integers; only f32 rounding and
                        // the scale re-association differ
                        assert!(
                            (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                            "{codec:?} n={n} q={q}: {a} vs {b}"
                        );
                    }
                }
                // and it accumulates rather than overwrites
                let mut again = got.clone();
                accum_row_scores(codec, &seg, n, &queries, &mut again, &mut scratch);
                for (q, (a, b)) in again.iter().zip(&got).enumerate() {
                    let twice = 2.0 * b;
                    assert!(
                        (a - twice).abs() <= 1e-4 * (1.0 + twice.abs()) || (a.is_nan() && b.is_nan()),
                        "{codec:?} n={n} q={q}: {a} vs 2*{b}"
                    );
                }
            }
        }
    }

    #[test]
    fn seg_norm2_matches_decoded_sumsq() {
        let mut rng = Rng::new(43);
        for codec in CodecId::ALL {
            for n in [1usize, 8, 33, 96] {
                let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let seg = encode(codec, &src);
                let want = sumsq(&decode(codec, &seg, n));
                let mut scratch = QuantScratch::new();
                let got = seg_norm2(codec, &seg, n, &mut scratch);
                if codec == CodecId::Bf16 {
                    assert_eq!(got, want, "{codec:?} n={n}");
                } else {
                    assert!(
                        (got - want).abs() <= 1e-4 * (1.0 + want),
                        "{codec:?} n={n}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn nan_groups_poison_and_zero_segments_score_zero() {
        for codec in [CodecId::Int8, CodecId::Int4] {
            let mut src = vec![1.0f32; 64];
            src[40] = f32::NAN;
            let seg = encode(codec, &src);
            let queries = Mat::from_vec(1, 64, vec![1.0; 64]);
            let mut out = vec![0.0f32];
            let mut scratch = QuantScratch::new();
            accum_row_scores(codec, &seg, 64, &queries, &mut out, &mut scratch);
            assert!(out[0].is_nan(), "{codec:?}: {}", out[0]);
            assert!(seg_norm2(codec, &seg, 64, &mut scratch).is_nan(), "{codec:?}");

            let zeros = encode(codec, &[0.0; 40]);
            let queries = Mat::from_vec(2, 40, vec![3.0; 80]);
            let mut out = vec![0.5f32, -0.5];
            accum_row_scores(codec, &zeros, 40, &queries, &mut out, &mut scratch);
            assert_eq!(out, vec![0.5, -0.5], "{codec:?} zero segment must add 0.0");
            assert_eq!(seg_norm2(codec, &zeros, 40, &mut scratch), 0.0, "{codec:?}");
        }
    }

    #[test]
    fn dot_i8_matches_scalar_loop() {
        let mut rng = Rng::new(47);
        for n in [0usize, 1, 7, 8, 9, 16, 100] {
            let codes: Vec<i8> = (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
            let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let want: f32 = codes.iter().zip(&y).map(|(&c, &v)| c as f32 * v).sum();
            assert!((dot_i8(&codes, &y) - want).abs() <= 1e-3 * (1.0 + want.abs()), "n={n}");
            let want_sq: f32 = codes.iter().map(|&c| (c as f32) * (c as f32)).sum();
            assert!((sumsq_i8(&codes) - want_sq).abs() <= 1e-2 * (1.0 + want_sq), "n={n}");
        }
    }

    #[test]
    fn quant_plan_addresses_dense_layer_segments() {
        for codec in CodecId::ALL {
            let meta = StoreMeta {
                kind: StoreKind::Dense,
                tier: "t".into(),
                f: 4,
                c: 1,
                layers: vec![(4, 12), (8, 8)],
                n_examples: 3,
                shards: None,
                summary_chunk: None,
                codec,
            };
            let plan = QuantPlan::dense(&meta).unwrap();
            assert_eq!(plan.codec(), codec);
            assert_eq!(plan.n_layers(), 2);

            // two records of distinct values, encoded layer by layer in
            // record order — exactly the writer's layout
            let mut rng = Rng::new(53);
            let mut raw = Vec::new();
            let mut per_layer: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
            for _ex in 0..2 {
                for (l, &(d1, d2)) in meta.layers.iter().enumerate() {
                    let vals: Vec<f32> = (0..d1 * d2).map(|_| rng.normal() as f32).collect();
                    codec.get().encode(&vals, &mut raw);
                    per_layer[l].push(vals);
                }
            }
            assert_eq!(raw.len(), 2 * meta.bytes_per_example(), "{codec:?}");
            assert_eq!(plan.examples(&raw), 2, "{codec:?}");
            for ex in 0..2 {
                for l in 0..2 {
                    let (seg, n) = plan.seg(&raw, ex, l);
                    assert_eq!(n, per_layer[l][ex].len(), "{codec:?}");
                    let got = decode(codec, seg, n);
                    let direct = {
                        let mut d = vec![0.0f32; n];
                        let mut bytes = Vec::new();
                        codec.get().encode(&per_layer[l][ex], &mut bytes);
                        codec.get().decode(&bytes, &mut d);
                        d
                    };
                    assert_eq!(got, direct, "{codec:?} ex={ex} l={l}");
                }
            }
        }

        let factored = StoreMeta {
            kind: StoreKind::Factored,
            tier: "t".into(),
            f: 4,
            c: 2,
            layers: vec![(4, 12)],
            n_examples: 1,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Int8,
        };
        assert!(QuantPlan::dense(&factored).is_err());
    }

    #[test]
    fn quant_score_knob_parses_and_resolves() {
        for mode in [QuantScore::Auto, QuantScore::On, QuantScore::Off] {
            assert_eq!(QuantScore::parse(mode.as_str()).unwrap(), mode);
        }
        assert!(QuantScore::parse("yes").is_err());
        assert_eq!(QuantScore::default(), QuantScore::Auto);

        for codec in CodecId::ALL {
            assert!(!QuantScore::Off.active(true, codec), "{codec:?}");
            assert!(!QuantScore::On.active(false, codec), "{codec:?}");
            assert!(QuantScore::On.active(true, codec), "{codec:?}");
        }
        assert!(QuantScore::Auto.active(true, CodecId::Int8));
        assert!(QuantScore::Auto.active(true, CodecId::Int4));
        assert!(!QuantScore::Auto.active(true, CodecId::Bf16));
        assert!(!QuantScore::Auto.active(false, CodecId::Int8));
    }
}

//! int8 segment codec: one f32 absmax scale per segment, one signed
//! byte per value.
//!
//! Layout of a segment of `n` values:
//!
//! ```text
//! [scale: f32 LE] [q_0: i8] [q_1: i8] ... [q_{n-1}: i8]
//! ```
//!
//! `scale = absmax / 127`; `q_i = round(x_i / scale)` clamped to
//! `[-127, 127]`, so `x̂_i = q_i * scale` satisfies
//! `|x̂_i − x_i| ≤ scale/2 + rounding ≤ max_rel_error() * absmax`.
//! An all-zero segment stores `scale = 0`; a segment containing any
//! non-finite value stores `scale = NaN` and decodes to all-NaN (the
//! summarizer then marks the chunk unprunable — see `codec::mod`).

use super::{absmax, group_scale, quantize, Codec, CodecId};

const QMAX: f32 = 127.0;

pub struct Int8Codec;

impl Codec for Int8Codec {
    fn id(&self) -> CodecId {
        CodecId::Int8
    }

    fn encoded_len(&self, n: usize) -> usize {
        4 + n
    }

    fn encode(&self, src: &[f32], dst: &mut Vec<u8>) {
        let scale = group_scale(absmax(src), QMAX);
        dst.reserve(4 + src.len());
        dst.extend_from_slice(&scale.to_le_bytes());
        for &x in src {
            dst.push(quantize(x, scale, QMAX) as u8);
        }
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) {
        assert_eq!(src.len(), self.encoded_len(dst.len()), "int8 segment length mismatch");
        let scale = f32::from_le_bytes([src[0], src[1], src[2], src[3]]);
        for (b, d) in src[4..].iter().zip(dst.iter_mut()) {
            *d = (*b as i8) as f32 * scale;
        }
    }

    fn max_rel_error(&self) -> f32 {
        // half a quantization step (0.5/127 ≈ 3.94e-3) plus margin for
        // the f32 rounding of the scale itself
        4.0e-3
    }

    fn bytes_per_value(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn stride_and_exact_small_integers() {
        let c = Int8Codec;
        assert_eq!(c.encoded_len(0), 4);
        assert_eq!(c.encoded_len(100), 104);
        // values already on the quantization grid decode exactly
        let src: Vec<f32> = (-127..=127).map(|q| q as f32 * 0.5).collect();
        let mut bytes = Vec::new();
        c.encode(&src, &mut bytes);
        let mut back = vec![0.0f32; src.len()];
        c.decode(&bytes, &mut back);
        for (a, b) in src.iter().zip(&back) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn absmax_element_maps_to_full_scale() {
        let c = Int8Codec;
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let n = 1 + rng.below(64);
            let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let m = super::absmax(&src);
            let mut bytes = Vec::new();
            c.encode(&src, &mut bytes);
            let peak = bytes[4..].iter().map(|&b| (b as i8).unsigned_abs()).max().unwrap();
            if m > 0.0 {
                assert_eq!(peak, 127, "absmax element must quantize to ±127");
            }
        }
    }

    #[test]
    fn reencoding_decoded_values_is_stable() {
        // decode → encode keeps every quantized integer (the scale may
        // wobble by an f32 ulp, which cannot move a rounded integer)
        let c = Int8Codec;
        let mut rng = Rng::new(11);
        let src: Vec<f32> = (0..97).map(|_| rng.normal() as f32 * 2.5).collect();
        let mut b1 = Vec::new();
        c.encode(&src, &mut b1);
        let mut v1 = vec![0.0f32; src.len()];
        c.decode(&b1, &mut v1);
        let mut b2 = Vec::new();
        c.encode(&v1, &mut b2);
        assert_eq!(&b1[4..], &b2[4..], "quantized integers drifted");
    }
}

//! int4 segment codec: group-wise f32 absmax scales, two values per
//! byte.
//!
//! Layout of a segment of `n` values with `g = ceil(n / INT4_GROUP)`
//! groups:
//!
//! ```text
//! [scale_0: f32 LE] ... [scale_{g-1}: f32 LE]   one per group
//! [q_1 q_0] [q_3 q_2] ...                       signed nibbles, low first
//! ```
//!
//! `scale_k = group_absmax / 7`; `q_i = round(x_i / scale_k)` clamped
//! to `[-7, 7]`, so `|x̂_i − x_i| ≤ max_rel_error() * group_absmax`.
//! Group-wise scales (default 32 values) keep the error local: one
//! outlier only coarsens its own group, not the whole segment.  An odd
//! trailing value pads the high nibble with 0.  Groups containing any
//! non-finite value store `scale = NaN` and decode to all-NaN (see
//! `codec::mod` for why that keeps pruning sound).

use super::{absmax, group_scale, quantize, Codec, CodecId};

/// Values sharing one f32 scale.
pub const INT4_GROUP: usize = 32;

const QMAX: f32 = 7.0;

pub struct Int4Codec;

impl Codec for Int4Codec {
    fn id(&self) -> CodecId {
        CodecId::Int4
    }

    fn encoded_len(&self, n: usize) -> usize {
        4 * ((n + INT4_GROUP - 1) / INT4_GROUP) + (n + 1) / 2
    }

    fn encode(&self, src: &[f32], dst: &mut Vec<u8>) {
        dst.reserve(self.encoded_len(src.len()));
        let mut scales = Vec::with_capacity((src.len() + INT4_GROUP - 1) / INT4_GROUP);
        for group in src.chunks(INT4_GROUP) {
            let scale = group_scale(absmax(group), QMAX);
            dst.extend_from_slice(&scale.to_le_bytes());
            scales.push(scale);
        }
        let mut pair = src.chunks_exact(2);
        let mut i = 0usize;
        for p in &mut pair {
            let lo = quantize(p[0], scales[i / INT4_GROUP], QMAX) as u8 & 0x0F;
            let hi = quantize(p[1], scales[(i + 1) / INT4_GROUP], QMAX) as u8 & 0x0F;
            dst.push(lo | (hi << 4));
            i += 2;
        }
        if let [last] = pair.remainder() {
            dst.push(quantize(*last, scales[i / INT4_GROUP], QMAX) as u8 & 0x0F);
        }
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) {
        assert_eq!(src.len(), self.encoded_len(dst.len()), "int4 segment length mismatch");
        let n = dst.len();
        let n_groups = (n + INT4_GROUP - 1) / INT4_GROUP;
        let mut scales = Vec::with_capacity(n_groups);
        for g in 0..n_groups {
            let b = &src[g * 4..g * 4 + 4];
            scales.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        let data = &src[n_groups * 4..];
        for (i, d) in dst.iter_mut().enumerate() {
            let b = data[i / 2];
            let nib = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
            // sign-extend the 4-bit two's-complement nibble
            let q = ((nib as i8) << 4) >> 4;
            *d = q as f32 * scales[i / INT4_GROUP];
        }
    }

    fn max_rel_error(&self) -> f32 {
        // half a quantization step (0.5/7 ≈ 7.14e-2) plus scale-rounding
        // margin, relative to the GROUP absmax
        7.2e-2
    }

    fn bytes_per_value(&self) -> f64 {
        0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn stride_counts_scales_and_nibbles() {
        let c = Int4Codec;
        assert_eq!(c.encoded_len(1), 4 + 1);
        assert_eq!(c.encoded_len(2), 4 + 1);
        assert_eq!(c.encoded_len(32), 4 + 16);
        assert_eq!(c.encoded_len(33), 8 + 17);
        assert_eq!(c.encoded_len(64), 8 + 32);
        assert_eq!(c.encoded_len(65), 12 + 33);
    }

    #[test]
    fn grid_values_roundtrip_exactly() {
        let c = Int4Codec;
        // one group of values already on the q-grid for absmax 7
        let src: Vec<f32> = (-7..=7).map(|q| q as f32).collect();
        let mut bytes = Vec::new();
        c.encode(&src, &mut bytes);
        let mut back = vec![0.0f32; src.len()];
        c.decode(&bytes, &mut back);
        assert_eq!(src, back);
    }

    #[test]
    fn odd_lengths_and_group_boundaries_roundtrip() {
        let c = Int4Codec;
        let mut rng = Rng::new(5);
        for n in [1usize, 3, 31, 32, 33, 63, 65, 97] {
            let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut bytes = Vec::new();
            c.encode(&src, &mut bytes);
            assert_eq!(bytes.len(), c.encoded_len(n), "n={n}");
            let mut back = vec![0.0f32; n];
            c.decode(&bytes, &mut back);
            for g in (0..n).step_by(INT4_GROUP) {
                let m = super::absmax(&src[g..(g + INT4_GROUP).min(n)]);
                for i in g..(g + INT4_GROUP).min(n) {
                    assert!(
                        (src[i] - back[i]).abs() <= c.max_rel_error() * m,
                        "n={n} i={i}: {} -> {}",
                        src[i],
                        back[i]
                    );
                }
            }
        }
    }

    #[test]
    fn an_outlier_only_coarsens_its_own_group() {
        let c = Int4Codec;
        let mut src = vec![0.1f32; 64];
        src[40] = 100.0; // second group only
        let mut bytes = Vec::new();
        c.encode(&src, &mut bytes);
        let mut back = vec![0.0f32; 64];
        c.decode(&bytes, &mut back);
        // first group untouched by the outlier: fine-grained scale
        for i in 0..32 {
            assert!((back[i] - 0.1).abs() <= c.max_rel_error() * 0.1, "i={i}: {}", back[i]);
        }
        // second group: small values flushed toward zero is expected
        assert!((back[40] - 100.0).abs() <= c.max_rel_error() * 100.0);
    }
}

//! Pluggable store codecs: how one record SEGMENT is laid out on disk.
//!
//! The factored record format (PR 0) cut the *count* of stored values;
//! a codec cuts the *cost per value* on top of it — the multiplication
//! GraSS (Hu et al., 2025) shows loses little attribution fidelity.
//! Consumers either decode back to f32 before scoring, or — for the
//! linear int codecs — score the encoded bytes directly through the
//! [`quant`] module's scale-folded dot products (`--quant-score`).
//!
//! A record is a fixed sequence of **segments** — one per dense layer,
//! or the `u` then `v` factor rows per factored layer — and a codec
//! encodes/decodes one segment at a time:
//!
//! * [`Bf16Codec`] (`"bf16"`, the default) — raw bf16 values, 2 B each.
//!   This is the layout every v1–v3 store already uses; a manifest with
//!   no `"codec"` key means bf16, so old stores read unchanged.
//! * [`Int8Codec`] (`"int8"`) — one f32 scale per segment (absmax /
//!   127) followed by one signed byte per value.
//! * [`Int4Codec`] (`"int4"`) — one f32 scale per [`INT4_GROUP`]-value
//!   group (group absmax / 7) followed by two values per byte (signed
//!   nibbles, low nibble first).
//!
//! Stores written with a non-bf16 codec carry `"codec"` in the manifest
//! and bump to layout version 4 (`StoreMeta::version`); `ShardSet`
//! rejects unknown codec names at open time instead of mis-decoding.
//!
//! **Error contract** (what makes pruning stay sound): for every codec,
//! `|decode(encode(x))_i − x_i| ≤ max_rel_error() · max_j |x_j|` where
//! `j` ranges over the value's scale group (the whole segment for bf16
//! and int8, the [`INT4_GROUP`]-value group for int4).  The summary
//! sidecar is built from the *decoded* bytes — exactly the values
//! scorers see — and additionally inflates its bounds by this factor
//! for quantized codecs (`sketch::summary`), so a stored bound is never
//! below any score the query path can compute.  Non-finite inputs are
//! not representable by the int codecs: a segment (int8) or group
//! (int4) containing NaN/Inf decodes to all-NaN, which the summarizer
//! marks unprunable and `total_cmp` ranks deterministically.
//!
//! Property coverage: `tests/prop.rs` checks the error contract per
//! codec over random segments, recode roundtrips, and per-codec
//! pruned-scan ≡ full-scan / cached ≡ cold scoring.

mod int4;
mod int8;
pub mod quant;

pub use int4::{Int4Codec, INT4_GROUP};
pub use int8::Int8Codec;
pub use quant::{QuantPlan, QuantScore, QuantScratch};

use crate::util::bf16;

/// One segment codec (see the module docs).  Implementations are
/// stateless unit structs; dispatch goes through [`CodecId::get`].
pub trait Codec: Sync {
    fn id(&self) -> CodecId;

    /// On-disk bytes of one encoded segment of `n` values.  Constant
    /// per `n`, so records keep a fixed stride and batched sequential
    /// reads stay a single `read_exact`.
    fn encoded_len(&self, n: usize) -> usize;

    /// Append the encoded segment to `dst`.
    fn encode(&self, src: &[f32], dst: &mut Vec<u8>);

    /// Decode one segment; `src` must be exactly
    /// `encoded_len(dst.len())` bytes.
    fn decode(&self, src: &[u8], dst: &mut [f32]);

    /// Worst-case `|decode(encode(x)) − x|` as a fraction of the scale
    /// group's max absolute value (for bf16, of `|x|` itself, which is
    /// tighter).  Includes margin for the f32 rounding of the scale.
    fn max_rel_error(&self) -> f32;

    /// Nominal payload bytes per value, excluding scale headers
    /// (`store inspect` / README codec matrix).
    fn bytes_per_value(&self) -> f64;
}

/// Manifest-level codec selector (the `"codec"` key / `--codec` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecId {
    Bf16,
    Int8,
    Int4,
}

impl CodecId {
    pub const ALL: [CodecId; 3] = [CodecId::Bf16, CodecId::Int8, CodecId::Int4];

    pub fn as_str(self) -> &'static str {
        match self {
            CodecId::Bf16 => "bf16",
            CodecId::Int8 => "int8",
            CodecId::Int4 => "int4",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<CodecId> {
        match s {
            "bf16" => Ok(CodecId::Bf16),
            "int8" => Ok(CodecId::Int8),
            "int4" => Ok(CodecId::Int4),
            _ => anyhow::bail!("unknown store codec '{s}' (bf16|int8|int4)"),
        }
    }

    /// The codec implementation behind this id.
    pub fn get(self) -> &'static dyn Codec {
        match self {
            CodecId::Bf16 => &Bf16Codec,
            CodecId::Int8 => &Int8Codec,
            CodecId::Int4 => &Int4Codec,
        }
    }
}

/// The v1–v3 layout: raw bf16, 2 bytes per value, no headers.
pub struct Bf16Codec;

impl Codec for Bf16Codec {
    fn id(&self) -> CodecId {
        CodecId::Bf16
    }

    fn encoded_len(&self, n: usize) -> usize {
        n * 2
    }

    fn encode(&self, src: &[f32], dst: &mut Vec<u8>) {
        bf16::encode_slice(src, dst);
    }

    fn decode(&self, src: &[u8], dst: &mut [f32]) {
        bf16::decode_into(src, dst);
    }

    fn max_rel_error(&self) -> f32 {
        // round-to-nearest-even on an 8-bit mantissa: 2^-9 per value;
        // report the truncation-safe 2^-8
        1.0 / 256.0
    }

    fn bytes_per_value(&self) -> f64 {
        2.0
    }
}

/// Shared by the int codecs: quantize one value against a group scale.
/// `scale == 0` means an all-zero group; non-finite scales poison the
/// group to NaN at decode time (`0 * NaN = NaN`), which is exactly the
/// "never prunable" signal the summarizer needs.
#[inline]
pub(crate) fn quantize(x: f32, scale: f32, qmax: f32) -> i8 {
    if scale == 0.0 || !scale.is_finite() || !x.is_finite() {
        return 0;
    }
    (x / scale).round().clamp(-qmax, qmax) as i8
}

/// Scale for a group with the given absmax and quantization ceiling.
/// Non-finite absmax (the group held NaN/Inf) propagates so decodes of
/// the group are NaN rather than silently wrong finite values.
#[inline]
pub(crate) fn group_scale(absmax: f32, qmax: f32) -> f32 {
    if !absmax.is_finite() {
        f32::NAN
    } else {
        absmax / qmax
    }
}

#[inline]
pub(crate) fn absmax(src: &[f32]) -> f32 {
    // fold through abs() so a NaN anywhere in the group survives the
    // max (f32::max ignores NaN operands)
    src.iter().fold(0.0f32, |m, &x| {
        let a = x.abs();
        if a.is_nan() || m.is_nan() {
            f32::NAN
        } else {
            m.max(a)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip(id: CodecId, src: &[f32]) -> Vec<f32> {
        let c = id.get();
        let mut bytes = Vec::new();
        c.encode(src, &mut bytes);
        assert_eq!(bytes.len(), c.encoded_len(src.len()), "{id:?} stride");
        let mut back = vec![0.0f32; src.len()];
        c.decode(&bytes, &mut back);
        back
    }

    #[test]
    fn ids_parse_and_roundtrip() {
        for id in CodecId::ALL {
            assert_eq!(CodecId::parse(id.as_str()).unwrap(), id);
            assert_eq!(id.get().id(), id);
        }
        assert!(CodecId::parse("zip").is_err());
        assert!(CodecId::parse("").is_err());
    }

    #[test]
    fn bf16_codec_matches_util_bf16() {
        let src: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let back = roundtrip(CodecId::Bf16, &src);
        for (a, b) in src.iter().zip(&back) {
            assert_eq!(*b, bf16::bf16_to_f32(bf16::f32_to_bf16(*a)));
        }
    }

    #[test]
    fn every_codec_honours_its_error_contract() {
        let mut rng = Rng::new(7);
        for id in CodecId::ALL {
            let c = id.get();
            for n in [1usize, 2, 31, 32, 33, 64, 200] {
                let src: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 3.0).collect();
                let back = roundtrip(id, &src);
                let m = absmax(&src);
                for (i, (a, b)) in src.iter().zip(&back).enumerate() {
                    assert!(
                        (a - b).abs() <= c.max_rel_error() * m + 1e-30,
                        "{id:?} n={n} i={i}: {a} -> {b} (absmax {m})"
                    );
                }
            }
        }
    }

    #[test]
    fn all_zero_segments_stay_zero() {
        for id in CodecId::ALL {
            let back = roundtrip(id, &[0.0; 37]);
            assert!(back.iter().all(|&x| x == 0.0), "{id:?}");
        }
    }

    #[test]
    fn int_codecs_poison_non_finite_groups_to_nan() {
        for id in [CodecId::Int8, CodecId::Int4] {
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                let mut src = vec![1.0f32; 40];
                src[17] = bad;
                let back = roundtrip(id, &src);
                // the poisoned value itself must not decode to a finite lie
                assert!(back[17].is_nan(), "{id:?} {bad} -> {}", back[17]);
            }
        }
    }

    #[test]
    fn quantize_clamps_and_zero_scale_is_zero() {
        assert_eq!(quantize(5.0, 0.0, 127.0), 0);
        assert_eq!(quantize(1e30, 1.0, 127.0), 127);
        assert_eq!(quantize(-1e30, 1.0, 7.0), -7);
        assert_eq!(quantize(f32::NAN, 1.0, 127.0), 0);
        assert!(group_scale(f32::INFINITY, 127.0).is_nan());
        assert!(absmax(&[1.0, f32::NAN, 2.0]).is_nan());
        assert_eq!(absmax(&[-3.0, 2.0]), 3.0);
    }
}

//! Cluster metadata for reordered (v5) stores.
//!
//! `lorif store recode --cluster k` rewrites a store so each summary
//! chunk holds one tight k-means cluster instead of an arrival-order
//! mixture, which is what makes the centroid/radius bounds in
//! `crate::sketch` bite early (ROADMAP item 3: touch ~1% of the store
//! per query).  The reordering is recorded here:
//!
//!   `perm[storage_pos] = original_index`
//!
//! i.e. the record at storage position `p` of the clustered store is
//! the example the caller knows as `perm[p]`.  Every score/top-k index
//! leaving the executor is mapped through `perm`, so callers never see
//! storage coordinates.
//!
//! The permutation lives in the `<name>.json` manifest as a `"cluster"`
//! object (`{"k": .., "perm": [..]}`) plus `"version": 5`.  `StoreMeta`
//! itself does not carry it — the struct is rebuilt and re-saved by
//! every writer, while the permutation is attached exactly once, after
//! `finalize()`, by the recode pass (`ClusterMeta::attach`).  Readers
//! pick it up via `ClusterMeta::load`, which validates the permutation
//! is a bijection before anything trusts it.

use std::path::Path;

use super::format::StoreMeta;
use crate::util::json::{obj, Value};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterMeta {
    /// number of k-means clusters the recode pass targeted
    pub k: usize,
    /// `perm[storage_pos] = original_index` (bijection over 0..n)
    pub perm: Vec<u32>,
}

impl ClusterMeta {
    /// Original (caller-coordinate) index of the record at `storage`.
    #[inline]
    pub fn original(&self, storage: usize) -> usize {
        self.perm[storage] as usize
    }

    pub fn n_examples(&self) -> usize {
        self.perm.len()
    }

    /// The permutation must be a bijection over exactly `n` examples
    /// and k must be a usable cluster count.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.perm.len() == n,
            "cluster permutation has {} entries, store has {n} examples",
            self.perm.len()
        );
        anyhow::ensure!(
            self.k >= 1 && self.k <= n.max(1),
            "cluster count k={} out of range for {n} examples",
            self.k
        );
        let mut seen = vec![false; n];
        for &p in &self.perm {
            let p = p as usize;
            anyhow::ensure!(p < n, "cluster permutation entry {p} out of range (n={n})");
            anyhow::ensure!(!seen[p], "cluster permutation repeats index {p}");
            seen[p] = true;
        }
        Ok(())
    }

    /// Inverse mapping: `inv[original_index] = storage_pos`.
    pub fn inverse(&self) -> Vec<u32> {
        let mut inv = vec![0u32; self.perm.len()];
        for (storage, &orig) in self.perm.iter().enumerate() {
            inv[orig as usize] = storage as u32;
        }
        inv
    }

    fn to_json(&self) -> Value {
        obj([
            ("k", self.k.into()),
            (
                "perm",
                Value::Arr(self.perm.iter().map(|&p| (p as usize).into()).collect()),
            ),
        ])
    }

    fn from_json(v: &Value) -> anyhow::Result<ClusterMeta> {
        let k = v.req_usize("k")?;
        let perm = v
            .req("perm")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("cluster 'perm' not an array"))?
            .iter()
            .map(|x| {
                x.as_usize()
                    .and_then(|p| u32::try_from(p).ok())
                    .ok_or_else(|| anyhow::anyhow!("bad cluster permutation entry"))
            })
            .collect::<anyhow::Result<Vec<u32>>>()?;
        Ok(ClusterMeta { k, perm })
    }

    /// Read cluster metadata (if any) from the store manifest and
    /// validate it against the declared example count.  `Ok(None)` for
    /// unclustered (v1–v4) stores.
    pub fn load(base: &Path) -> anyhow::Result<Option<ClusterMeta>> {
        let text = std::fs::read_to_string(StoreMeta::meta_path(base))?;
        let doc = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let Some(cv) = doc.get("cluster") else {
            return Ok(None);
        };
        let cm = ClusterMeta::from_json(cv)
            .map_err(|e| anyhow::anyhow!("bad cluster metadata in manifest: {e}"))?;
        cm.validate(doc.req_usize("n_examples")?)?;
        Ok(Some(cm))
    }

    /// Patch the manifest at `base` with this cluster metadata and bump
    /// it to version 5.  Must run AFTER the writer's `finalize()` —
    /// `StoreMeta::save` knows nothing about clustering and would drop
    /// these keys.
    pub fn attach(&self, base: &Path) -> anyhow::Result<()> {
        let path = StoreMeta::meta_path(base);
        let text = std::fs::read_to_string(&path)?;
        let doc = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        self.validate(doc.req_usize("n_examples")?)?;
        let Value::Obj(mut fields) = doc else {
            anyhow::bail!("store manifest is not a json object");
        };
        fields.insert("version".into(), 5usize.into());
        fields.insert("cluster".into(), self.to_json());
        std::fs::write(&path, Value::Obj(fields).to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{CodecId, StoreKind};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("lorif_cluster_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn meta(n: usize) -> StoreMeta {
        StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(4, 4)],
            n_examples: n,
            shards: None,
            summary_chunk: Some(2),
            codec: CodecId::Bf16,
        }
    }

    #[test]
    fn attach_then_load_roundtrips_and_bumps_version() {
        let base = tmp("roundtrip");
        meta(4).save(&base).unwrap();
        assert!(ClusterMeta::load(&base).unwrap().is_none());
        let cm = ClusterMeta { k: 2, perm: vec![2, 3, 0, 1] };
        cm.attach(&base).unwrap();
        let text = std::fs::read_to_string(StoreMeta::meta_path(&base)).unwrap();
        let doc = Value::parse(&text).unwrap();
        assert_eq!(doc.req_usize("version").unwrap(), 5);
        // StoreMeta itself still loads (unknown keys ignored, v5 accepted)
        let m = StoreMeta::load(&base).unwrap();
        assert_eq!(m.n_examples, 4);
        assert_eq!(ClusterMeta::load(&base).unwrap(), Some(cm));
    }

    #[test]
    fn rejects_non_bijective_permutations() {
        let base = tmp("bad_perm");
        meta(3).save(&base).unwrap();
        for perm in [vec![0u32, 1], vec![0, 1, 1], vec![0, 1, 9]] {
            let cm = ClusterMeta { k: 2, perm };
            assert!(cm.attach(&base).is_err(), "accepted a broken permutation");
        }
        // a valid one still attaches after the failures above
        ClusterMeta { k: 3, perm: vec![1, 2, 0] }.attach(&base).unwrap();
        assert!(ClusterMeta::load(&base).unwrap().is_some());
    }

    #[test]
    fn inverse_roundtrips_indices() {
        let cm = ClusterMeta { k: 2, perm: vec![3, 1, 4, 0, 2] };
        cm.validate(5).unwrap();
        let inv = cm.inverse();
        for orig in 0..5 {
            assert_eq!(cm.original(inv[orig] as usize), orig);
        }
    }

    #[test]
    fn rejects_out_of_range_k() {
        let cm = ClusterMeta { k: 0, perm: vec![0, 1] };
        assert!(cm.validate(2).is_err());
        let cm = ClusterMeta { k: 3, perm: vec![0, 1] };
        assert!(cm.validate(2).is_err());
    }
}

//! Bounded, shard-aware decoded-chunk cache for the serving hot path.
//!
//! Query latency is I/O-dominated (Fig 3): every batch the server scores
//! re-reads and re-decodes the same store chunks.  This cache keeps hot
//! chunks (`Arc<Chunk>`) resident under a byte budget — DECODED f32
//! matrices on the classic path, or the raw ENCODED record bytes when a
//! reader streams for a quantized-domain kernel (`StoreReader::encoded`,
//! see `store::codec::quant`), which lets the same budget keep 2–4×
//! more corpus resident on int8/int4 stores.  Keys are
//! `(shard, global_start, count, encoded)` so shards never alias, a
//! pass with a different chunk grid never serves a mis-sized chunk, and
//! the two representations of the same span never serve one another.
//!
//! Eviction is CLOCK (second-chance): each entry carries a referenced
//! bit set on hit; the hand sweeps the slot ring, clearing bits until it
//! finds an unreferenced victim.  One sweep costs O(slots) worst case,
//! entries are chunk-sized (hundreds of KB), so the lock is never held
//! long — a single `Mutex` protects the ring and is shared freely across
//! the scoring workers (`ShardSet` hands an `Arc<ChunkCache>` to every
//! reader it creates).
//!
//! **Exactness**: a hit returns the same decoded bytes a disk read would
//! produce — `decode_chunk` is deterministic and the key pins the exact
//! record span — so cache-backed scoring is bit-identical to cold
//! scoring (property-tested across every kernel x layout in
//! `tests/prop.rs`).  The pruning path (`crate::sketch`) decides skips
//! BEFORE any cache lookup: skipped chunks neither populate nor touch
//! the cache, and a cached chunk never changes a skip decision.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::reader::Chunk;

/// Cache key: (shard index, global start example, example count,
/// encoded-form flag).
pub type ChunkKey = (usize, usize, usize, bool);

/// Point-in-time counters (the server's `stats` endpoint and the bench
/// report read these).
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// resident bytes (decoded f32 matrices plus any encoded payloads)
    pub bytes: u64,
    /// configured byte budget
    pub capacity: u64,
    /// entries currently resident
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slot {
    key: ChunkKey,
    chunk: Arc<Chunk>,
    bytes: u64,
    referenced: bool,
}

#[derive(Default)]
struct Ring {
    map: HashMap<ChunkKey, usize>,
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    hand: usize,
    bytes: u64,
    insertions: u64,
    evictions: u64,
    // hit/miss counters live under the same lock as the ring, so a
    // `stats()` snapshot is always coherent with `insertions`/`entries`
    // (counting them outside the lock let `hits + misses` drift from
    // the insert count under concurrent workers)
    hits: u64,
    misses: u64,
}

impl Ring {
    /// Evict unreferenced entries (clearing referenced bits as the hand
    /// passes) until at least `need` bytes fit under `capacity`.
    fn make_room(&mut self, need: u64, capacity: u64) {
        let n = self.slots.len();
        if n == 0 {
            return;
        }
        // two full sweeps always suffice: the first clears every
        // referenced bit, the second finds a victim
        let mut scanned = 0usize;
        while self.bytes + need > capacity && scanned < 2 * n {
            let i = self.hand;
            self.hand = (self.hand + 1) % n;
            scanned += 1;
            let evict = match &mut self.slots[i] {
                Some(slot) if slot.referenced => {
                    slot.referenced = false;
                    false
                }
                Some(_) => true,
                None => false,
            };
            if evict {
                let slot = self.slots[i].take().expect("victim slot occupied");
                self.map.remove(&slot.key);
                self.bytes -= slot.bytes;
                self.free.push(i);
                self.evictions += 1;
            }
        }
    }

    fn insert(&mut self, key: ChunkKey, chunk: Arc<Chunk>, bytes: u64, capacity: u64) {
        if let Some(&i) = self.map.get(&key) {
            // racing readers decoded the same chunk: keep the resident
            // copy, but give it the same recency credit a hit would —
            // two readers just wanted this span, so evicting it on the
            // next sweep would be exactly wrong
            self.slots[i].as_mut().expect("mapped slot occupied").referenced = true;
            return;
        }
        self.make_room(bytes, capacity);
        if self.bytes + bytes > capacity {
            return; // everything resident is referenced-hot; don't thrash
        }
        let slot = Slot { key, chunk, bytes, referenced: false };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(slot);
                i
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.map.insert(key, i);
        self.bytes += bytes;
        self.insertions += 1;
    }
}

/// See the module docs.  Construct via [`ChunkCache::with_capacity`]
/// (bytes) or [`ChunkCache::from_mb`] (the `--chunk-cache-mb` knob;
/// 0 disables caching by returning `None`).
pub struct ChunkCache {
    capacity: u64,
    ring: Mutex<Ring>,
}

impl ChunkCache {
    pub fn with_capacity(capacity_bytes: u64) -> Arc<ChunkCache> {
        Arc::new(ChunkCache { capacity: capacity_bytes, ring: Mutex::new(Ring::default()) })
    }

    /// The `--chunk-cache-mb` spelling: `None` when `mb == 0` (off).
    pub fn from_mb(mb: usize) -> Option<Arc<ChunkCache>> {
        (mb > 0).then(|| ChunkCache::with_capacity(mb as u64 * 1024 * 1024))
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Look up a chunk; marks the entry recently-used.  The hit/miss
    /// counter is bumped under the same lock that answers the lookup,
    /// so `stats()` never observes a lookup without its counter.
    pub fn get(&self, key: ChunkKey) -> Option<Arc<Chunk>> {
        let mut ring = self.ring.lock().expect("chunk cache lock");
        if let Some(&i) = ring.map.get(&key) {
            ring.hits += 1;
            let slot = ring.slots[i].as_mut().expect("mapped slot occupied");
            slot.referenced = true;
            Some(Arc::clone(&slot.chunk))
        } else {
            ring.misses += 1;
            None
        }
    }

    /// Offer a freshly-fetched chunk (decoded or encoded form; the key
    /// says which).  Oversized chunks (bigger than the whole budget) are
    /// not cached; insertion never blocks readers for longer than one
    /// CLOCK sweep.
    ///
    /// Each insert also publishes its insertion/eviction deltas and the
    /// post-op residency gauges into the scoped metrics registry
    /// (`telemetry::current_registry`), outside the ring lock.  Hits
    /// and misses are NOT published here — they flow through the
    /// streaming ledger (`StreamStats::publish`) so the registry's
    /// cache-hit counters stay coherent with its byte counters.  The
    /// residency gauges assume the usual one-serving-cache-per-scope
    /// deployment; two caches publishing into one registry would
    /// interleave last-writer-wins snapshots.
    pub fn insert(&self, key: ChunkKey, chunk: &Arc<Chunk>) {
        let bytes = chunk.resident_bytes();
        if bytes == 0 || bytes > self.capacity {
            return;
        }
        let (inserted, evicted, resident, entries) = {
            let mut ring = self.ring.lock().expect("chunk cache lock");
            let (ins0, ev0) = (ring.insertions, ring.evictions);
            ring.insert(key, Arc::clone(chunk), bytes, self.capacity);
            (
                ring.insertions - ins0,
                ring.evictions - ev0,
                ring.bytes,
                ring.map.len() as u64,
            )
        };
        let reg = crate::telemetry::current_registry();
        reg.cache_insertions.add(inserted);
        reg.cache_evictions.add(evicted);
        reg.cache_resident_bytes.set(resident);
        reg.cache_capacity_bytes.set(self.capacity);
        reg.cache_entries.set(entries);
    }

    /// Publish the residency gauges (capacity, resident bytes, entries)
    /// into `reg`.  The executor calls this at pass start so a
    /// configured but not-yet-populated cache scrapes with its real
    /// capacity instead of 0 — without it the gauges would only appear
    /// as a side effect of the first insert.
    pub fn publish_gauges(&self, reg: &crate::telemetry::Registry) {
        let (bytes, entries) = {
            let ring = self.ring.lock().expect("chunk cache lock");
            (ring.bytes, ring.map.len() as u64)
        };
        reg.cache_capacity_bytes.set(self.capacity);
        reg.cache_resident_bytes.set(bytes);
        reg.cache_entries.set(entries);
    }

    pub fn stats(&self) -> CacheStats {
        let ring = self.ring.lock().expect("chunk cache lock");
        CacheStats {
            hits: ring.hits,
            misses: ring.misses,
            insertions: ring.insertions,
            evictions: ring.evictions,
            bytes: ring.bytes,
            capacity: self.capacity,
            entries: ring.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::store::ChunkLayer;
    use std::time::Duration;

    fn chunk(start: usize, count: usize, cols: usize) -> Arc<Chunk> {
        Arc::new(Chunk {
            start,
            count,
            layers: vec![ChunkLayer::Dense { g: Mat::zeros(count, cols) }],
            encoded: None,
            io_time: Duration::ZERO,
        })
    }

    fn encoded_chunk(start: usize, count: usize, bytes: usize) -> Arc<Chunk> {
        Arc::new(Chunk {
            start,
            count,
            layers: Vec::new(),
            encoded: Some(vec![0u8; bytes]),
            io_time: Duration::ZERO,
        })
    }

    #[test]
    fn hit_returns_the_same_decoded_chunk() {
        let cache = ChunkCache::with_capacity(1 << 20);
        let c = chunk(0, 4, 8);
        cache.insert((0, 0, 4, false), &c);
        let got = cache.get((0, 0, 4, false)).expect("hit");
        assert!(Arc::ptr_eq(&got, &c), "cache must serve the same decoded data");
        assert!(cache.get((1, 0, 4, false)).is_none(), "shard is part of the key");
        assert!(cache.get((0, 0, 5, false)).is_none(), "count is part of the key");
        assert!(cache.get((0, 0, 4, true)).is_none(), "encoded form is part of the key");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 3, 1));
        assert!(s.hit_rate() > 0.2 && s.hit_rate() < 0.3);
    }

    #[test]
    fn byte_budget_is_respected_under_eviction() {
        // each chunk: 4 * 8 floats = 128 B; budget fits exactly 3
        let cache = ChunkCache::with_capacity(3 * 128);
        for i in 0..10 {
            cache.insert((0, i * 4, 4, false), &chunk(i * 4, 4, 8));
            let s = cache.stats();
            assert!(s.bytes <= s.capacity, "over budget: {} > {}", s.bytes, s.capacity);
        }
        let s = cache.stats();
        assert_eq!(s.bytes, 3 * 128);
        assert_eq!(s.entries, 3);
        assert_eq!(s.insertions, 10);
        assert_eq!(s.evictions, 7);
    }

    #[test]
    fn encoded_chunks_budget_by_their_byte_size() {
        // encoded int8/int4 payloads are a fraction of the decoded f32
        // size: the same budget must hold proportionally more of them
        let cache = ChunkCache::with_capacity(3 * 128);
        for i in 0..12 {
            cache.insert((0, i * 4, 4, true), &encoded_chunk(i * 4, 4, 32));
        }
        let s = cache.stats();
        assert_eq!(s.bytes, 12 * 32, "all twelve 32 B encoded chunks fit");
        assert_eq!(s.entries, 12);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn clock_gives_hot_entries_a_second_chance() {
        let cache = ChunkCache::with_capacity(2 * 128);
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        cache.insert((0, 4, 4, false), &chunk(4, 4, 8));
        // touch the first entry: its referenced bit protects it from the
        // next eviction sweep
        assert!(cache.get((0, 0, 4, false)).is_some());
        cache.insert((0, 8, 4, false), &chunk(8, 4, 8));
        assert!(cache.get((0, 0, 4, false)).is_some(), "hot entry evicted");
        assert!(cache.get((0, 4, 4, false)).is_none(), "cold entry kept");
        assert!(cache.get((0, 8, 4, false)).is_some());
    }

    #[test]
    fn duplicate_insert_gives_the_resident_entry_recency_credit() {
        // two racing readers decode the same span; the second insert is
        // a no-op for the map but must set the referenced bit, exactly
        // like a hit — otherwise the chunk both readers just wanted is
        // the next CLOCK victim
        let cache = ChunkCache::with_capacity(2 * 128);
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        cache.insert((0, 4, 4, false), &chunk(4, 4, 8));
        // duplicate insert (no get: the referenced bit comes from the
        // insert path alone)
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        assert_eq!(cache.stats().insertions, 2, "duplicate must not re-insert");
        // the sweep for a third chunk must evict the un-referenced
        // entry, not the one the duplicate insert marked hot
        cache.insert((0, 8, 4, false), &chunk(8, 4, 8));
        assert!(cache.get((0, 0, 4, false)).is_some(), "duplicated entry evicted");
        assert!(cache.get((0, 4, 4, false)).is_none(), "cold entry kept instead");
    }

    #[test]
    fn stats_snapshot_is_coherent_under_concurrent_lookups() {
        // the reader protocol is miss-then-insert; with the hit/miss
        // counters under the ring lock, a miss is counted BEFORE its
        // insert can land, so every snapshot satisfies
        // insertions <= misses (counting the miss after dropping the
        // lock let snapshots observe an insert with no recorded miss —
        // the flaky `hit_rate` assertions in the serving tests)
        let cache = ChunkCache::with_capacity(1 << 30);
        let per_thread = 300usize;
        let workers: Vec<_> = (0..4)
            .map(|t| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let key = (t, i * 4, 4, false);
                        if cache.get(key).is_none() {
                            cache.insert(key, &chunk(i * 4, 4, 8));
                        }
                        let _ = cache.get(key); // one guaranteed hit
                    }
                })
            })
            .collect();
        for _ in 0..200 {
            let s = cache.stats();
            assert!(
                s.insertions <= s.misses,
                "snapshot saw an insert with no recorded miss: {} inserts, {} misses",
                s.insertions,
                s.misses
            );
        }
        for w in workers {
            w.join().unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.misses, 4 * per_thread as u64);
        assert_eq!(s.hits, 4 * per_thread as u64);
        assert_eq!(s.insertions, 4 * per_thread as u64);
    }

    #[test]
    fn oversized_and_duplicate_inserts_are_ignored() {
        let cache = ChunkCache::with_capacity(100);
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8)); // 128 B > 100
        assert_eq!(cache.stats().insertions, 0);
        let cache = ChunkCache::with_capacity(1 << 20);
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        assert_eq!(cache.stats().insertions, 1);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn from_mb_zero_disables() {
        assert!(ChunkCache::from_mb(0).is_none());
        let c = ChunkCache::from_mb(2).unwrap();
        assert_eq!(c.capacity(), 2 * 1024 * 1024);
    }

    #[test]
    fn publish_gauges_seeds_capacity_for_a_cold_cache() {
        // a configured but never-inserted cache must scrape with its
        // real capacity, not 0 (gauges used to appear only on insert)
        let reg = crate::telemetry::Registry::new();
        let cache = ChunkCache::with_capacity(3 * 128);
        cache.publish_gauges(&reg);
        assert_eq!(reg.cache_capacity_bytes.get(), 3 * 128);
        assert_eq!(reg.cache_resident_bytes.get(), 0);
        assert_eq!(reg.cache_entries.get(), 0);
        // and after population it reports the live residency
        cache.insert((0, 0, 4, false), &chunk(0, 4, 8));
        cache.publish_gauges(&reg);
        assert_eq!(reg.cache_resident_bytes.get(), 128);
        assert_eq!(reg.cache_entries.get(), 1);
    }

    #[test]
    fn inserts_publish_deltas_and_gauges_into_the_scoped_registry() {
        let reg = Arc::new(crate::telemetry::Registry::new());
        crate::telemetry::with_registry(reg.clone(), || {
            // budget fits exactly 3 of the 128 B chunks
            let cache = ChunkCache::with_capacity(3 * 128);
            for i in 0..5 {
                cache.insert((0, i * 4, 4, false), &chunk(i * 4, 4, 8));
            }
            let s = cache.stats();
            // registry counters mirror the cache's own ledger exactly
            assert_eq!(reg.cache_insertions.get(), s.insertions);
            assert_eq!(reg.cache_evictions.get(), s.evictions);
            assert_eq!(reg.cache_resident_bytes.get(), s.bytes);
            assert_eq!(reg.cache_capacity_bytes.get(), s.capacity);
            assert_eq!(reg.cache_entries.get(), s.entries as u64);
        });
    }
}

//! Bounded, shard-aware decoded-chunk cache for the serving hot path.
//!
//! Query latency is I/O-dominated (Fig 3): every batch the server scores
//! re-reads and re-decodes the same store chunks.  This cache keeps hot
//! DECODED chunks (`Arc<Chunk>`, the post-bf16 f32 matrices scorers
//! consume) resident under a byte budget, keyed by
//! `(shard, global_start, count)` so shards never alias and a pass with
//! a different chunk grid never serves a mis-sized chunk.
//!
//! Eviction is CLOCK (second-chance): each entry carries a referenced
//! bit set on hit; the hand sweeps the slot ring, clearing bits until it
//! finds an unreferenced victim.  One sweep costs O(slots) worst case,
//! entries are chunk-sized (hundreds of KB), so the lock is never held
//! long — a single `Mutex` protects the ring and is shared freely across
//! the scoring workers (`ShardSet` hands an `Arc<ChunkCache>` to every
//! reader it creates).
//!
//! **Exactness**: a hit returns the same decoded bytes a disk read would
//! produce — `decode_chunk` is deterministic and the key pins the exact
//! record span — so cache-backed scoring is bit-identical to cold
//! scoring (property-tested across every kernel x layout in
//! `tests/prop.rs`).  The pruning path (`crate::sketch`) decides skips
//! BEFORE any cache lookup: skipped chunks neither populate nor touch
//! the cache, and a cached chunk never changes a skip decision.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::reader::Chunk;

/// Cache key: (shard index, global start example, example count).
pub type ChunkKey = (usize, usize, usize);

/// Point-in-time counters (the server's `stats` endpoint and the bench
/// report read these).
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// decoded bytes currently resident
    pub bytes: u64,
    /// configured byte budget
    pub capacity: u64,
    /// entries currently resident
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slot {
    key: ChunkKey,
    chunk: Arc<Chunk>,
    bytes: u64,
    referenced: bool,
}

#[derive(Default)]
struct Ring {
    map: HashMap<ChunkKey, usize>,
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    hand: usize,
    bytes: u64,
    insertions: u64,
    evictions: u64,
}

impl Ring {
    /// Evict unreferenced entries (clearing referenced bits as the hand
    /// passes) until at least `need` bytes fit under `capacity`.
    fn make_room(&mut self, need: u64, capacity: u64) {
        let n = self.slots.len();
        if n == 0 {
            return;
        }
        // two full sweeps always suffice: the first clears every
        // referenced bit, the second finds a victim
        let mut scanned = 0usize;
        while self.bytes + need > capacity && scanned < 2 * n {
            let i = self.hand;
            self.hand = (self.hand + 1) % n;
            scanned += 1;
            let evict = match &mut self.slots[i] {
                Some(slot) if slot.referenced => {
                    slot.referenced = false;
                    false
                }
                Some(_) => true,
                None => false,
            };
            if evict {
                let slot = self.slots[i].take().expect("victim slot occupied");
                self.map.remove(&slot.key);
                self.bytes -= slot.bytes;
                self.free.push(i);
                self.evictions += 1;
            }
        }
    }

    fn insert(&mut self, key: ChunkKey, chunk: Arc<Chunk>, bytes: u64, capacity: u64) {
        if self.map.contains_key(&key) {
            return; // racing readers decoded the same chunk: keep one
        }
        self.make_room(bytes, capacity);
        if self.bytes + bytes > capacity {
            return; // everything resident is referenced-hot; don't thrash
        }
        let slot = Slot { key, chunk, bytes, referenced: false };
        let i = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(slot);
                i
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.map.insert(key, i);
        self.bytes += bytes;
        self.insertions += 1;
    }
}

/// See the module docs.  Construct via [`ChunkCache::with_capacity`]
/// (bytes) or [`ChunkCache::from_mb`] (the `--chunk-cache-mb` knob;
/// 0 disables caching by returning `None`).
pub struct ChunkCache {
    capacity: u64,
    ring: Mutex<Ring>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ChunkCache {
    pub fn with_capacity(capacity_bytes: u64) -> Arc<ChunkCache> {
        Arc::new(ChunkCache {
            capacity: capacity_bytes,
            ring: Mutex::new(Ring::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    /// The `--chunk-cache-mb` spelling: `None` when `mb == 0` (off).
    pub fn from_mb(mb: usize) -> Option<Arc<ChunkCache>> {
        (mb > 0).then(|| ChunkCache::with_capacity(mb as u64 * 1024 * 1024))
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Look up a decoded chunk; marks the entry recently-used.
    pub fn get(&self, key: ChunkKey) -> Option<Arc<Chunk>> {
        let mut ring = self.ring.lock().expect("chunk cache lock");
        if let Some(&i) = ring.map.get(&key) {
            let slot = ring.slots[i].as_mut().expect("mapped slot occupied");
            slot.referenced = true;
            let chunk = Arc::clone(&slot.chunk);
            drop(ring);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(chunk)
        } else {
            drop(ring);
            self.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Offer a freshly-decoded chunk.  Oversized chunks (bigger than the
    /// whole budget) are not cached; insertion never blocks readers for
    /// longer than one CLOCK sweep.
    pub fn insert(&self, key: ChunkKey, chunk: &Arc<Chunk>) {
        let bytes = chunk.decoded_bytes();
        if bytes == 0 || bytes > self.capacity {
            return;
        }
        let mut ring = self.ring.lock().expect("chunk cache lock");
        ring.insert(key, Arc::clone(chunk), bytes, self.capacity);
    }

    pub fn stats(&self) -> CacheStats {
        let ring = self.ring.lock().expect("chunk cache lock");
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: ring.insertions,
            evictions: ring.evictions,
            bytes: ring.bytes,
            capacity: self.capacity,
            entries: ring.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::store::ChunkLayer;
    use std::time::Duration;

    fn chunk(start: usize, count: usize, cols: usize) -> Arc<Chunk> {
        Arc::new(Chunk {
            start,
            count,
            layers: vec![ChunkLayer::Dense { g: Mat::zeros(count, cols) }],
            io_time: Duration::ZERO,
        })
    }

    #[test]
    fn hit_returns_the_same_decoded_chunk() {
        let cache = ChunkCache::with_capacity(1 << 20);
        let c = chunk(0, 4, 8);
        cache.insert((0, 0, 4), &c);
        let got = cache.get((0, 0, 4)).expect("hit");
        assert!(Arc::ptr_eq(&got, &c), "cache must serve the same decoded data");
        assert!(cache.get((1, 0, 4)).is_none(), "shard is part of the key");
        assert!(cache.get((0, 0, 5)).is_none(), "count is part of the key");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 2, 1));
        assert!(s.hit_rate() > 0.3 && s.hit_rate() < 0.4);
    }

    #[test]
    fn byte_budget_is_respected_under_eviction() {
        // each chunk: 4 * 8 floats = 128 B; budget fits exactly 3
        let cache = ChunkCache::with_capacity(3 * 128);
        for i in 0..10 {
            cache.insert((0, i * 4, 4), &chunk(i * 4, 4, 8));
            let s = cache.stats();
            assert!(s.bytes <= s.capacity, "over budget: {} > {}", s.bytes, s.capacity);
        }
        let s = cache.stats();
        assert_eq!(s.bytes, 3 * 128);
        assert_eq!(s.entries, 3);
        assert_eq!(s.insertions, 10);
        assert_eq!(s.evictions, 7);
    }

    #[test]
    fn clock_gives_hot_entries_a_second_chance() {
        let cache = ChunkCache::with_capacity(2 * 128);
        cache.insert((0, 0, 4), &chunk(0, 4, 8));
        cache.insert((0, 4, 4), &chunk(4, 4, 8));
        // touch the first entry: its referenced bit protects it from the
        // next eviction sweep
        assert!(cache.get((0, 0, 4)).is_some());
        cache.insert((0, 8, 4), &chunk(8, 4, 8));
        assert!(cache.get((0, 0, 4)).is_some(), "hot entry evicted");
        assert!(cache.get((0, 4, 4)).is_none(), "cold entry kept");
        assert!(cache.get((0, 8, 4)).is_some());
    }

    #[test]
    fn oversized_and_duplicate_inserts_are_ignored() {
        let cache = ChunkCache::with_capacity(100);
        cache.insert((0, 0, 4), &chunk(0, 4, 8)); // 128 B > 100
        assert_eq!(cache.stats().insertions, 0);
        let cache = ChunkCache::with_capacity(1 << 20);
        cache.insert((0, 0, 4), &chunk(0, 4, 8));
        cache.insert((0, 0, 4), &chunk(0, 4, 8));
        assert_eq!(cache.stats().insertions, 1);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn from_mb_zero_disables() {
        assert!(ChunkCache::from_mb(0).is_none());
        let c = ChunkCache::from_mb(2).unwrap();
        assert_eq!(c.capacity(), 2 * 1024 * 1024);
    }
}

//! Gradient store: the persistent per-example index (paper's central
//! storage/IO bottleneck).  bf16 fixed-stride records + JSON sidecar;
//! dense (LoGRA) and rank-c factored (LoRIF) layouts share one reader.

pub mod format;
pub mod reader;
pub mod writer;

pub use format::{StoreKind, StoreMeta};
pub use reader::{Chunk, ChunkLayer, StoreReader};
pub use writer::StoreWriter;

//! Gradient store: the persistent per-example index (paper's central
//! storage/IO bottleneck).  Fixed-stride codec-encoded records + JSON
//! sidecar; dense (LoGRA) and rank-c factored (LoRIF) layouts share one
//! reader.
//!
//! Stores come in four on-disk layouts: v1 (one `.grads` file), v2
//! (contiguous `.shard{i}.grads` files + a shard manifest), v3 (either
//! of the above plus a `.summaries` pruning sidecar, see
//! `crate::sketch`), v4 (any of the above with records encoded
//! through a non-default codec, see [`codec`]), and v5 (records
//! reordered by a streaming k-means pass so each summary chunk is one
//! tight cluster; the original→clustered permutation lives in the
//! manifest, see [`cluster`]).  `ShardSet` opens all of them; the v2
//! layout feeds the parallel scoring path in `query::parallel`, the v3
//! sidecar lets top-k queries skip chunk reads entirely, the v4 codecs
//! shrink the bytes every remaining read costs, and the v5 reordering
//! turns the sidecar into a retrieval tier (best-first chunk visits in
//! `attribution::exec`).  [`recode`] converts any existing store
//! between codecs, shard layouts, clusterings, and manifest versions in
//! one bounded-memory streaming pass (`lorif store recode`) and powers
//! `lorif store inspect`.
//!
//! On top of the readers sits the chunk cache (`cache`): a
//! byte-budgeted, shard-aware CLOCK cache of chunks that the serving
//! path shares across scoring workers so hot store spans are read (and,
//! on the decoded path, decoded) once, not once per batch.  A chunk is
//! cached in whichever form the query pipeline scored it — decoded f32
//! matrices, or raw encoded bytes when quantized-domain scoring is
//! active ([`codec::quant`], the `--quant-score` knob; encoded
//! residency is 2–4× denser for the int codecs).  The two forms never
//! alias (the cache key carries the form), each entry's budget charge
//! is its actual resident bytes (`Chunk::resident_bytes`), and
//! `bytes_read` stays the on-disk (encoded) count either way, so
//! cached ≡ cold scoring is preserved per codec and per scoring mode.

pub mod cache;
pub mod cluster;
pub mod codec;
pub mod format;
pub mod reader;
pub mod recode;
pub mod writer;

pub use cache::{CacheStats, ChunkCache};
pub use cluster::ClusterMeta;
pub use codec::{
    Bf16Codec, Codec, CodecId, Int4Codec, Int8Codec, QuantPlan, QuantScore, QuantScratch,
    INT4_GROUP,
};
pub use format::{StoreKind, StoreMeta};
pub use reader::{
    Chunk, ChunkCursor, ChunkLayer, ShardSet, ShardSpan, StoreReader, StreamStats,
    DEFAULT_PREFETCH_DEPTH,
};
pub use recode::{inspect_store, recode_store, RecodeOptions, RecodeReport, StoreInspection};
pub use writer::{ShardedWriter, StoreWriter};

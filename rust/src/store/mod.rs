//! Gradient store: the persistent per-example index (paper's central
//! storage/IO bottleneck).  bf16 fixed-stride records + JSON sidecar;
//! dense (LoGRA) and rank-c factored (LoRIF) layouts share one reader.
//!
//! Stores come in three on-disk layouts: v1 (one `.grads` file), v2
//! (contiguous `.shard{i}.grads` files + a shard manifest), and v3
//! (either of the above plus a `.summaries` pruning sidecar, see
//! `crate::sketch`).  `ShardSet` opens all of them; the v2 layout feeds
//! the parallel scoring path in `query::parallel`, the v3 sidecar lets
//! top-k queries skip chunk reads entirely.
//!
//! On top of the readers sits the decoded-chunk cache (`cache`): a
//! byte-budgeted, shard-aware CLOCK cache of decoded chunks that the
//! serving path shares across scoring workers so hot store spans are
//! read and bf16-decoded once, not once per batch.

pub mod cache;
pub mod format;
pub mod reader;
pub mod writer;

pub use cache::{CacheStats, ChunkCache};
pub use format::{StoreKind, StoreMeta};
pub use reader::{
    Chunk, ChunkCursor, ChunkLayer, ShardSet, ShardSpan, StoreReader, StreamStats,
    DEFAULT_PREFETCH_DEPTH,
};
pub use writer::{ShardedWriter, StoreWriter};

//! Gradient store: the persistent per-example index (paper's central
//! storage/IO bottleneck).  bf16 fixed-stride records + JSON sidecar;
//! dense (LoGRA) and rank-c factored (LoRIF) layouts share one reader.
//!
//! Stores come in two on-disk layouts: v1 (one `.grads` file) and v2
//! (contiguous `.shard{i}.grads` files + a shard manifest).  `ShardSet`
//! opens both; the v2 layout feeds the parallel scoring path in
//! `query::parallel`.

pub mod format;
pub mod reader;
pub mod writer;

pub use format::{StoreKind, StoreMeta};
pub use reader::{Chunk, ChunkLayer, ShardSet, ShardSpan, StoreReader};
pub use writer::{ShardedWriter, StoreWriter};

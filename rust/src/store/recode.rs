//! Streaming store migration (`lorif store recode`) and the
//! `lorif store inspect` report.
//!
//! `recode_store` converts an existing store between codecs, shard
//! layouts, and manifest versions in ONE bounded-memory pass: the
//! source streams chunk by chunk through the regular `ShardSet` reader
//! (so any v1–v4 layout is a valid input), each decoded chunk is
//! re-encoded through the target codec by `append_chunk`, and the
//! target writer rebuilds the `.summaries` sidecar from the freshly
//! encoded bytes as records stream through — every store already on
//! disk migrates without re-running gradient extraction, and the
//! regenerated summaries are exact for the NEW bytes (plus the codec
//! guard, `sketch::summary`).
//!
//! `--cluster k` adds a REORDERING migration on top: a bounded-memory
//! streaming k-means pass (a few Lloyd iterations, each one full stream
//! of the source) assigns every example to one of `k` clusters, the
//! records are rewritten grouped by cluster (so each summary chunk is
//! one tight cluster and the centroid/radius bounds in `crate::sketch`
//! bite early), and the original→clustered permutation is attached to
//! the manifest as v5 cluster metadata ([`super::cluster`]).  A plain
//! recode of an already-clustered source preserves record order and
//! re-attaches the source's permutation, so the v5 contract survives
//! codec and shard migrations.
//!
//! Peak memory is one decoded chunk (`chunk_size` records of f32) plus
//! the writer's single-record scratch, independent of the store size —
//! clustering adds the k centroids/accumulators and the n-length
//! assignment it exists to produce.

use std::fmt;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::cluster::ClusterMeta;
use super::codec::{Codec, CodecId};
use super::format::{StoreKind, StoreMeta};
use super::reader::{ChunkLayer, ShardSet};
use super::writer::{ShardedWriter, StoreWriter};
use crate::sketch::DEFAULT_SUMMARY_CHUNK;

/// What `recode_store` should change.  Every `None` keeps the source
/// store's setting, so a plain re-shard preserves the codec and a
/// plain codec migration preserves the shard layout and summary grid.
pub struct RecodeOptions {
    /// Target record codec; `None` keeps the source codec.
    pub codec: Option<CodecId>,
    /// Target shard count (`Some(1)` = v1 single file; `Some(s >= 2)` =
    /// v2 layout); `None` keeps the source layout.
    pub shards: Option<usize>,
    /// Target summary grid (`Some(0)` drops the sidecar entirely);
    /// `None` keeps the source grid (or its absence).
    pub summary_chunk: Option<usize>,
    /// Records decoded per streaming step (bounds peak memory).
    pub chunk_size: usize,
    /// Reorder records by a streaming k-means pass into this many
    /// clusters (`--cluster k`), writing a v5 store whose manifest
    /// carries the original→clustered permutation.  `None` leaves the
    /// record order alone (and preserves an existing permutation).
    pub cluster: Option<usize>,
}

impl Default for RecodeOptions {
    fn default() -> RecodeOptions {
        RecodeOptions {
            codec: None,
            shards: None,
            summary_chunk: None,
            chunk_size: DEFAULT_SUMMARY_CHUNK,
            cluster: None,
        }
    }
}

/// Resolve a store base for the in-place check: canonicalize the
/// parent directory (which exists for any openable source, and may not
/// yet for the target — in which case the target cannot collide with
/// the source anyway) and re-attach the final component, so `./store`
/// vs `store`, relative vs absolute spellings, and symlinked
/// directories all compare equal.
fn resolved_base(base: &Path) -> PathBuf {
    let parent = match base.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.canonicalize().ok(),
        // a bare file name lives in the current directory
        _ => std::env::current_dir().ok(),
    };
    match (parent, base.file_name()) {
        (Some(dir), Some(name)) => dir.join(name),
        _ => base.to_path_buf(),
    }
}

/// Would writing a store at `dst` clobber the store at `src`?  Path
/// resolution catches spelling differences; the filesystem-identity
/// check on the manifests catches what resolution cannot — leaf-name
/// symlinks and case-insensitive filesystems, where `Store.json` and
/// `store.json` are one file with two unequal paths.
fn is_same_store(src: &Path, dst: &Path) -> bool {
    if resolved_base(src) == resolved_base(dst) {
        return true;
    }
    let a = StoreMeta::meta_path(src);
    let b = StoreMeta::meta_path(dst);
    #[cfg(unix)]
    {
        use std::os::unix::fs::MetadataExt;
        if let (Ok(ma), Ok(mb)) = (std::fs::metadata(&a), std::fs::metadata(&b)) {
            return ma.dev() == mb.dev() && ma.ino() == mb.ino();
        }
    }
    // non-unix fallback: both manifests exist and canonicalize to one
    // path (an absent target manifest can never be the source's)
    matches!((a.canonicalize(), b.canonicalize()), (Ok(ca), Ok(cb)) if ca == cb)
}

/// What a migration did (printed by the CLI, asserted by tests).
#[derive(Debug)]
pub struct RecodeReport {
    pub n_examples: usize,
    pub kind: StoreKind,
    pub src_codec: CodecId,
    pub dst_codec: CodecId,
    /// on-disk data bytes before/after (manifest strides × examples)
    pub src_bytes: u64,
    pub dst_bytes: u64,
    pub shards: Option<Vec<usize>>,
    pub summary_chunk: Option<usize>,
    /// cluster count when the target carries v5 cluster metadata
    /// (freshly clustered, or carried through from a clustered source)
    pub cluster: Option<usize>,
    pub version: usize,
    pub wall: Duration,
}

impl RecodeReport {
    /// Size ratio of the migration (>1 means the target is smaller).
    pub fn shrink(&self) -> f64 {
        self.src_bytes as f64 / self.dst_bytes.max(1) as f64
    }
}

/// Lloyd iterations the clustering pass runs; each is one full stream
/// of the source store.  Fixed (not convergence-tested) so the pass
/// cost is predictable and the permutation deterministic.
const KMEANS_PASSES: usize = 4;

/// Feature row for k-means: the example's decoded record with all
/// layers concatenated (dense rows, or U then V for factored stores) —
/// the same vectors the summary sidecar summarizes, so tight k-means
/// clusters become tight centroid/radius bounds.
fn record_features(chunk: &super::reader::Chunk, ex: usize, out: &mut Vec<f32>) {
    out.clear();
    for layer in &chunk.layers {
        match layer {
            ChunkLayer::Dense { g } => out.extend_from_slice(g.row(ex)),
            ChunkLayer::Factored { u, v } => {
                out.extend_from_slice(u.row(ex));
                out.extend_from_slice(v.row(ex));
            }
        }
    }
}

/// Bounded-memory streaming k-means over the source store.  Memory is
/// the k centroids and accumulators plus the n-length assignment this
/// function exists to produce — never the store.  Deterministic:
/// centroids start at k evenly spaced records and every pass streams in
/// storage order, so one source always yields one permutation.
fn cluster_permutation(
    set: &ShardSet,
    k: usize,
    chunk_size: usize,
) -> anyhow::Result<ClusterMeta> {
    let n = set.meta.n_examples;
    anyhow::ensure!(k >= 1, "--cluster needs k >= 1 (omit the flag to keep arrival order)");
    anyhow::ensure!(k <= n, "--cluster k={k} exceeds the store's {n} examples");
    let dim = set.meta.decoded_bytes_per_example() / 4;
    let mut feat = Vec::with_capacity(dim);
    let mut centroids = vec![0.0f32; k * dim];
    for j in 0..k {
        let chunk = set.read_range(j * n / k, 1)?;
        record_features(&chunk, 0, &mut feat);
        centroids[j * dim..(j + 1) * dim].copy_from_slice(&feat);
    }
    let mut assign = vec![0u32; n];
    for _pass in 0..KMEANS_PASSES {
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0u64; k];
        set.stream(chunk_size, false, |chunk| {
            for ex in 0..chunk.count {
                record_features(chunk, ex, &mut feat);
                // non-finite records would poison every centroid they
                // touch; park them in cluster 0 without accumulating
                // (the summarizer marks their chunks never-skippable
                // anyway, so their placement costs nothing)
                if !feat.iter().all(|x| x.is_finite()) {
                    assign[chunk.start + ex] = 0;
                    continue;
                }
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for j in 0..k {
                    let c = &centroids[j * dim..(j + 1) * dim];
                    let mut d = 0.0f64;
                    for (a, b) in feat.iter().zip(c) {
                        let t = (*a - *b) as f64;
                        d += t * t;
                    }
                    if d < best_d {
                        best_d = d;
                        best = j;
                    }
                }
                assign[chunk.start + ex] = best as u32;
                counts[best] += 1;
                let s = &mut sums[best * dim..(best + 1) * dim];
                for (acc, &x) in s.iter_mut().zip(feat.iter()) {
                    *acc += x as f64;
                }
            }
            Ok(())
        })?;
        for j in 0..k {
            // empty clusters keep their previous centroid
            if counts[j] > 0 {
                let s = &sums[j * dim..(j + 1) * dim];
                for (c, &acc) in centroids[j * dim..(j + 1) * dim].iter_mut().zip(s) {
                    *c = (acc / counts[j] as f64) as f32;
                }
            }
        }
    }
    // storage order: by (cluster, original index) — stable within a
    // cluster, so ascending original runs survive and the permuted
    // write below can batch its ranged reads
    let mut perm: Vec<u32> = (0..n as u32).collect();
    perm.sort_by_key(|&i| (assign[i as usize], i));
    let cm = ClusterMeta { k, perm };
    cm.validate(n)?;
    Ok(cm)
}

/// One-pass migration; see the module docs.  `src` and `dst` are store
/// base paths; recoding in place is refused (the pass reads the source
/// while writing the target).
pub fn recode_store(
    src: &Path,
    dst: &Path,
    opts: &RecodeOptions,
) -> anyhow::Result<RecodeReport> {
    // a target that aliases the source under any spelling, symlink, or
    // case-insensitive filesystem would have its data files truncated
    // by the writer while the reader streams them
    anyhow::ensure!(
        !is_same_store(src, dst),
        "recode in place is not supported: pick a different output base"
    );
    anyhow::ensure!(opts.chunk_size >= 1, "chunk_size must be >= 1");
    let t0 = Instant::now();
    let set = ShardSet::open(src)?;
    let src_meta = set.meta.clone();

    let summary_chunk = opts
        .summary_chunk
        .unwrap_or_else(|| src_meta.summary_chunk.unwrap_or(0));

    // clustering: compute the permutation up front (it streams the
    // source a few times) so a rejected request never creates target
    // files.  Re-clustering a clustered store is refused — permutations
    // do not compose across recodes, and the caller's coordinates would
    // silently shift.
    let src_cluster = set.cluster().cloned();
    let cluster = match opts.cluster {
        None => None,
        Some(k) => {
            anyhow::ensure!(
                src_cluster.is_none(),
                "source store is already clustered; recode it without --cluster first"
            );
            anyhow::ensure!(
                summary_chunk >= 1,
                "--cluster requires a summary grid in the output (the sidecar is the \
                 retrieval tier); drop --summary-chunk 0 or pick a grid"
            );
            Some(cluster_permutation(&set, k, opts.chunk_size)?)
        }
    };

    let mut meta = src_meta.clone();
    meta.codec = opts.codec.unwrap_or(src_meta.codec);
    meta.n_examples = 0;
    meta.shards = None;
    meta.summary_chunk = None;

    enum Target {
        Mono(StoreWriter),
        Sharded(ShardedWriter),
    }
    // `shards: None` preserves the source layout EXACTLY — the planned
    // writer replays the source's own shard counts (which may deviate
    // from the uniform ceil rule, e.g. after mid-extraction drops), and
    // a v2 manifest stays v2 even with a single shard.  An explicit
    // count re-buckets with the uniform stage-1 rule.
    let mut w = match (opts.shards, &src_meta.shards) {
        (None, Some(counts)) => {
            let mut w = ShardedWriter::create_planned(dst, meta, counts.clone())?;
            w.set_summary_chunk(summary_chunk)?;
            Target::Sharded(w)
        }
        (Some(s), _) if s >= 2 => {
            let mut w = ShardedWriter::create(dst, meta, s, src_meta.n_examples)?;
            w.set_summary_chunk(summary_chunk)?;
            Target::Sharded(w)
        }
        (shards, _) => {
            anyhow::ensure!(shards != Some(0), "shards must be >= 1");
            let mut w = StoreWriter::create(dst, meta)?;
            w.set_summary_chunk(summary_chunk)?;
            Target::Mono(w)
        }
    };

    match &cluster {
        None => set.stream(opts.chunk_size, true, |chunk| match &mut w {
            Target::Mono(w) => w.append_chunk(chunk),
            Target::Sharded(w) => w.append_chunk(chunk),
        })?,
        Some(cm) => {
            // permuted write: walk storage order, folding maximal runs
            // of consecutive ORIGINAL indices into one ranged read
            // (within a cluster originals stay ascending, so runs are
            // the common case, not the lucky one)
            let n = src_meta.n_examples;
            let mut pos = 0usize;
            while pos < n {
                let orig = cm.perm[pos] as usize;
                let mut len = 1usize;
                while pos + len < n
                    && len < opts.chunk_size
                    && cm.perm[pos + len] as usize == orig + len
                {
                    len += 1;
                }
                let chunk = set.read_range(orig, len)?;
                match &mut w {
                    Target::Mono(w) => w.append_chunk(&chunk),
                    Target::Sharded(w) => w.append_chunk(&chunk),
                }?;
                pos += len;
            }
        }
    }

    let new_meta = match w {
        Target::Mono(w) => w.finalize()?,
        Target::Sharded(w) => w.finalize()?,
    };
    anyhow::ensure!(
        new_meta.n_examples == src_meta.n_examples,
        "recode wrote {} of {} examples",
        new_meta.n_examples,
        src_meta.n_examples
    );
    // attach AFTER finalize: the writers re-save the manifest and know
    // nothing about cluster keys.  A plain recode of a clustered source
    // preserves record order, so the source permutation still holds and
    // is carried through.
    let attached = match (&cluster, &src_cluster) {
        (Some(cm), _) | (None, Some(cm)) => {
            cm.attach(dst)?;
            Some(cm.k)
        }
        (None, None) => None,
    };
    Ok(RecodeReport {
        n_examples: new_meta.n_examples,
        kind: new_meta.kind,
        src_codec: src_meta.codec,
        dst_codec: new_meta.codec,
        src_bytes: src_meta.total_bytes(),
        dst_bytes: new_meta.total_bytes(),
        shards: new_meta.shards.clone(),
        summary_chunk: new_meta.summary_chunk,
        cluster: attached,
        version: if attached.is_some() { 5 } else { new_meta.version() },
        wall: t0.elapsed(),
    })
}

/// Everything `lorif store inspect <base>` reports.  Opening goes
/// through `ShardSet::open`, so a store that inspects cleanly also
/// passes every manifest/size/sidecar validation — which is what makes
/// `inspect` double as the post-`recode` verification tool.
pub struct StoreInspection {
    pub meta: StoreMeta,
    pub version: usize,
    /// per shard file: path, on-disk bytes, example count
    pub shard_files: Vec<(PathBuf, u64, usize)>,
    /// total on-disk data bytes (encoded)
    pub on_disk_bytes: u64,
    /// total decoded f32 bytes the same records occupy in memory
    pub decoded_bytes: u64,
    /// `.summaries` sidecar: (grid, chunk count, examples covered,
    /// sidecar file bytes) when present
    pub summaries: Option<(usize, usize, usize, u64)>,
    /// v5 clustering tier: `(k, permutation entries)` when present
    pub cluster: Option<(usize, usize)>,
    /// per-chunk centroid radii (layer radii summed) from the sidecar —
    /// the cluster-tightness signal the report histograms
    pub chunk_radii: Vec<f32>,
}

pub fn inspect_store(base: &Path) -> anyhow::Result<StoreInspection> {
    let set = ShardSet::open(base)?;
    let meta = set.meta.clone();
    let mut shard_files = Vec::new();
    let mut on_disk = 0u64;
    for i in 0..set.n_shards() {
        let span = set.shard(i);
        let bytes = std::fs::metadata(&span.path)?.len();
        on_disk += bytes;
        shard_files.push((span.path.clone(), bytes, span.count));
    }
    let summaries = match set.summaries() {
        None => None,
        Some(s) => {
            let covered: usize = s.chunks.iter().map(|c| c.count).sum();
            let bytes = std::fs::metadata(StoreMeta::summaries_path(base))?.len();
            Some((s.chunk_size, s.chunks.len(), covered, bytes))
        }
    };
    let cluster = set.cluster().map(|c| (c.k, c.perm.len()));
    let chunk_radii = set
        .summaries()
        .map(|s| {
            s.chunks
                .iter()
                .map(|c| c.layers.iter().map(|l| l.radius).sum::<f32>())
                .collect()
        })
        .unwrap_or_default();
    Ok(StoreInspection {
        version: if cluster.is_some() { 5 } else { meta.version() },
        on_disk_bytes: on_disk,
        decoded_bytes: meta.decoded_bytes_per_example() as u64 * meta.n_examples as u64,
        meta,
        shard_files,
        summaries,
        cluster,
        chunk_radii,
    })
}

impl fmt::Display for StoreInspection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let m = &self.meta;
        writeln!(
            f,
            "store v{} | kind {} | codec {} | tier {} | f={} c={} | {} examples",
            self.version,
            m.kind.as_str(),
            m.codec.as_str(),
            m.tier,
            m.f,
            m.c,
            m.n_examples
        )?;
        writeln!(
            f,
            "layers: {}",
            m.layers
                .iter()
                .map(|&(a, b)| format!("({a}, {b})"))
                .collect::<Vec<_>>()
                .join(" ")
        )?;
        writeln!(
            f,
            "record: {} B encoded ({} B/example decoded, {:.2} B/value payload)",
            m.bytes_per_example(),
            m.decoded_bytes_per_example(),
            m.codec.get().bytes_per_value()
        )?;
        writeln!(
            f,
            "on disk {:.3} MB encoded vs {:.3} MB decoded ({:.2}x)",
            self.on_disk_bytes as f64 / 1e6,
            self.decoded_bytes as f64 / 1e6,
            self.decoded_bytes as f64 / self.on_disk_bytes.max(1) as f64
        )?;
        match m.shards {
            None => writeln!(f, "layout: v1 single file")?,
            Some(_) => writeln!(f, "layout: v2 sharded ({} files)", self.shard_files.len())?,
        }
        for (i, (path, bytes, count)) in self.shard_files.iter().enumerate() {
            writeln!(
                f,
                "  shard {i}: {count} examples, {bytes} B ({})",
                path.display()
            )?;
        }
        match self.summaries {
            None => writeln!(f, "summaries: none (queries always full-scan)")?,
            Some((grid, chunks, covered, bytes)) => writeln!(
                f,
                "summaries: grid {grid} | {chunks} chunks covering {covered}/{} examples \
                 | sidecar {bytes} B",
                m.n_examples
            )?,
        }
        match self.cluster {
            None => writeln!(
                f,
                "cluster: none (arrival order; `store recode --cluster k` builds the \
                 v5 retrieval tier)"
            )?,
            Some((k, entries)) => {
                writeln!(f, "cluster: k={k} | permutation {entries} entries")?
            }
        }
        if !self.chunk_radii.is_empty() {
            // 8-bucket histogram of per-chunk radii: a clustered store
            // piles its chunks into the low buckets
            let lo = self.chunk_radii.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = self.chunk_radii.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let span = (hi - lo).max(f32::MIN_POSITIVE);
            let mut buckets = [0usize; 8];
            for &r in &self.chunk_radii {
                let b = (((r - lo) / span) * 8.0) as usize;
                buckets[b.min(7)] += 1;
            }
            writeln!(
                f,
                "chunk radii: min {lo:.4} | max {hi:.4} | histogram {buckets:?}"
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::runtime::{ExtractBatch, LayerGrads};
    use crate::util::prng::Rng;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("lorif_recode_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_source(name: &str, kind: StoreKind, n: usize, shards: usize) -> PathBuf {
        let layers = vec![(6usize, 8usize), (4, 4)];
        let c = 2;
        let mut rng = Rng::new(7);
        let lg: Vec<LayerGrads> = layers
            .iter()
            .map(|&(d1, d2)| LayerGrads {
                g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                u: Mat::random_normal(n, d1 * c, 1.0, &mut rng),
                v: Mat::random_normal(n, d2 * c, 1.0, &mut rng),
            })
            .collect();
        let batch = ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n };
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers,
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let base = tmp(name);
        if shards <= 1 {
            let mut w = StoreWriter::create(&base, meta).unwrap();
            w.set_summary_chunk(5).unwrap();
            w.append(&batch).unwrap();
            w.finalize().unwrap();
        } else {
            let mut w = ShardedWriter::create(&base, meta, shards, n).unwrap();
            w.set_summary_chunk(5).unwrap();
            w.append(&batch).unwrap();
            w.finalize().unwrap();
        }
        base
    }

    fn collect(base: &Path) -> Vec<f32> {
        let set = ShardSet::open(base).unwrap();
        let mut out = Vec::new();
        set.stream(7, false, |chunk| {
            for layer in &chunk.layers {
                match layer {
                    crate::store::ChunkLayer::Dense { g } => out.extend(g.data.iter()),
                    crate::store::ChunkLayer::Factored { u, v } => {
                        out.extend(u.data.iter());
                        out.extend(v.data.iter());
                    }
                }
            }
            Ok(())
        })
        .unwrap();
        out
    }

    #[test]
    fn recode_to_int8_preserves_structure_and_shrinks() {
        let src = write_source("r_src_sharded", StoreKind::Dense, 23, 3);
        let dst = tmp("r_dst_int8");
        let rep = recode_store(
            &src,
            &dst,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.n_examples, 23);
        assert_eq!(rep.kind, StoreKind::Dense);
        assert_eq!(rep.src_codec, CodecId::Bf16);
        assert_eq!(rep.dst_codec, CodecId::Int8);
        assert_eq!(rep.version, 4);
        assert!(rep.shrink() > 1.5, "shrink {}", rep.shrink());
        // layout preserved: same shard counts, same summary grid
        let src_meta = StoreMeta::load(&src).unwrap();
        let dst_meta = StoreMeta::load(&dst).unwrap();
        assert_eq!(dst_meta.shards, src_meta.shards);
        assert_eq!(dst_meta.summary_chunk, src_meta.summary_chunk);
        // values within the codec error of the source decode
        let a = collect(&src);
        let b = collect(&dst);
        assert_eq!(a.len(), b.len());
        let m = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let rel = CodecId::Int8.get().max_rel_error();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= rel * m + 1e-30, "{x} vs {y}");
        }
    }

    #[test]
    fn recode_reshards_and_regrids() {
        let src = write_source("r_src_mono", StoreKind::Factored, 19, 1);
        let dst = tmp("r_dst_resharded");
        let rep = recode_store(
            &src,
            &dst,
            &RecodeOptions {
                codec: Some(CodecId::Bf16),
                shards: Some(4),
                summary_chunk: Some(3),
                chunk_size: 4,
            },
        )
        .unwrap();
        assert_eq!(rep.shards.as_ref().map(|s| s.len()), Some(4));
        assert_eq!(rep.summary_chunk, Some(3));
        assert_eq!(rep.version, 3, "bf16 resharded store stays pre-v4");
        // bf16 -> bf16 is byte-exact on the record level
        assert_eq!(collect(&src), collect(&dst));
        // and back to a v1 store with no sidecar
        let dst2 = tmp("r_dst_flat");
        let rep = recode_store(
            &dst,
            &dst2,
            &RecodeOptions {
                codec: Some(CodecId::Bf16),
                shards: Some(1),
                summary_chunk: Some(0),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(rep.shards, None);
        assert_eq!(rep.summary_chunk, None);
        assert_eq!(rep.version, 1);
        assert_eq!(collect(&src), collect(&dst2));
    }

    #[test]
    fn recode_preserves_non_uniform_shard_layouts() {
        // shard counts the uniform ceil rule cannot produce (the shape
        // mid-extraction drops leave behind): keeping the layout must
        // replay them EXACTLY, not re-bucket; and a v2 single-shard
        // manifest must stay v2, not flatten to v1
        let layers = vec![(4usize, 4usize)];
        let mut rng = Rng::new(23);
        for plan in [vec![2usize, 6, 3], vec![11]] {
            let n: usize = plan.iter().sum();
            let meta = StoreMeta {
                kind: StoreKind::Dense,
                tier: "small".into(),
                f: 4,
                c: 1,
                layers: layers.clone(),
                n_examples: 0,
                shards: None,
                summary_chunk: None,
                codec: CodecId::Bf16,
            };
            let src = tmp(&format!("r_plan_src_{}", plan.len()));
            let mut w = ShardedWriter::create_planned(&src, meta, plan.clone()).unwrap();
            w.set_summary_chunk(4).unwrap();
            let lg = vec![LayerGrads {
                g: Mat::random_normal(n, 16, 1.0, &mut rng),
                u: Mat::zeros(n, 4),
                v: Mat::zeros(n, 4),
            }];
            w.append(&ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n })
                .unwrap();
            let src_meta = w.finalize().unwrap();
            assert_eq!(src_meta.shards, Some(plan.clone()), "planned writer layout");

            let dst = tmp(&format!("r_plan_dst_{}", plan.len()));
            let rep = recode_store(
                &src,
                &dst,
                &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
            )
            .unwrap();
            assert_eq!(rep.shards, Some(plan.clone()), "layout re-bucketed");
            assert_eq!(StoreMeta::load(&dst).unwrap().shards, Some(plan.clone()));
            assert_eq!(rep.version, 4);
            // records land in the same global order
            assert_eq!(collect(&src).len(), collect(&dst).len());
        }
    }

    #[test]
    fn recode_refuses_in_place_even_under_different_spellings() {
        let src = write_source("r_inplace", StoreKind::Dense, 8, 1);
        let err = recode_store(&src, &src, &RecodeOptions::default()).unwrap_err();
        assert!(format!("{err}").contains("in place"), "{err}");
        // a different spelling of the same base must not slip past the
        // guard and truncate the source mid-read
        let parent = src.parent().unwrap();
        let dotted = parent.join(".").join(src.file_name().unwrap());
        assert_ne!(src, dotted, "raw paths differ by construction");
        let err = recode_store(&src, &dotted, &RecodeOptions::default()).unwrap_err();
        assert!(format!("{err}").contains("in place"), "{err}");
        // a target whose manifest is a symlink to the source's (the
        // aliasing path resolution can't see) must also be refused
        #[cfg(unix)]
        {
            let alias = parent.join("r_inplace_alias");
            let _ = std::fs::remove_file(StoreMeta::meta_path(&alias));
            std::os::unix::fs::symlink(
                StoreMeta::meta_path(&src),
                StoreMeta::meta_path(&alias),
            )
            .unwrap();
            let err = recode_store(&src, &alias, &RecodeOptions::default()).unwrap_err();
            assert!(format!("{err}").contains("in place"), "{err}");
            let _ = std::fs::remove_file(StoreMeta::meta_path(&alias));
        }
        // and the source is still intact and openable
        assert!(ShardSet::open(&src).is_ok());
    }

    #[test]
    fn recode_without_codec_keeps_the_source_codec() {
        // resharding a quantized store must not silently transcode it
        let src = write_source("r_keep_codec_src", StoreKind::Dense, 12, 1);
        let i8_base = tmp("r_keep_codec_i8");
        recode_store(
            &src,
            &i8_base,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        let resharded = tmp("r_keep_codec_resharded");
        let rep = recode_store(
            &i8_base,
            &resharded,
            &RecodeOptions { shards: Some(3), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.src_codec, CodecId::Int8);
        assert_eq!(rep.dst_codec, CodecId::Int8, "omitted --codec transcoded the store");
        assert_eq!(StoreMeta::load(&resharded).unwrap().codec, CodecId::Int8);
        // int8 -> int8 re-encoding keeps every quantized integer; only
        // the f32 scale may wobble by an ulp, so values match to ~2^-22
        let a = collect(&i8_base);
        let b = collect(&resharded);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= x.abs() * 3e-7 + 1e-30, "{x} vs {y}");
        }
    }

    /// Examples alternate between two far-apart blobs, so k-means with
    /// k = 2 must untangle the parities.  `n/2` is kept odd by callers
    /// so the two evenly spaced init centroids land in DIFFERENT blobs.
    fn write_two_blob_source(name: &str, n: usize) -> PathBuf {
        let mut rng = Rng::new(11);
        let mut g = Mat::zeros(n, 8);
        for i in 0..n {
            let center = if i % 2 == 0 { 10.0 } else { -10.0 };
            for x in g.row_mut(i) {
                *x = center + 0.01 * rng.normal() as f32;
            }
        }
        let lg = vec![LayerGrads { g, u: Mat::zeros(n, 2), v: Mat::zeros(n, 4) }];
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: vec![(2, 4)],
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: CodecId::Bf16,
        };
        let base = tmp(name);
        let mut w = StoreWriter::create(&base, meta).unwrap();
        w.set_summary_chunk(5).unwrap();
        w.append(&ExtractBatch { losses: vec![0.0; n], layers: lg, valid: n }).unwrap();
        w.finalize().unwrap();
        base
    }

    #[test]
    fn cluster_recode_groups_blobs_and_records_the_permutation() {
        let src = write_two_blob_source("r_cluster_src", 10);
        let dst = tmp("r_cluster_dst");
        let rep = recode_store(
            &src,
            &dst,
            &RecodeOptions { cluster: Some(2), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.cluster, Some(2));
        assert_eq!(rep.version, 5);
        let cm = ClusterMeta::load(&dst).unwrap().expect("permutation attached");
        assert_eq!(cm.k, 2);
        cm.validate(10).unwrap();
        // each half of the storage order is one parity blob, originals
        // ascending within it (the stable sort)
        for half in [&cm.perm[..5], &cm.perm[5..]] {
            let parity = half[0] % 2;
            assert!(half.iter().all(|&p| p % 2 == parity), "blobs mixed: {:?}", cm.perm);
            assert!(half.windows(2).all(|w| w[0] < w[1]), "not stable: {:?}", cm.perm);
        }
        // the record at storage position p IS original example perm[p]
        // (bf16 -> bf16 is byte-exact)
        let s = ShardSet::open(&src).unwrap();
        let d = ShardSet::open(&dst).unwrap();
        for p in 0..10 {
            let want = s.read_range(cm.perm[p] as usize, 1).unwrap();
            let got = d.read_range(p, 1).unwrap();
            match (&want.layers[0], &got.layers[0]) {
                (ChunkLayer::Dense { g: a }, ChunkLayer::Dense { g: b }) => {
                    assert_eq!(a.data, b.data, "storage {p}");
                }
                _ => panic!("unexpected layer shape"),
            }
        }
        // inspect reports the tier
        let text = format!("{}", inspect_store(&dst).unwrap());
        assert!(text.contains("store v5"), "{text}");
        assert!(text.contains("cluster: k=2 | permutation 10 entries"), "{text}");
        assert!(text.contains("chunk radii:"), "{text}");
    }

    #[test]
    fn cluster_recode_rejects_bad_requests_cleanly() {
        let src = write_two_blob_source("r_cluster_rej", 10);
        for (opts, msg) in [
            (RecodeOptions { cluster: Some(0), ..Default::default() }, "k >= 1"),
            (RecodeOptions { cluster: Some(11), ..Default::default() }, "exceeds"),
            (
                RecodeOptions {
                    cluster: Some(2),
                    summary_chunk: Some(0),
                    ..Default::default()
                },
                "summary grid",
            ),
        ] {
            let dst = tmp("r_cluster_rej_dst");
            let err = recode_store(&src, &dst, &opts).unwrap_err();
            assert!(format!("{err}").contains(msg), "{err}");
            // rejected before any target file was created
            assert!(StoreMeta::load(&dst).is_err(), "rejection left target files");
        }
        // re-clustering a clustered store is refused
        let clustered = tmp("r_cluster_rej_clustered");
        recode_store(
            &src,
            &clustered,
            &RecodeOptions { cluster: Some(2), ..Default::default() },
        )
        .unwrap();
        let dst = tmp("r_cluster_rej_dst2");
        let err = recode_store(
            &clustered,
            &dst,
            &RecodeOptions { cluster: Some(2), ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err}").contains("already clustered"), "{err}");
    }

    #[test]
    fn plain_recode_carries_the_permutation_through() {
        let src = write_two_blob_source("r_carry_src", 10);
        let clustered = tmp("r_carry_clustered");
        recode_store(
            &src,
            &clustered,
            &RecodeOptions { cluster: Some(2), ..Default::default() },
        )
        .unwrap();
        let before = ClusterMeta::load(&clustered).unwrap().unwrap();
        // codec migration of a clustered store preserves record order,
        // so the permutation must ride along and the store stay v5
        let dst = tmp("r_carry_int8");
        let rep = recode_store(
            &clustered,
            &dst,
            &RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() },
        )
        .unwrap();
        assert_eq!(rep.cluster, Some(2));
        assert_eq!(rep.version, 5);
        assert_eq!(ClusterMeta::load(&dst).unwrap().unwrap(), before);
    }

    #[test]
    fn inspect_reports_layout_and_coverage() {
        let src = write_source("r_inspect", StoreKind::Dense, 23, 3);
        let insp = inspect_store(&src).unwrap();
        assert_eq!(insp.version, 3);
        assert_eq!(insp.shard_files.len(), 3);
        assert_eq!(insp.shard_files.iter().map(|s| s.2).sum::<usize>(), 23);
        assert_eq!(insp.on_disk_bytes, insp.meta.total_bytes());
        assert_eq!(insp.decoded_bytes, 23 * insp.meta.decoded_bytes_per_example() as u64);
        let (grid, _, covered, _) = insp.summaries.unwrap();
        assert_eq!(grid, 5);
        assert_eq!(covered, 23);
        let text = format!("{insp}");
        assert!(text.contains("codec bf16"), "{text}");
        assert!(text.contains("v2 sharded"), "{text}");
        // the int8 migration shows up in the report
        let dst = tmp("r_inspect_int8");
        let opts = RecodeOptions { codec: Some(CodecId::Int8), ..Default::default() };
        recode_store(&src, &dst, &opts).unwrap();
        let text = format!("{}", inspect_store(&dst).unwrap());
        assert!(text.contains("codec int8"), "{text}");
        assert!(text.contains("store v4"), "{text}");
    }
}

//! Store readers: sequential batched reads with optional prefetch.
//!
//! `StoreReader` streams one data file (a v1 store, or a single shard of
//! a v2 store) and reports example indices in GLOBAL coordinates.  The
//! prefetch thread reads the next chunk from disk while the scorer
//! consumes the current one, overlapping I/O and compute — the reader
//! reports the two times separately, which is what Figure 3 plots.
//!
//! Both streaming paths (`StoreReader::stream` and the skip-aware
//! `ChunkCursor`) consult the optional decoded-chunk cache
//! (`super::cache`) before touching the disk: a hit serves the resident
//! `Arc<Chunk>` and seeks past the bytes, a miss decodes and populates.
//! Hit/miss/byte counters land on `StreamStats`; `bytes_read` stays the
//! LOGICAL byte count (disk + cache), so the pruning invariant
//! `bytes_read + bytes_skipped == full-scan bytes` holds with or without
//! a cache, and `bytes_from_cache` says how much of it never hit disk.
//!
//! `ShardSet` opens a whole store (either layout), validates every data
//! file against the manifest, and hands out per-shard readers for the
//! parallel query path (`query::parallel`).

use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::cache::ChunkCache;
use super::cluster::ClusterMeta;
use super::codec::Codec;
use super::format::{StoreKind, StoreMeta};
use crate::linalg::Mat;
use crate::sketch::StoreSummaries;

/// A chunk of consecutive examples, in one of two forms: DECODED
/// (per-layer f32 matrices, the classic path) or ENCODED (the raw
/// codec bytes, for kernels that score in the quantized domain —
/// `ChunkKernel::supports_encoded` / `store::codec::quant`).
pub struct Chunk {
    /// global index of the first example in this chunk
    pub start: usize,
    pub count: usize,
    /// per layer: matrices with `count` rows (empty in encoded form)
    pub layers: Vec<ChunkLayer>,
    /// raw encoded record bytes (`count * bytes_per_example`), present
    /// only when the reader streamed in encoded mode
    pub encoded: Option<Vec<u8>>,
    /// wall time spent decoding this chunk (the streaming passes report
    /// their full read+decode time separately, via `fetch_chunk`)
    pub io_time: Duration,
}

pub enum ChunkLayer {
    Dense { g: Mat },
    Factored { u: Mat, v: Mat },
}

impl Chunk {
    /// Decoded in-memory footprint (the f32 matrices).
    pub fn decoded_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| match l {
                ChunkLayer::Dense { g } => g.data.len(),
                ChunkLayer::Factored { u, v } => u.data.len() + v.data.len(),
            })
            .sum::<usize>() as u64
            * 4
    }

    /// Actual resident footprint — decoded matrices plus any encoded
    /// payload.  This is the byte unit the chunk cache budgets against:
    /// encoded int8/int4 chunks cost 2–4× less than their decoded form,
    /// so the same budget keeps proportionally more corpus resident.
    pub fn resident_bytes(&self) -> u64 {
        self.decoded_bytes() + self.encoded.as_ref().map_or(0, |e| e.len() as u64)
    }
}

impl ChunkLayer {
    pub fn dense(&self) -> &Mat {
        match self {
            ChunkLayer::Dense { g } => g,
            _ => panic!("expected dense layer"),
        }
    }

    pub fn factors(&self) -> (&Mat, &Mat) {
        match self {
            ChunkLayer::Factored { u, v } => (u, v),
            _ => panic!("expected factored layer"),
        }
    }
}

/// Decode `raw` (a whole number of records) into a chunk starting at
/// global example index `start`.  Shared by the streaming readers and
/// the writer-side summarizer (`crate::sketch::summary`), so bound
/// statistics are computed from exactly the values scorers see.  All
/// byte offsets go through the store's codec (`store::codec`): the
/// cache, the scorers, and the summaries only ever see the decoded f32
/// values, so a codec changes bytes on disk, never scoring code.
pub(crate) fn decode_chunk(meta: &StoreMeta, start: usize, raw: &[u8]) -> anyhow::Result<Chunk> {
    let stride = meta.bytes_per_example();
    let count = raw.len() / stride;
    let codec = meta.codec.get();
    let t0 = Instant::now();
    let mut layers = Vec::with_capacity(meta.layers.len());
    for (l, &(d1, d2)) in meta.layers.iter().enumerate() {
        let (off, _) = meta.layer_span(l)?;
        match meta.kind {
            StoreKind::Dense => {
                let blen = codec.encoded_len(d1 * d2);
                let mut g = Mat::zeros(count, d1 * d2);
                for ex in 0..count {
                    let base = ex * stride + off;
                    codec.decode(&raw[base..base + blen], g.row_mut(ex));
                }
                layers.push(ChunkLayer::Dense { g });
            }
            StoreKind::Factored => {
                let cu = d1 * meta.c;
                let cv = d2 * meta.c;
                let ulen = codec.encoded_len(cu);
                let vlen = codec.encoded_len(cv);
                let mut u = Mat::zeros(count, cu);
                let mut v = Mat::zeros(count, cv);
                for ex in 0..count {
                    let base = ex * stride + off;
                    codec.decode(&raw[base..base + ulen], u.row_mut(ex));
                    codec.decode(&raw[base + ulen..base + ulen + vlen], v.row_mut(ex));
                }
                layers.push(ChunkLayer::Factored { u, v });
            }
        }
    }
    Ok(Chunk { start, count, layers, encoded: None, io_time: t0.elapsed() })
}

/// Wrap a raw span as an ENCODED chunk: no decode, layers stay empty.
/// Only kernels that opted in (`ChunkKernel::supports_encoded`) ever see
/// these; they score the codec bytes directly (`store::codec::quant`).
pub(crate) fn encoded_chunk(meta: &StoreMeta, start: usize, raw: &[u8]) -> Chunk {
    let count = raw.len() / meta.bytes_per_example();
    Chunk {
        start,
        count,
        layers: Vec::new(),
        encoded: Some(raw.to_vec()),
        io_time: Duration::ZERO,
    }
}

/// Resolve one chunk span for every streaming path (sync, prefetch
/// thread, skip-aware cursor): serve the decoded chunk from `cache`
/// (seeking `file` past the on-disk bytes) or read + decode + populate.
/// Returns `(chunk, from_cache, io)` where `io` is the wall time this
/// fetch spent on the file + decode (a hit contributes only its seek).
/// Keeping the protocol in one place means a change to it (seek
/// behavior, insert policy, accounting) cannot drift between the three
/// call sites.
fn fetch_chunk(
    meta: &StoreMeta,
    cache: Option<&Arc<ChunkCache>>,
    key: super::cache::ChunkKey,
    file: &mut std::fs::File,
    raw: &mut Vec<u8>,
    global_start: usize,
    nbytes: usize,
    encoded: bool,
) -> anyhow::Result<(Arc<Chunk>, bool, Duration)> {
    let t0 = Instant::now();
    if let Some(cached) = cache.and_then(|c| c.get(key)) {
        file.seek(SeekFrom::Current(nbytes as i64))?;
        return Ok((cached, true, t0.elapsed()));
    }
    raw.resize(nbytes, 0);
    file.read_exact(raw)?;
    let chunk = if encoded {
        Arc::new(encoded_chunk(meta, global_start, raw))
    } else {
        Arc::new(decode_chunk(meta, global_start, raw)?)
    };
    if let Some(cache) = cache {
        cache.insert(key, &chunk);
    }
    Ok((chunk, false, t0.elapsed()))
}

/// Reader over one data file holding examples [start, start + count).
pub struct StoreReader {
    pub meta: StoreMeta,
    path: PathBuf,
    /// global index of this file's first example (0 for a v1 store)
    pub start: usize,
    /// number of examples in this file
    pub count: usize,
    /// bounded prefetch queue depth (chunks in flight), >= 1
    pub prefetch_depth: usize,
    /// shard index within the owning store (0 for a v1 store); part of
    /// the chunk-cache key so shards never alias
    pub shard: usize,
    /// decoded-chunk cache consulted before every disk read
    pub cache: Option<Arc<ChunkCache>>,
    /// stream ENCODED chunks (raw codec bytes, no decode) instead of
    /// decoded f32 matrices — set by the executor when the active kernel
    /// scores in the quantized domain.  Part of the chunk-cache key, so
    /// the two forms of the same span never serve one another.
    pub encoded: bool,
}

impl StoreReader {
    /// Open a v1 (single-file) store.  Sharded stores must be opened
    /// with [`ShardSet::open`].
    pub fn open(base: &Path) -> anyhow::Result<StoreReader> {
        let meta = StoreMeta::load(base)?;
        anyhow::ensure!(
            meta.shards.is_none(),
            "sharded store manifest: open it with ShardSet::open"
        );
        let path = StoreMeta::data_path(base);
        let size = std::fs::metadata(&path)?.len();
        anyhow::ensure!(
            size == meta.total_bytes(),
            "store size mismatch: {} vs expected {}",
            size,
            meta.total_bytes()
        );
        let count = meta.n_examples;
        Ok(StoreReader {
            meta,
            path,
            start: 0,
            count,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            shard: 0,
            cache: None,
            encoded: false,
        })
    }

    /// Stream this file's examples in chunks of `chunk_size`, calling `f`
    /// for each.  Chunk `start` fields are global example indices.
    /// Returns `(io_time, stats)`: `io_time` covers read+decode (cache
    /// hits contribute only their seek), `stats.bytes_read` is the
    /// LOGICAL byte count with `stats.bytes_from_cache` of it served
    /// from the decoded-chunk cache.
    pub fn stream(
        &self,
        chunk_size: usize,
        prefetch: bool,
        mut f: impl FnMut(&Chunk) -> anyhow::Result<()>,
    ) -> anyhow::Result<(Duration, StreamStats)> {
        let n = self.count;
        let mut stats = StreamStats::default();
        if n == 0 {
            return Ok((Duration::ZERO, stats));
        }
        let stride = self.meta.bytes_per_example();
        let global_off = self.start;
        if !prefetch {
            let mut file = std::fs::File::open(&self.path)?;
            let mut io_total = Duration::ZERO;
            let mut start = 0usize;
            let mut raw = Vec::with_capacity(chunk_size * stride);
            while start < n {
                let count = chunk_size.min(n - start);
                let key = (self.shard, global_off + start, count, self.encoded);
                let (chunk, from_cache, io) = fetch_chunk(
                    &self.meta,
                    self.cache.as_ref(),
                    key,
                    &mut file,
                    &mut raw,
                    global_off + start,
                    count * stride,
                    self.encoded,
                )?;
                io_total += io;
                stats.note_read((count * stride) as u64, from_cache, self.cache.is_some());
                f(&chunk)?;
                start += count;
            }
            return Ok((io_total, stats));
        }

        // prefetch thread: reads + decodes (or cache-resolves) ahead,
        // bounded queue of `prefetch_depth` chunks (`--prefetch-depth`);
        // each message carries the producer-side fetch time and whether
        // the chunk came from the cache
        let (tx, rx) = mpsc::sync_channel::<anyhow::Result<(Arc<Chunk>, bool, Duration)>>(
            self.prefetch_depth.max(1),
        );
        let meta = self.meta.clone();
        let path = self.path.clone();
        let cache = self.cache.clone();
        let shard = self.shard;
        let encoded = self.encoded;
        // Carry the caller's telemetry scope (registry override + trace
        // context) across the thread boundary, mirroring util::pool::run:
        // fetch_chunk publishes cache metrics via current_registry(), which
        // would otherwise resolve to the process-global registry here.
        let ctx = crate::telemetry::current_ctx();
        let handle = std::thread::spawn(move || {
            crate::telemetry::with_ctx(ctx, move || {
                let run = || -> anyhow::Result<()> {
                    let mut file = std::fs::File::open(&path)?;
                    let mut start = 0usize;
                    let mut raw = Vec::new();
                    while start < n {
                        let count = chunk_size.min(n - start);
                        let key = (shard, global_off + start, count, encoded);
                        let msg = fetch_chunk(
                            &meta,
                            cache.as_ref(),
                            key,
                            &mut file,
                            &mut raw,
                            global_off + start,
                            count * stride,
                            encoded,
                        )?;
                        if tx.send(Ok(msg)).is_err() {
                            return Ok(()); // consumer hung up
                        }
                        start += count;
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    let _ = tx.send(Err(e));
                }
            })
        });

        let mut io_total = Duration::ZERO;
        for msg in rx {
            let (chunk, from_cache, io) = msg?;
            io_total += io;
            stats.note_read((chunk.count * stride) as u64, from_cache, self.cache.is_some());
            f(&chunk)?;
        }
        handle.join().map_err(|_| anyhow::anyhow!("prefetch thread panicked"))?;
        Ok((io_total, stats))
    }

    /// Read a specific contiguous range of GLOBAL example indices, which
    /// must lie inside this file (used by tests and diagnostics).
    pub fn read_range(&self, start: usize, count: usize) -> anyhow::Result<Chunk> {
        anyhow::ensure!(
            start >= self.start && start + count <= self.start + self.count,
            "range out of bounds"
        );
        let stride = self.meta.bytes_per_example();
        let mut file = std::fs::File::open(&self.path)?;
        file.seek(SeekFrom::Start(((start - self.start) * stride) as u64))?;
        let mut raw = vec![0u8; count * stride];
        file.read_exact(&mut raw)?;
        decode_chunk(&self.meta, start, &raw)
    }

    /// Chunk-at-a-time cursor over this file: [`ChunkCursor::peek`] the
    /// next span, then [`ChunkCursor::read`] it or [`ChunkCursor::skip`]
    /// past it without touching the bytes.  This is the skip-aware
    /// streaming primitive behind chunk pruning (`crate::sketch`); it
    /// has no prefetch thread because skip decisions depend on consumer
    /// state (the top-k heaps) fed back chunk by chunk.
    pub fn chunks(&self, chunk_size: usize) -> anyhow::Result<ChunkCursor<'_>> {
        anyhow::ensure!(chunk_size >= 1, "chunk_size must be >= 1");
        Ok(ChunkCursor {
            reader: self,
            file: std::fs::File::open(&self.path)?,
            pos: 0,
            chunk_size,
            raw: Vec::new(),
            io: Duration::ZERO,
            stats: StreamStats::default(),
        })
    }
}

/// Default prefetch queue depth (chunks in flight) — overridable via
/// the `--prefetch-depth` config/CLI knob.
pub const DEFAULT_PREFETCH_DEPTH: usize = 2;

/// Byte/chunk accounting of a streaming pass.  `bytes_read` is the
/// LOGICAL byte count delivered to the consumer (disk + cache), so
/// `bytes_read + bytes_skipped` equals the full-scan byte count whether
/// or not a chunk cache is attached; `bytes_from_cache` is the portion
/// of `bytes_read` that never hit disk.  Hit/miss counters stay 0 when
/// no cache is attached.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    pub bytes_read: u64,
    pub bytes_skipped: u64,
    pub chunks_read: usize,
    pub chunks_skipped: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
    pub bytes_from_cache: u64,
}

impl StreamStats {
    /// Account one delivered chunk — the single place the hit/miss
    /// protocol turns into counters, shared by all three streaming
    /// paths.
    fn note_read(&mut self, bytes: u64, from_cache: bool, cache_attached: bool) {
        self.bytes_read += bytes;
        self.chunks_read += 1;
        if from_cache {
            self.cache_hits += 1;
            self.bytes_from_cache += bytes;
        } else if cache_attached {
            self.cache_misses += 1;
        }
    }

    /// Field-wise accumulation (per-shard stats rolled into a pass).
    pub fn merge(&mut self, other: &StreamStats) {
        self.bytes_read += other.bytes_read;
        self.bytes_skipped += other.bytes_skipped;
        self.chunks_read += other.chunks_read;
        self.chunks_skipped += other.chunks_skipped;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.bytes_from_cache += other.bytes_from_cache;
    }

    /// Publish this pass's ledger into a metrics registry — the single
    /// field-to-family mapping, so the registry's
    /// `lorif_store_bytes_read_total + lorif_store_bytes_skipped_total`
    /// sums the same full-scan total this struct guarantees (the
    /// skipped view is mirrored into the prune family by
    /// `crate::sketch::prune::publish_prune_outcome`).  Called once per
    /// pass at the executor's aggregation point, never per chunk, so
    /// the streaming hot path stays free of shared-cacheline traffic.
    pub fn publish(&self, reg: &crate::telemetry::Registry) {
        reg.store_bytes_read.add(self.bytes_read);
        reg.store_bytes_skipped.add(self.bytes_skipped);
        reg.store_bytes_from_cache.add(self.bytes_from_cache);
        reg.store_chunks_read.add(self.chunks_read as u64);
        reg.store_chunks_skipped.add(self.chunks_skipped as u64);
        reg.cache_hits.add(self.cache_hits as u64);
        reg.cache_misses.add(self.cache_misses as u64);
    }
}

/// See [`StoreReader::chunks`].
pub struct ChunkCursor<'a> {
    reader: &'a StoreReader,
    file: std::fs::File,
    /// examples consumed within this file
    pos: usize,
    chunk_size: usize,
    raw: Vec<u8>,
    io: Duration,
    stats: StreamStats,
}

impl ChunkCursor<'_> {
    /// Global `(start, count)` of the next chunk, `None` at end of file.
    pub fn peek(&self) -> Option<(usize, usize)> {
        if self.pos >= self.reader.count {
            return None;
        }
        let count = self.chunk_size.min(self.reader.count - self.pos);
        Some((self.reader.start + self.pos, count))
    }

    /// Read + decode the next chunk and advance.  Consults the reader's
    /// decoded-chunk cache first (a hit seeks past the bytes); the skip
    /// path never touches the cache, so pruning decisions neither
    /// populate nor invalidate entries.
    pub fn read(&mut self) -> anyhow::Result<Arc<Chunk>> {
        let (start, count) =
            self.peek().ok_or_else(|| anyhow::anyhow!("cursor past end of file"))?;
        let stride = self.reader.meta.bytes_per_example();
        let key = (self.reader.shard, start, count, self.reader.encoded);
        let (chunk, from_cache, io) = fetch_chunk(
            &self.reader.meta,
            self.reader.cache.as_ref(),
            key,
            &mut self.file,
            &mut self.raw,
            start,
            count * stride,
            self.reader.encoded,
        )?;
        self.io += io;
        self.pos += count;
        self.stats
            .note_read((count * stride) as u64, from_cache, self.reader.cache.is_some());
        Ok(chunk)
    }

    /// Seek past the next chunk without reading its bytes.
    pub fn skip(&mut self) -> anyhow::Result<()> {
        let (_, count) =
            self.peek().ok_or_else(|| anyhow::anyhow!("cursor past end of file"))?;
        let stride = self.reader.meta.bytes_per_example();
        self.file.seek(SeekFrom::Current((count * stride) as i64))?;
        self.pos += count;
        self.stats.bytes_skipped += (count * stride) as u64;
        self.stats.chunks_skipped += 1;
        Ok(())
    }

    /// Reposition the cursor at the GLOBAL example index `start`, which
    /// must lie inside this file.  The next `peek`/`read` then covers
    /// the chunk beginning there.  This is the seeking primitive behind
    /// the best-first (IVF-style) scan: the executor visits chunks in
    /// bound order, not file order, so the cursor must jump both
    /// forwards and backwards.
    pub fn goto(&mut self, start: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            start >= self.reader.start && start <= self.reader.start + self.reader.count,
            "cursor goto target {start} outside file range [{}, {})",
            self.reader.start,
            self.reader.start + self.reader.count
        );
        let stride = self.reader.meta.bytes_per_example();
        self.file.seek(SeekFrom::Start(((start - self.reader.start) * stride) as u64))?;
        self.pos = start - self.reader.start;
        Ok(())
    }

    /// Account a chunk of `count` examples as skipped WITHOUT moving
    /// the file position.  The best-first scan never sits before a
    /// chunk it rejects (it seeks straight to the next best one, or
    /// stops early), so the relative-seeking `skip` does not apply —
    /// but the pruning ledger `bytes_read + bytes_skipped == full-scan
    /// bytes` must still balance, skipped-not-visited chunks included.
    pub fn account_skip(&mut self, count: usize) {
        let stride = self.reader.meta.bytes_per_example();
        self.stats.bytes_skipped += (count * stride) as u64;
        self.stats.chunks_skipped += 1;
    }

    /// Wall time spent reading + decoding so far.
    pub fn io_time(&self) -> Duration {
        self.io
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }
}

/// One shard's location within the global example range.
#[derive(Clone, Debug)]
pub struct ShardSpan {
    pub path: PathBuf,
    pub start: usize,
    pub count: usize,
    /// this shard's index in the MANIFEST — differs from its position
    /// in `ShardSet::spans` when the set was opened over a subset
    pub shard: usize,
}

/// An opened store: v1 single file (one pseudo-shard) or v2 shard files.
/// Every data file is validated against the manifest at open time, as
/// is the v3 chunk-summary sidecar when the manifest declares one.
pub struct ShardSet {
    pub meta: StoreMeta,
    spans: Vec<ShardSpan>,
    /// v3 pruning sidecar; `None` on v1/v2 stores (full scans only)
    summaries: Option<StoreSummaries>,
    /// v5 cluster reordering (`super::cluster`); `None` on unclustered
    /// stores.  When present, record order is storage order and scores
    /// must be mapped back through `perm` before callers see them.
    cluster: Option<ClusterMeta>,
    /// prefetch queue depth handed to every per-shard reader
    pub prefetch_depth: usize,
    /// decoded-chunk cache handed to every per-shard reader; shared
    /// across scorer instances via `Arc` on the serving path
    cache: Option<Arc<ChunkCache>>,
}

impl ShardSet {
    pub fn open(base: &Path) -> anyhow::Result<ShardSet> {
        ShardSet::open_subset(base, None)
    }

    /// Open only the manifest shards listed in `subset` (strictly
    /// increasing manifest indices), validating just those data files.
    /// Spans keep their GLOBAL `start` offsets from the full manifest,
    /// so every score this set produces carries the same original
    /// example index a full open would — the property that lets a node
    /// serving a shard subset feed the coordinator's `merge_topk`
    /// without any coordinate translation.  `None` opens every shard.
    pub fn open_subset(base: &Path, subset: Option<&[usize]>) -> anyhow::Result<ShardSet> {
        let meta = StoreMeta::load(base)?;
        let stride = meta.bytes_per_example() as u64;
        let mut spans = Vec::new();
        match meta.shards.clone() {
            None => {
                if let Some(sel) = subset {
                    anyhow::ensure!(
                        sel == [0],
                        "shard subset {sel:?} on an unsharded (v1) store: only shard 0 exists"
                    );
                }
                let path = StoreMeta::data_path(base);
                let size = std::fs::metadata(&path)?.len();
                anyhow::ensure!(
                    size == meta.total_bytes(),
                    "store size mismatch: {} vs expected {}",
                    size,
                    meta.total_bytes()
                );
                spans.push(ShardSpan { path, start: 0, count: meta.n_examples, shard: 0 });
            }
            Some(counts) => {
                if let Some(sel) = subset {
                    anyhow::ensure!(!sel.is_empty(), "shard subset is empty");
                    anyhow::ensure!(
                        sel.windows(2).all(|w| w[0] < w[1]),
                        "shard subset {sel:?} must be strictly increasing (no duplicates)"
                    );
                    let last = *sel.last().unwrap();
                    anyhow::ensure!(
                        last < counts.len(),
                        "shard subset names shard {last} but the manifest has {} shards",
                        counts.len()
                    );
                }
                // global start offsets come from the FULL manifest even
                // when only a subset is opened
                let mut start = 0usize;
                for (i, &count) in counts.iter().enumerate() {
                    let wanted = subset.map_or(true, |sel| sel.contains(&i));
                    if wanted {
                        let path = StoreMeta::shard_data_path(base, i);
                        let size = std::fs::metadata(&path)?.len();
                        anyhow::ensure!(
                            size == count as u64 * stride,
                            "shard {i} size mismatch: {size} B on disk vs {count} examples \
                             x {stride} B/example in the manifest"
                        );
                        spans.push(ShardSpan { path, start, count, shard: i });
                    }
                    start += count;
                }
            }
        }
        let summaries = match meta.summary_chunk {
            None => None,
            Some(declared) => {
                let path = StoreMeta::summaries_path(base);
                let sums = StoreSummaries::load(&path).map_err(|e| {
                    anyhow::anyhow!(
                        "manifest declares a summary sidecar but {} is unreadable: {e}",
                        path.display()
                    )
                })?;
                anyhow::ensure!(
                    sums.chunk_size == declared,
                    "summary sidecar grid {} disagrees with the manifest's {declared}",
                    sums.chunk_size
                );
                sums.validate(&meta)?;
                Some(sums)
            }
        };
        // v5 cluster reordering: validated (bijection over n_examples)
        // at open, so everything downstream can index through it freely
        let cluster = ClusterMeta::load(base)?;
        Ok(ShardSet {
            meta,
            spans,
            summaries,
            cluster,
            prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            cache: None,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.spans.len()
    }

    pub fn shard(&self, i: usize) -> &ShardSpan {
        &self.spans[i]
    }

    /// The v3 pruning sidecar, when this store carries one.
    pub fn summaries(&self) -> Option<&StoreSummaries> {
        self.summaries.as_ref()
    }

    /// The v5 cluster reordering, when this store carries one.
    pub fn cluster(&self) -> Option<&ClusterMeta> {
        self.cluster.as_ref()
    }

    /// Attach (or detach) a decoded-chunk cache; every reader handed out
    /// afterwards consults it before hitting disk.  Call before sharing
    /// the set behind `Arc`.
    pub fn set_cache(&mut self, cache: Option<Arc<ChunkCache>>) {
        self.cache = cache;
    }

    /// The attached decoded-chunk cache, if any.
    pub fn cache(&self) -> Option<&Arc<ChunkCache>> {
        self.cache.as_ref()
    }

    /// A reader over the set's `i`-th span, reporting global example
    /// indices.  The reader's `shard` (cache key, trace lane) is the
    /// span's MANIFEST index, so a subset-opened set shares cache
    /// entries with a full open of the same store.
    pub fn reader(&self, i: usize) -> StoreReader {
        let s = &self.spans[i];
        StoreReader {
            meta: self.meta.clone(),
            path: s.path.clone(),
            start: s.start,
            count: s.count,
            prefetch_depth: self.prefetch_depth,
            shard: s.shard,
            cache: self.cache.clone(),
            encoded: false,
        }
    }

    /// Sequential stream over every shard in order — same contract as
    /// `StoreReader::stream` on a v1 store (used by the stage-2 builders
    /// and anything else that wants a single-threaded full pass).
    pub fn stream(
        &self,
        chunk_size: usize,
        prefetch: bool,
        mut f: impl FnMut(&Chunk) -> anyhow::Result<()>,
    ) -> anyhow::Result<(Duration, StreamStats)> {
        let mut io = Duration::ZERO;
        let mut stats = StreamStats::default();
        for i in 0..self.spans.len() {
            let (d, s) = self.reader(i).stream(chunk_size, prefetch, &mut f)?;
            io += d;
            stats.merge(&s);
        }
        Ok((io, stats))
    }

    /// Read a contiguous global range, stitching across shard boundaries.
    pub fn read_range(&self, start: usize, count: usize) -> anyhow::Result<Chunk> {
        anyhow::ensure!(start + count <= self.meta.n_examples, "range out of bounds");
        let stride = self.meta.bytes_per_example();
        let mut raw = vec![0u8; count * stride];
        for s in &self.spans {
            let lo = start.max(s.start);
            let hi = (start + count).min(s.start + s.count);
            if lo >= hi {
                continue;
            }
            let mut file = std::fs::File::open(&s.path)?;
            file.seek(SeekFrom::Start(((lo - s.start) * stride) as u64))?;
            let dst = &mut raw[(lo - start) * stride..(hi - start) * stride];
            file.read_exact(dst)?;
        }
        decode_chunk(&self.meta, start, &raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExtractBatch, LayerGrads};
    use crate::store::writer::{ShardedWriter, StoreWriter};
    use crate::util::prng::Rng;

    fn fake_batch(n: usize, layers: &[(usize, usize)], c: usize, seed: u64) -> ExtractBatch {
        let mut rng = Rng::new(seed);
        let layers = layers
            .iter()
            .map(|&(d1, d2)| LayerGrads {
                g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                u: Mat::random_normal(n, d1 * c, 1.0, &mut rng),
                v: Mat::random_normal(n, d2 * c, 1.0, &mut rng),
            })
            .collect();
        ExtractBatch { losses: vec![0.0; n], layers, valid: n }
    }

    fn meta_for(kind: StoreKind, layers: &[(usize, usize)], c: usize) -> StoreMeta {
        StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers: layers.to_vec(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        }
    }

    fn write_store(kind: StoreKind, n: usize, c: usize) -> (tempdir::TempBase, StoreMeta) {
        let layers = vec![(8, 12), (8, 8)];
        let base = tempdir::base(&format!("store_{}_{}", kind.as_str(), n));
        let mut w = StoreWriter::create(&base.path, meta_for(kind, &layers, c)).unwrap();
        let mut written = 0;
        while written < n {
            let take = 5.min(n - written);
            let b = fake_batch(take, &layers, c, written as u64);
            w.append(&b).unwrap();
            written += take;
        }
        let meta = w.finalize().unwrap();
        (base, meta)
    }

    fn write_sharded(
        kind: StoreKind,
        n: usize,
        c: usize,
        shards: usize,
        name: &str,
    ) -> (tempdir::TempBase, StoreMeta) {
        let layers = vec![(8, 12), (8, 8)];
        let base = tempdir::base(name);
        let mut w =
            ShardedWriter::create(&base.path, meta_for(kind, &layers, c), shards, n).unwrap();
        let mut written = 0;
        while written < n {
            let take = 5.min(n - written);
            let b = fake_batch(take, &layers, c, written as u64);
            w.append(&b).unwrap();
            written += take;
        }
        let meta = w.finalize().unwrap();
        (base, meta)
    }

    // tiny temp-dir helper
    mod tempdir {
        use std::path::PathBuf;

        pub struct TempBase {
            pub path: PathBuf,
        }

        impl Drop for TempBase {
            fn drop(&mut self) {
                let _ = std::fs::remove_file(self.path.with_extension("grads"));
                let _ = std::fs::remove_file(self.path.with_extension("json"));
                let _ = std::fs::remove_file(self.path.with_extension("summaries"));
                for i in 0..64 {
                    let _ = std::fs::remove_file(
                        self.path.with_extension(format!("shard{i}.grads")),
                    );
                }
            }
        }

        pub fn base(name: &str) -> TempBase {
            let dir = std::env::temp_dir().join("lorif_store_tests");
            std::fs::create_dir_all(&dir).unwrap();
            TempBase { path: dir.join(name) }
        }
    }

    #[test]
    fn roundtrip_factored() {
        let (base, meta) = write_store(StoreKind::Factored, 17, 2);
        assert_eq!(meta.n_examples, 17);
        let r = StoreReader::open(&base.path).unwrap();
        let mut seen = 0;
        r.stream(6, false, |chunk| {
            let (u, v) = chunk.layers[0].factors();
            assert_eq!(u.rows, chunk.count);
            assert_eq!(u.cols, 8 * 2);
            assert_eq!(v.cols, 12 * 2);
            seen += chunk.count;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, 17);
    }

    #[test]
    fn codec_stores_roundtrip_within_error_bounds() {
        // the same records under every codec: the reader decodes v4
        // stores through the manifest codec, and every value is within
        // the codec's documented error of the original
        use crate::store::CodecId;
        let layers = vec![(8usize, 12usize), (8, 8)];
        let n = 13;
        for codec in CodecId::ALL {
            for kind in [StoreKind::Dense, StoreKind::Factored] {
                let base =
                    tempdir::base(&format!("codec_rt_{}_{}", codec.as_str(), kind.as_str()));
                let mut meta = meta_for(kind, &layers, 2);
                meta.codec = codec;
                let mut w = StoreWriter::create(&base.path, meta).unwrap();
                let b = fake_batch(n, &layers, 2, 99);
                w.append(&b).unwrap();
                let meta = w.finalize().unwrap();
                assert_eq!(meta.codec, codec);
                let set = ShardSet::open(&base.path).unwrap();
                assert_eq!(set.meta.codec, codec);
                let rel = codec.get().max_rel_error();
                let chunk = set.read_range(0, n).unwrap();
                for (l, layer) in chunk.layers.iter().enumerate() {
                    let originals: Vec<&Mat> = match kind {
                        StoreKind::Dense => vec![&b.layers[l].g],
                        StoreKind::Factored => vec![&b.layers[l].u, &b.layers[l].v],
                    };
                    let decoded: Vec<&Mat> = match layer {
                        ChunkLayer::Dense { g } => vec![g],
                        ChunkLayer::Factored { u, v } => vec![u, v],
                    };
                    for (orig, dec) in originals.iter().zip(&decoded) {
                        for ex in 0..n {
                            // bound against the row absmax: every codec's
                            // scale group is within one stored row
                            let m = orig.row(ex).iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                            for (a, b) in orig.row(ex).iter().zip(dec.row(ex)) {
                                assert!(
                                    (a - b).abs() <= rel * m + 1e-30,
                                    "{codec:?}/{kind:?} layer {l} ex {ex}: {a} -> {b}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn roundtrip_dense_values() {
        let (base, _) = write_store(StoreKind::Dense, 9, 1);
        let r = StoreReader::open(&base.path).unwrap();
        // regenerate the same fake data and compare within bf16 tolerance
        let b0 = fake_batch(5, &[(8, 12), (8, 8)], 1, 0);
        let chunk = r.read_range(0, 5).unwrap();
        let g = chunk.layers[0].dense();
        for ex in 0..5 {
            for (a, b) in g.row(ex).iter().zip(b0.layers[0].g.row(ex)) {
                assert!((a - b).abs() <= b.abs() / 128.0 + 1e-2, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn prefetch_matches_sync() {
        let (base, _) = write_store(StoreKind::Factored, 23, 1);
        let mut r = StoreReader::open(&base.path).unwrap();
        let collect = |r: &StoreReader, prefetch: bool| {
            let mut rows: Vec<f32> = Vec::new();
            r.stream(7, prefetch, |chunk| {
                let (u, _) = chunk.layers[1].factors();
                rows.extend(u.data.iter());
                Ok(())
            })
            .unwrap();
            rows
        };
        let sync = collect(&r, false);
        assert_eq!(sync, collect(&r, true));
        // deeper and minimal queues deliver the identical stream
        r.prefetch_depth = 5;
        assert_eq!(sync, collect(&r, true));
        r.prefetch_depth = 1;
        assert_eq!(sync, collect(&r, true));
    }

    #[test]
    fn cursor_read_all_matches_stream() {
        let (base, _) = write_store(StoreKind::Dense, 17, 1);
        let r = StoreReader::open(&base.path).unwrap();
        let mut streamed: Vec<f32> = Vec::new();
        r.stream(5, false, |c| {
            streamed.extend(c.layers[0].dense().data.iter());
            Ok(())
        })
        .unwrap();
        let mut cur = r.chunks(5).unwrap();
        let mut via_cursor: Vec<f32> = Vec::new();
        while cur.peek().is_some() {
            via_cursor.extend(cur.read().unwrap().layers[0].dense().data.iter());
        }
        assert_eq!(streamed, via_cursor);
        assert_eq!(cur.stats().chunks_read, 4);
        assert_eq!(cur.stats().chunks_skipped, 0);
        assert_eq!(cur.stats().bytes_read, r.meta.total_bytes());
    }

    #[test]
    fn cursor_skip_seeks_past_chunks() {
        let (base, _) = write_store(StoreKind::Dense, 20, 1);
        let r = StoreReader::open(&base.path).unwrap();
        let stride = r.meta.bytes_per_example() as u64;
        let mut cur = r.chunks(6).unwrap();
        let mut read_chunks = Vec::new();
        let mut i = 0;
        while let Some((start, count)) = cur.peek() {
            if i % 2 == 0 {
                cur.skip().unwrap();
            } else {
                let c = cur.read().unwrap();
                assert_eq!((c.start, c.count), (start, count));
                read_chunks.push(c);
            }
            i += 1;
        }
        // chunks: [0,6) skipped, [6,12) read, [12,18) skipped, [18,20) read
        assert_eq!(cur.stats().chunks_skipped, 2);
        assert_eq!(cur.stats().chunks_read, 2);
        assert_eq!(cur.stats().bytes_skipped, 12 * stride);
        assert_eq!(cur.stats().bytes_read, 8 * stride);
        // a skipped-over read still lands on the right records
        let want = r.read_range(6, 6).unwrap();
        assert_eq!(read_chunks[0].layers[0].dense().data, want.layers[0].dense().data);
    }

    #[test]
    fn cursor_goto_reads_chunks_out_of_order() {
        let (base, _) = write_store(StoreKind::Dense, 20, 1);
        let r = StoreReader::open(&base.path).unwrap();
        let stride = r.meta.bytes_per_example() as u64;
        let mut cur = r.chunks(6).unwrap();
        // visit chunk [12, 18) first, then jump BACK to [0, 6)
        cur.goto(12).unwrap();
        let c = cur.read().unwrap();
        assert_eq!((c.start, c.count), (12, 6));
        cur.goto(0).unwrap();
        let c0 = cur.read().unwrap();
        assert_eq!((c0.start, c0.count), (0, 6));
        let want = r.read_range(0, 6).unwrap();
        assert_eq!(c0.layers[0].dense().data, want.layers[0].dense().data);
        // the unvisited chunks [6, 12) and [18, 20) balance the ledger
        // via accounting-only skips (no seek happens for them)
        cur.account_skip(6);
        cur.account_skip(2);
        assert_eq!(cur.stats().chunks_read, 2);
        assert_eq!(cur.stats().chunks_skipped, 2);
        assert_eq!(cur.stats().bytes_read, 12 * stride);
        assert_eq!(cur.stats().bytes_skipped, 8 * stride);
        assert_eq!(
            cur.stats().bytes_read + cur.stats().bytes_skipped,
            r.meta.total_bytes()
        );
        // out-of-range targets are rejected, in-range end is allowed
        assert!(cur.goto(21).is_err());
        assert!(cur.goto(20).is_ok());
        assert!(cur.peek().is_none());
    }

    #[test]
    fn v3_store_loads_and_validates_summaries() {
        let (base, meta) = write_store(StoreKind::Dense, 11, 1);
        assert!(meta.summary_chunk.is_some());
        let set = ShardSet::open(&base.path).unwrap();
        let sums = set.summaries().expect("sidecar loaded");
        assert_eq!(sums.chunks.iter().map(|c| c.count).sum::<usize>(), 11);

        // manifest declares summaries but the sidecar is gone -> error
        std::fs::remove_file(StoreMeta::summaries_path(&base.path)).unwrap();
        let err = ShardSet::open(&base.path).unwrap_err();
        assert!(format!("{err}").contains("summary sidecar"), "{err}");
    }

    #[test]
    fn corrupt_summary_sidecar_is_a_clean_error() {
        let (base, _) = write_store(StoreKind::Dense, 9, 1);
        let p = StoreMeta::summaries_path(&base.path);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(ShardSet::open(&base.path).is_err());
        // garbage magic
        std::fs::write(&p, b"NOTASUMMARYFILE!").unwrap();
        let err = ShardSet::open(&base.path).unwrap_err();
        assert!(format!("{err}").contains("unreadable"), "{err}");
    }

    #[test]
    fn sharded_summaries_restart_per_shard() {
        let (base, meta) = write_sharded(StoreKind::Dense, 20, 1, 3, "sum_per_shard");
        assert!(meta.summary_chunk.is_some());
        let set = ShardSet::open(&base.path).unwrap();
        let sums = set.summaries().unwrap();
        // every shard start must begin a summary chunk
        for i in 0..set.n_shards() {
            assert!(sums.find(set.shard(i).start).is_some(), "shard {i}");
        }
    }

    #[test]
    fn subset_open_keeps_global_offsets_and_validates() {
        let (base, meta) = write_sharded(StoreKind::Dense, 20, 1, 3, "subset_open");
        let counts = meta.shards.clone().unwrap();
        let full = ShardSet::open(&base.path).unwrap();
        // the middle shard alone: one span, at its FULL-manifest offset
        let sub = ShardSet::open_subset(&base.path, Some(&[1])).unwrap();
        assert_eq!(sub.n_shards(), 1);
        assert_eq!(sub.shard(0).start, full.shard(1).start);
        assert_eq!(sub.shard(0).count, counts[1]);
        assert_eq!(sub.shard(0).shard, 1);
        // a subset reader reports the same global coordinates
        let r = sub.reader(0);
        assert_eq!((r.start, r.count), (full.shard(1).start, counts[1]));
        // malformed subsets are clean errors
        for bad in [&[][..], &[1, 1][..], &[2, 1][..], &[3][..]] {
            assert!(ShardSet::open_subset(&base.path, Some(bad)).is_err(), "{bad:?}");
        }
        // a missing NON-subset shard file doesn't block a subset open
        std::fs::remove_file(StoreMeta::shard_data_path(&base.path, 0)).unwrap();
        assert!(ShardSet::open_subset(&base.path, Some(&[1, 2])).is_ok());
        assert!(ShardSet::open(&base.path).is_err());

        // v1 store: only the trivial subset exists
        let (mono, _) = write_store(StoreKind::Dense, 7, 1);
        assert!(ShardSet::open_subset(&mono.path, Some(&[0])).is_ok());
        assert!(ShardSet::open_subset(&mono.path, Some(&[1])).is_err());
    }

    #[test]
    fn detects_truncated_file() {
        let (base, _) = write_store(StoreKind::Dense, 6, 1);
        // truncate the data file
        let data = StoreMeta::data_path(&base.path);
        let full = std::fs::read(&data).unwrap();
        std::fs::write(&data, &full[..full.len() - 10]).unwrap();
        assert!(StoreReader::open(&base.path).is_err());
        assert!(ShardSet::open(&base.path).is_err());
    }

    #[test]
    fn read_range_bounds() {
        let (base, _) = write_store(StoreKind::Factored, 10, 1);
        let r = StoreReader::open(&base.path).unwrap();
        assert!(r.read_range(8, 3).is_err());
        assert!(r.read_range(8, 2).is_ok());
    }

    #[test]
    fn sharded_roundtrip_matches_monolithic() {
        let (mono, _) = write_store(StoreKind::Factored, 27, 2);
        let (shard, meta) =
            write_sharded(StoreKind::Factored, 27, 2, 4, "sharded_vs_mono");
        assert_eq!(meta.shards.as_ref().unwrap().len(), 4);
        assert_eq!(meta.shards.as_ref().unwrap().iter().sum::<usize>(), 27);

        let collect = |set: &ShardSet, chunk: usize| {
            let mut rows: Vec<(usize, Vec<f32>)> = Vec::new();
            set.stream(chunk, false, |c| {
                let (u, _) = c.layers[0].factors();
                for ex in 0..c.count {
                    rows.push((c.start + ex, u.row(ex).to_vec()));
                }
                Ok(())
            })
            .unwrap();
            rows
        };
        let a = collect(&ShardSet::open(&mono.path).unwrap(), 6);
        let b = collect(&ShardSet::open(&shard.path).unwrap(), 4);
        assert_eq!(a, b);
    }

    #[test]
    fn shard_set_opens_v1_as_single_shard() {
        let (base, _) = write_store(StoreKind::Dense, 11, 1);
        let set = ShardSet::open(&base.path).unwrap();
        assert_eq!(set.n_shards(), 1);
        assert_eq!(set.shard(0).start, 0);
        assert_eq!(set.shard(0).count, 11);
        // the per-shard reader equals the plain v1 reader
        let direct = StoreReader::open(&base.path).unwrap();
        let via_set = set.reader(0);
        let a = direct.read_range(2, 4).unwrap();
        let b = via_set.read_range(2, 4).unwrap();
        assert_eq!(a.layers[0].dense().data, b.layers[0].dense().data);
    }

    #[test]
    fn shard_readers_report_global_offsets() {
        let (base, meta) = write_sharded(StoreKind::Dense, 20, 1, 3, "global_offsets");
        let set = ShardSet::open(&base.path).unwrap();
        assert_eq!(set.n_shards(), meta.shards.as_ref().unwrap().len());
        let mut starts = Vec::new();
        for i in 0..set.n_shards() {
            let r = set.reader(i);
            r.stream(64, false, |chunk| {
                starts.push(chunk.start);
                assert_eq!(chunk.start, r.start);
                Ok(())
            })
            .unwrap();
        }
        assert_eq!(starts[0], 0);
        assert!(starts.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn sharded_read_range_stitches_across_shards() {
        let (base, _) = write_sharded(StoreKind::Dense, 20, 1, 3, "stitch_range");
        let set = ShardSet::open(&base.path).unwrap();
        // shards hold 7/7/6 examples; [5, 11) crosses the first boundary
        let chunk = set.read_range(5, 6).unwrap();
        assert_eq!(chunk.start, 5);
        assert_eq!(chunk.count, 6);
        let full = set.read_range(0, 20).unwrap();
        for ex in 0..6 {
            assert_eq!(
                chunk.layers[0].dense().row(ex),
                full.layers[0].dense().row(5 + ex)
            );
        }
    }

    #[test]
    fn rejects_shard_size_disagreeing_with_manifest() {
        let (base, _) = write_sharded(StoreKind::Dense, 20, 1, 3, "bad_shard_size");
        assert!(ShardSet::open(&base.path).is_ok());
        // truncate shard 1 by one record
        let p = StoreMeta::shard_data_path(&base.path, 1);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let err = ShardSet::open(&base.path).unwrap_err();
        assert!(format!("{err}").contains("shard 1 size mismatch"), "{err}");
    }

    #[test]
    fn v1_reader_refuses_v2_manifest() {
        let (base, _) = write_sharded(StoreKind::Dense, 10, 1, 2, "v2_refuse");
        let err = StoreReader::open(&base.path).unwrap_err();
        assert!(format!("{err}").contains("ShardSet"), "{err}");
    }

    #[test]
    fn sharded_writer_with_one_shard_still_v2() {
        let (base, meta) = write_sharded(StoreKind::Dense, 8, 1, 1, "one_shard");
        assert_eq!(meta.shards, Some(vec![8]));
        let set = ShardSet::open(&base.path).unwrap();
        assert_eq!(set.n_shards(), 1);
        let mut seen = 0;
        set.stream(3, false, |c| {
            seen += c.count;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, 8);
    }

    fn collect_stream(set: &ShardSet, chunk: usize, prefetch: bool) -> (Vec<f32>, StreamStats) {
        let mut rows: Vec<f32> = Vec::new();
        let (_, stats) = set
            .stream(chunk, prefetch, |c| {
                rows.extend(c.layers[0].dense().data.iter());
                Ok(())
            })
            .unwrap();
        (rows, stats)
    }

    #[test]
    fn cached_stream_is_bit_identical_and_counts_hits() {
        let (base, _) = write_store(StoreKind::Dense, 23, 1);
        let cold_set = ShardSet::open(&base.path).unwrap();
        let (cold, cold_stats) = collect_stream(&cold_set, 7, false);
        assert_eq!(cold_stats.cache_hits + cold_stats.cache_misses, 0, "no cache attached");

        let mut warm_set = ShardSet::open(&base.path).unwrap();
        warm_set.set_cache(Some(crate::store::ChunkCache::with_capacity(1 << 20)));
        for (pass, prefetch) in [(0, false), (1, true), (2, false)] {
            let (rows, stats) = collect_stream(&warm_set, 7, prefetch);
            assert_eq!(rows, cold, "pass {pass} diverged from the cold stream");
            assert_eq!(stats.bytes_read, cold_stats.bytes_read, "logical bytes stable");
            if pass == 0 {
                assert_eq!(stats.cache_misses, 4, "first pass decodes every chunk");
                assert_eq!(stats.cache_hits, 0);
            } else {
                assert_eq!(stats.cache_hits, 4, "warm pass {pass} must hit");
                assert_eq!(stats.cache_misses, 0);
                assert_eq!(stats.bytes_from_cache, stats.bytes_read);
            }
        }
        // a different chunk grid never aliases cached spans
        let (rows, stats) = collect_stream(&warm_set, 5, false);
        assert_eq!(rows, cold);
        assert_eq!(stats.cache_hits, 0, "grid change must miss, not alias");
    }

    #[test]
    fn sharded_cache_keys_do_not_alias_across_shards() {
        let (base, _) = write_sharded(StoreKind::Dense, 20, 1, 3, "cache_shards");
        let mut set = ShardSet::open(&base.path).unwrap();
        set.set_cache(Some(crate::store::ChunkCache::with_capacity(1 << 20)));
        let cold = collect_stream(&ShardSet::open(&base.path).unwrap(), 4, false).0;
        let (first, s1) = collect_stream(&set, 4, false);
        let (second, s2) = collect_stream(&set, 4, false);
        assert_eq!(first, cold);
        assert_eq!(second, cold);
        assert_eq!(s1.cache_hits, 0);
        assert_eq!(s2.cache_hits, s1.cache_misses, "every decoded chunk re-served");
        assert_eq!(s2.bytes_from_cache, s2.bytes_read);
    }

    #[test]
    fn cursor_skip_never_populates_the_cache() {
        let (base, _) = write_store(StoreKind::Dense, 20, 1);
        let mut set = ShardSet::open(&base.path).unwrap();
        let cache = crate::store::ChunkCache::with_capacity(1 << 20);
        set.set_cache(Some(cache.clone()));
        let r = set.reader(0);
        let mut cur = r.chunks(5).unwrap();
        // skip, read, skip, read over the 4 chunks
        let mut i = 0;
        while cur.peek().is_some() {
            if i % 2 == 0 {
                cur.skip().unwrap();
            } else {
                cur.read().unwrap();
            }
            i += 1;
        }
        assert_eq!(cur.stats().chunks_skipped, 2);
        assert_eq!(cur.stats().cache_misses, 2);
        assert_eq!(cache.stats().insertions, 2, "skipped chunks must not populate");
        // a second identical walk hits on exactly the read chunks
        let mut cur = r.chunks(5).unwrap();
        let mut i = 0;
        let mut read_data: Vec<f32> = Vec::new();
        while cur.peek().is_some() {
            if i % 2 == 0 {
                cur.skip().unwrap();
            } else {
                read_data.extend(cur.read().unwrap().layers[0].dense().data.iter());
            }
            i += 1;
        }
        assert_eq!(cur.stats().cache_hits, 2);
        assert_eq!(cur.stats().cache_misses, 0);
        let want = r.read_range(5, 5).unwrap();
        assert_eq!(&read_data[..want.layers[0].dense().data.len()], &want.layers[0].dense().data[..]);
    }
}

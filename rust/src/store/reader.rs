//! Store reader: sequential batched reads with optional prefetch.
//!
//! The query hot path streams the whole store once per query batch.  The
//! prefetch thread reads the next chunk from disk while the scorer
//! consumes the current one, overlapping I/O and compute — the reader
//! reports the two times separately, which is what Figure 3 plots.

use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::format::{StoreKind, StoreMeta};
use crate::linalg::Mat;
use crate::util::bf16;

/// A decoded chunk of consecutive examples.
pub struct Chunk {
    /// index of the first example in this chunk
    pub start: usize,
    pub count: usize,
    /// per layer: matrices with `count` rows
    pub layers: Vec<ChunkLayer>,
    /// wall time spent on disk reads + decode for this chunk
    pub io_time: Duration,
}

pub enum ChunkLayer {
    Dense { g: Mat },
    Factored { u: Mat, v: Mat },
}

impl ChunkLayer {
    pub fn dense(&self) -> &Mat {
        match self {
            ChunkLayer::Dense { g } => g,
            _ => panic!("expected dense layer"),
        }
    }

    pub fn factors(&self) -> (&Mat, &Mat) {
        match self {
            ChunkLayer::Factored { u, v } => (u, v),
            _ => panic!("expected factored layer"),
        }
    }
}

pub struct StoreReader {
    pub meta: StoreMeta,
    path: PathBuf,
}

impl StoreReader {
    pub fn open(base: &Path) -> anyhow::Result<StoreReader> {
        let meta = StoreMeta::load(base)?;
        let path = StoreMeta::data_path(base);
        let size = std::fs::metadata(&path)?.len();
        anyhow::ensure!(
            size == meta.total_bytes(),
            "store size mismatch: {} vs expected {}",
            size,
            meta.total_bytes()
        );
        Ok(StoreReader { meta, path })
    }

    fn decode_chunk(meta: &StoreMeta, start: usize, raw: &[u8]) -> Chunk {
        let stride = meta.bytes_per_example();
        let count = raw.len() / stride;
        let t0 = Instant::now();
        let mut layers = Vec::with_capacity(meta.layers.len());
        for (l, &(d1, d2)) in meta.layers.iter().enumerate() {
            let (off, len) = meta.layer_span(l);
            match meta.kind {
                StoreKind::Dense => {
                    let mut g = Mat::zeros(count, d1 * d2);
                    for ex in 0..count {
                        let src = &raw[ex * stride + off..ex * stride + off + len * 2];
                        bf16::decode_into(src, g.row_mut(ex));
                    }
                    layers.push(ChunkLayer::Dense { g });
                }
                StoreKind::Factored => {
                    let cu = d1 * meta.c;
                    let cv = d2 * meta.c;
                    let mut u = Mat::zeros(count, cu);
                    let mut v = Mat::zeros(count, cv);
                    for ex in 0..count {
                        let base = ex * stride + off;
                        bf16::decode_into(&raw[base..base + cu * 2], u.row_mut(ex));
                        bf16::decode_into(
                            &raw[base + cu * 2..base + (cu + cv) * 2],
                            v.row_mut(ex),
                        );
                    }
                    layers.push(ChunkLayer::Factored { u, v });
                }
            }
        }
        Chunk { start, count, layers, io_time: t0.elapsed() }
    }

    /// Stream all examples in chunks of `chunk_size`, calling `f` for each.
    /// Returns (io_time, total_bytes_read).  `io_time` covers read+decode.
    pub fn stream(
        &self,
        chunk_size: usize,
        prefetch: bool,
        mut f: impl FnMut(Chunk) -> anyhow::Result<()>,
    ) -> anyhow::Result<(Duration, u64)> {
        let n = self.meta.n_examples;
        if n == 0 {
            return Ok((Duration::ZERO, 0));
        }
        let stride = self.meta.bytes_per_example();
        let total_bytes = self.meta.total_bytes();
        if !prefetch {
            let mut file = std::fs::File::open(&self.path)?;
            let mut io_total = Duration::ZERO;
            let mut start = 0usize;
            let mut raw = vec![0u8; chunk_size * stride];
            while start < n {
                let count = chunk_size.min(n - start);
                let t0 = Instant::now();
                let buf = &mut raw[..count * stride];
                file.read_exact(buf)?;
                let chunk = Self::decode_chunk(&self.meta, start, buf);
                io_total += t0.elapsed();
                f(chunk)?;
                start += count;
            }
            return Ok((io_total, total_bytes));
        }

        // prefetch thread: reads + decodes ahead, bounded queue of 2
        let (tx, rx) = mpsc::sync_channel::<anyhow::Result<Chunk>>(2);
        let meta = self.meta.clone();
        let path = self.path.clone();
        let handle = std::thread::spawn(move || {
            let run = || -> anyhow::Result<()> {
                let mut file = std::fs::File::open(&path)?;
                file.seek(SeekFrom::Start(0))?;
                let mut start = 0usize;
                while start < n {
                    let count = chunk_size.min(n - start);
                    let t0 = Instant::now();
                    let mut raw = vec![0u8; count * stride];
                    file.read_exact(&mut raw)?;
                    let mut chunk = Self::decode_chunk(&meta, start, &raw);
                    chunk.io_time = t0.elapsed();
                    if tx.send(Ok(chunk)).is_err() {
                        return Ok(()); // consumer hung up
                    }
                    start += count;
                }
                Ok(())
            };
            if let Err(e) = run() {
                let _ = tx.send(Err(e));
            }
        });

        let mut io_total = Duration::ZERO;
        for chunk in rx {
            let chunk = chunk?;
            io_total += chunk.io_time;
            f(chunk)?;
        }
        handle.join().map_err(|_| anyhow::anyhow!("prefetch thread panicked"))?;
        Ok((io_total, total_bytes))
    }

    /// Read a specific contiguous range (used by tests and diagnostics).
    pub fn read_range(&self, start: usize, count: usize) -> anyhow::Result<Chunk> {
        anyhow::ensure!(start + count <= self.meta.n_examples, "range out of bounds");
        let stride = self.meta.bytes_per_example();
        let mut file = std::fs::File::open(&self.path)?;
        file.seek(SeekFrom::Start((start * stride) as u64))?;
        let mut raw = vec![0u8; count * stride];
        file.read_exact(&mut raw)?;
        Ok(Self::decode_chunk(&self.meta, start, &raw))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{ExtractBatch, LayerGrads};
    use crate::store::writer::StoreWriter;
    use crate::util::prng::Rng;

    fn fake_batch(n: usize, layers: &[(usize, usize)], c: usize, seed: u64) -> ExtractBatch {
        let mut rng = Rng::new(seed);
        let layers = layers
            .iter()
            .map(|&(d1, d2)| LayerGrads {
                g: Mat::random_normal(n, d1 * d2, 1.0, &mut rng),
                u: Mat::random_normal(n, d1 * c, 1.0, &mut rng),
                v: Mat::random_normal(n, d2 * c, 1.0, &mut rng),
            })
            .collect();
        ExtractBatch { losses: vec![0.0; n], layers, valid: n }
    }

    fn write_store(kind: StoreKind, n: usize, c: usize) -> (tempdir::TempBase, StoreMeta) {
        let layers = vec![(8, 12), (8, 8)];
        let base = tempdir::base(&format!("store_{}_{}", kind.as_str(), n));
        let meta = StoreMeta {
            kind,
            tier: "small".into(),
            f: 4,
            c,
            layers: layers.clone(),
            n_examples: 0,
        };
        let mut w = StoreWriter::create(&base.path, meta).unwrap();
        let mut written = 0;
        while written < n {
            let take = 5.min(n - written);
            let b = fake_batch(take, &layers, c, written as u64);
            w.append(&b).unwrap();
            written += take;
        }
        let meta = w.finalize().unwrap();
        (base, meta)
    }

    // tiny temp-dir helper
    mod tempdir {
        use std::path::PathBuf;

        pub struct TempBase {
            pub path: PathBuf,
        }

        impl Drop for TempBase {
            fn drop(&mut self) {
                let _ = std::fs::remove_file(self.path.with_extension("grads"));
                let _ = std::fs::remove_file(self.path.with_extension("json"));
            }
        }

        pub fn base(name: &str) -> TempBase {
            let dir = std::env::temp_dir().join("lorif_store_tests");
            std::fs::create_dir_all(&dir).unwrap();
            TempBase { path: dir.join(name) }
        }
    }

    #[test]
    fn roundtrip_factored() {
        let (base, meta) = write_store(StoreKind::Factored, 17, 2);
        assert_eq!(meta.n_examples, 17);
        let r = StoreReader::open(&base.path).unwrap();
        let mut seen = 0;
        r.stream(6, false, |chunk| {
            let (u, v) = chunk.layers[0].factors();
            assert_eq!(u.rows, chunk.count);
            assert_eq!(u.cols, 8 * 2);
            assert_eq!(v.cols, 12 * 2);
            seen += chunk.count;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, 17);
    }

    #[test]
    fn roundtrip_dense_values() {
        let (base, _) = write_store(StoreKind::Dense, 9, 1);
        let r = StoreReader::open(&base.path).unwrap();
        // regenerate the same fake data and compare within bf16 tolerance
        let b0 = fake_batch(5, &[(8, 12), (8, 8)], 1, 0);
        let chunk = r.read_range(0, 5).unwrap();
        let g = chunk.layers[0].dense();
        for ex in 0..5 {
            for (a, b) in g.row(ex).iter().zip(b0.layers[0].g.row(ex)) {
                assert!((a - b).abs() <= b.abs() / 128.0 + 1e-2, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn prefetch_matches_sync() {
        let (base, _) = write_store(StoreKind::Factored, 23, 1);
        let r = StoreReader::open(&base.path).unwrap();
        let collect = |prefetch: bool| {
            let mut rows: Vec<f32> = Vec::new();
            r.stream(7, prefetch, |chunk| {
                let (u, _) = chunk.layers[1].factors();
                rows.extend(u.data.iter());
                Ok(())
            })
            .unwrap();
            rows
        };
        assert_eq!(collect(false), collect(true));
    }

    #[test]
    fn detects_truncated_file() {
        let (base, _) = write_store(StoreKind::Dense, 6, 1);
        // truncate the data file
        let data = StoreMeta::data_path(&base.path);
        let full = std::fs::read(&data).unwrap();
        std::fs::write(&data, &full[..full.len() - 10]).unwrap();
        assert!(StoreReader::open(&base.path).is_err());
    }

    #[test]
    fn read_range_bounds() {
        let (base, _) = write_store(StoreKind::Factored, 10, 1);
        let r = StoreReader::open(&base.path).unwrap();
        assert!(r.read_range(8, 3).is_err());
        assert!(r.read_range(8, 2).is_ok());
    }
}

//! LoRIF: Low-Rank Influence Functions for scalable training data
//! attribution — full-system reproduction (Rust L3 coordinator).
//!
//! See DESIGN.md for the architecture and README.md for usage.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod app;
pub mod attribution;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod corpus;
pub mod curvature;
pub mod eval;
pub mod grads;
pub mod index;
pub mod linalg;
pub mod model;
pub mod query;
pub mod runtime;
pub mod sketch;
pub mod store;
pub mod telemetry;
pub mod util;

//! Process-wide observability: the metrics registry, Prometheus text
//! exposition, and per-query trace spans.  Zero external dependencies.
//!
//! Three pieces (see README "Observability" for the operator view):
//!
//! - [`registry`]: a lock-free [`Registry`] of atomic counters, gauges,
//!   and log-bucketed latency histograms that the store reader, chunk
//!   cache, pruning cursor, executor, worker pool, and server queue all
//!   publish into.  The existing per-pass structs (`StreamStats`,
//!   `ScoreReport`, the server `stats` blob) stay the working ledgers;
//!   they publish their deltas here at aggregation points, so ledger
//!   invariants like `bytes_read + bytes_skipped == full-scan bytes`
//!   hold identically when read through the registry (property-tested
//!   in `tests/prop.rs`).
//! - Exposition: [`Registry::render_prometheus`], served by the
//!   `{"cmd":"metrics"}` server verb and the `lorif metrics dump`
//!   subcommand; [`Registry::render_prometheus_with`] attaches a base
//!   label set (`{node="host:port",role="..."}`) to every sample.
//! - [`federation`]: parse/relabel/merge of scraped expositions — the
//!   coordinator's scrape loop federates every node's page into one
//!   merged exposition with per-node labels (see `query::fleet`).
//! - [`trace`]: Chrome trace-event spans behind `--trace-out <path>`,
//!   with per-query trace IDs threaded server → engine → executor →
//!   reader via the thread-local context below, and propagated over the
//!   line protocol (`"trace"` field) so node-side spans join the
//!   coordinator's query span in one Perfetto timeline.
//!
//! # Registry scoping
//!
//! Production code publishes into [`current_registry`], which resolves
//! to the process [`global`] registry unless a scope installed its own
//! via [`with_registry`].  Two consumers rely on the override: the
//! attribution server gives each instance a private registry (so
//! concurrently running servers — e.g. under `cargo test` — expose
//! coherent counters), and tests hand a fresh registry to a scoring
//! pass to assert exact ledger equality without cross-test pollution.
//! [`util::pool::run`](crate::util::pool::run) re-installs the spawning
//! thread's context inside every worker job, so the override (and the
//! trace ID) follows the shard fan-out across threads.

pub mod federation;
pub mod registry;
pub mod trace;

pub use registry::{escape_label_value, Counter, Gauge, Histogram, Registry};
pub use trace::TraceCtx;

use std::cell::RefCell;
use std::sync::{Arc, OnceLock};

/// The process-wide registry: what `lorif metrics dump` renders and
/// what every publisher falls back to when no scope override is set.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
}

/// Thread-local telemetry scope: which registry to publish into and
/// which query's trace track this thread is working for.
#[derive(Clone, Default)]
pub struct TelemetryCtx {
    pub registry: Option<Arc<Registry>>,
    pub trace: TraceCtx,
}

thread_local! {
    static CTX: RefCell<TelemetryCtx> = RefCell::new(TelemetryCtx::default());
}

/// Snapshot of the current thread's telemetry scope (cheap: one Arc
/// clone).  Worker pools capture this before spawning and re-install it
/// inside each job so scopes survive the thread hop.
pub fn current_ctx() -> TelemetryCtx {
    CTX.with(|c| c.borrow().clone())
}

/// The registry the current scope publishes into ([`global`] unless
/// overridden by [`with_registry`] / [`with_ctx`]).
pub fn current_registry() -> Arc<Registry> {
    CTX.with(|c| c.borrow().registry.clone()).unwrap_or_else(global)
}

/// Run `f` with `ctx` installed as this thread's telemetry scope,
/// restoring the previous scope afterwards (also on unwind).
pub fn with_ctx<R>(ctx: TelemetryCtx, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<TelemetryCtx>);
    impl Drop for Restore {
        fn drop(&mut self) {
            if let Some(prev) = self.0.take() {
                CTX.with(|c| *c.borrow_mut() = prev);
            }
        }
    }
    let prev = CTX.with(|c| std::mem::replace(&mut *c.borrow_mut(), ctx));
    let _restore = Restore(Some(prev));
    f()
}

/// Run `f` publishing into `reg` instead of the global registry,
/// keeping the current trace context.
pub fn with_registry<R>(reg: Arc<Registry>, f: impl FnOnce() -> R) -> R {
    let mut ctx = current_ctx();
    ctx.registry = Some(reg);
    with_ctx(ctx, f)
}

/// Run `f` on the given query's trace track, keeping the current
/// registry override.
pub fn with_trace<R>(trace: TraceCtx, f: impl FnOnce() -> R) -> R {
    let mut ctx = current_ctx();
    ctx.trace = trace;
    with_ctx(ctx, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_overrides_nest_and_restore() {
        let a = Arc::new(Registry::new());
        let b = Arc::new(Registry::new());
        // default scope: the global registry
        assert!(Arc::ptr_eq(&current_registry(), &global()));
        with_registry(a.clone(), || {
            assert!(Arc::ptr_eq(&current_registry(), &a));
            with_registry(b.clone(), || {
                assert!(Arc::ptr_eq(&current_registry(), &b));
            });
            // inner scope restored the outer override
            assert!(Arc::ptr_eq(&current_registry(), &a));
        });
        assert!(Arc::ptr_eq(&current_registry(), &global()));
    }

    #[test]
    fn scope_restores_on_unwind() {
        let a = Arc::new(Registry::new());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_registry(a.clone(), || panic!("boom"));
        }));
        assert!(r.is_err());
        assert!(Arc::ptr_eq(&current_registry(), &global()));
    }

    #[test]
    fn trace_ctx_rides_the_scope() {
        let t = TraceCtx { id: 42, lane: 0 };
        with_trace(t, || {
            assert_eq!(current_ctx().trace.id, 42);
            with_trace(t.with_lane(5), || {
                assert_eq!(current_ctx().trace.lane, 5);
            });
        });
        assert_eq!(current_ctx().trace, TraceCtx::default());
    }
}

//! Metrics federation: parse, relabel, and merge Prometheus text
//! expositions from many fleet members into one page.
//!
//! The coordinator's scrape loop collects each member's `{"cmd":"metrics"}`
//! exposition verbatim; [`merge`] re-renders the set as a single valid
//! exposition by injecting page-level labels (`node="host:port"`,
//! `role="node"`) into every sample line while emitting each family's
//! `# HELP`/`# TYPE` metadata exactly once.  Family ordering is stable:
//! first-seen across pages in page order, so the coordinator's own
//! families lead and every scrape of the same fleet renders families in
//! the same order.
//!
//! Parsing keeps sample values as their original strings (no
//! float-roundtrip drift); [`sample_value`] / [`samples`] parse a merged
//! page back into per-member numbers — the same helpers the cluster
//! tests use to reconcile the federated byte ledger against a local
//! full scan.

use std::collections::HashMap;

use super::registry::escape_label_value;

/// One scraped exposition plus the labels to inject into all its samples.
pub struct Page<'a> {
    pub labels: Vec<(String, String)>,
    pub text: &'a str,
}

impl<'a> Page<'a> {
    pub fn new(labels: &[(&str, &str)], text: &'a str) -> Page<'a> {
        Page {
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            text,
        }
    }
}

#[derive(Default)]
struct Family {
    help: Option<String>,
    typ: Option<String>,
    samples: Vec<String>,
}

fn touch<'m>(
    fams: &'m mut HashMap<String, Family>,
    order: &mut Vec<String>,
    name: &str,
) -> &'m mut Family {
    if !fams.contains_key(name) {
        order.push(name.to_string());
        fams.insert(name.to_string(), Family::default());
    }
    fams.get_mut(name).unwrap()
}

/// Merge expositions into one page.  Page labels are injected ahead of
/// any labels a sample already carries (so `le` stays last on histogram
/// buckets); on a name collision the page label wins.  `# HELP`/`# TYPE`
/// come from the first page that declares the family.
pub fn merge(pages: &[Page]) -> String {
    let mut fams: HashMap<String, Family> = HashMap::new();
    let mut order: Vec<String> = Vec::new();
    for page in pages {
        // samples are grouped under the most recent `# TYPE`/`# HELP`
        // family header, which is how `render_prometheus` lays them out
        // (`_bucket`/`_sum`/`_count` suffixes belong to the histogram
        // family, not a family of their own)
        let mut current = String::new();
        for line in page.text.lines() {
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let (name, help) = rest.split_once(' ').unwrap_or((rest, ""));
                current = name.to_string();
                let f = touch(&mut fams, &mut order, name);
                f.help.get_or_insert_with(|| help.to_string());
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, typ) = rest.split_once(' ').unwrap_or((rest, ""));
                current = name.to_string();
                let f = touch(&mut fams, &mut order, name);
                f.typ.get_or_insert_with(|| typ.to_string());
            } else if let Some((name, labels, value)) = parse_sample_line(line) {
                let fam = if !current.is_empty() && name.starts_with(current.as_str()) {
                    current.clone()
                } else {
                    name.clone()
                };
                let f = touch(&mut fams, &mut order, &fam);
                f.samples.push(relabel_line(&name, &page.labels, &labels, &value));
            }
        }
    }
    let mut out = String::new();
    for name in &order {
        let f = &fams[name];
        if let Some(h) = &f.help {
            out.push_str(&format!("# HELP {name} {h}\n"));
        }
        if let Some(t) = &f.typ {
            out.push_str(&format!("# TYPE {name} {t}\n"));
        }
        for s in &f.samples {
            out.push_str(s);
            out.push('\n');
        }
    }
    out
}

/// Rebuild one sample line with `page` labels injected ahead of the
/// labels it already carries; a page label shadows a same-named one.
fn relabel_line(
    name: &str,
    page: &[(String, String)],
    existing: &[(String, String)],
    value: &str,
) -> String {
    let mut all: Vec<(&str, &str)> =
        page.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    for (k, v) in existing {
        if !all.iter().any(|(pk, _)| pk == k) {
            all.push((k, v));
        }
    }
    if all.is_empty() {
        return format!("{name} {value}");
    }
    let body: Vec<String> = all
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{name}{{{}}} {value}", body.join(","))
}

/// Parse one exposition sample line into (metric name, labels, value
/// string).  Comment/blank lines return `None`.  Label values are
/// unescaped (`\\` `\"` `\n`), so a parse of a rendered line round-trips
/// the original value.
pub fn parse_sample_line(line: &str) -> Option<(String, Vec<(String, String)>, String)> {
    let line = line.trim_end();
    if line.is_empty() || line.starts_with('#') {
        return None;
    }
    let split = line.find(|c: char| c == '{' || c.is_whitespace())?;
    let name = line[..split].to_string();
    if name.is_empty() {
        return None;
    }
    let (labels, rest) = if line[split..].starts_with('{') {
        let (labels, consumed) = parse_labels(&line[split + 1..])?;
        (labels, &line[split + 1 + consumed..])
    } else {
        (Vec::new(), &line[split..])
    };
    let value = rest.trim().to_string();
    if value.is_empty() {
        return None;
    }
    Some((name, labels, value))
}

/// Parse a label body starting just past `{`; returns the labels and
/// the byte offset just past the closing `}`.
fn parse_labels(s: &str) -> Option<(Vec<(String, String)>, usize)> {
    let b = s.as_bytes();
    let mut i = 0usize;
    let mut labels = Vec::new();
    loop {
        while i < b.len() && (b[i] == b',' || b[i] == b' ') {
            i += 1;
        }
        if i < b.len() && b[i] == b'}' {
            return Some((labels, i + 1));
        }
        let k0 = i;
        while i < b.len() && b[i] != b'=' {
            i += 1;
        }
        if i >= b.len() {
            return None;
        }
        let key = s[k0..i].trim().to_string();
        i += 1; // '='
        if i >= b.len() || b[i] != b'"' {
            return None;
        }
        i += 1;
        let mut val = String::new();
        loop {
            if i >= b.len() {
                return None;
            }
            match b[i] {
                b'\\' => {
                    if i + 1 >= b.len() {
                        return None;
                    }
                    match b[i + 1] {
                        b'\\' => val.push('\\'),
                        b'"' => val.push('"'),
                        b'n' => val.push('\n'),
                        c => {
                            val.push('\\');
                            val.push(c as char);
                        }
                    }
                    i += 2;
                }
                b'"' => {
                    i += 1;
                    break;
                }
                _ => {
                    let ch = s[i..].chars().next()?;
                    val.push(ch);
                    i += ch.len_utf8();
                }
            }
        }
        labels.push((key, val));
    }
}

/// First sample of `name` whose label set contains every `(k, v)` in
/// `labels`, parsed as f64.
pub fn sample_value(text: &str, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
    for line in text.lines() {
        let Some((n, ls, value)) = parse_sample_line(line) else { continue };
        if n != name {
            continue;
        }
        if labels.iter().all(|(k, v)| ls.iter().any(|(lk, lv)| lk == k && lv == v)) {
            return value.parse().ok();
        }
    }
    None
}

/// All samples of `name`: (labels, value) per matching line, in order.
pub fn samples(text: &str, name: &str) -> Vec<(Vec<(String, String)>, f64)> {
    text.lines()
        .filter_map(parse_sample_line)
        .filter(|(n, _, _)| n == name)
        .filter_map(|(_, ls, v)| v.parse().ok().map(|f| (ls, f)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    /// A rendered label survives the parse: escape → render → parse
    /// recovers the original bytes, including `\` `"` and newlines.
    #[test]
    fn label_escaping_round_trips_through_the_parser() {
        let nasty = "path\\to \"x\"\nline2";
        let reg = Registry::new();
        reg.server_served.add(5);
        let text = reg.render_prometheus_with(&[("node", nasty)]);
        let line = text
            .lines()
            .find(|l| l.starts_with("lorif_server_served_total{"))
            .unwrap();
        let (name, labels, value) = parse_sample_line(line).unwrap();
        assert_eq!(name, "lorif_server_served_total");
        assert_eq!(labels, vec![("node".to_string(), nasty.to_string())]);
        assert_eq!(value, "5");
    }

    #[test]
    fn parse_sample_line_shapes() {
        assert_eq!(
            parse_sample_line("m_total 3"),
            Some(("m_total".to_string(), vec![], "3".to_string()))
        );
        let (n, ls, v) =
            parse_sample_line("h_bucket{node=\"a:1\",le=\"+Inf\"} 12").unwrap();
        assert_eq!(n, "h_bucket");
        assert_eq!(ls.len(), 2);
        assert_eq!(ls[1], ("le".to_string(), "+Inf".to_string()));
        assert_eq!(v, "12");
        assert_eq!(parse_sample_line("# HELP m help"), None);
        assert_eq!(parse_sample_line(""), None);
        assert_eq!(parse_sample_line("dangling{k=\"v\" "), None);
    }

    /// Merging two node pages: families keep first-seen order, metadata
    /// is emitted once, every sample gains the page's `node` label ahead
    /// of existing labels (`le` stays last), and the merged page parses
    /// back into the per-node values that went in.
    #[test]
    fn merge_relabels_and_parses_back() {
        let a = Registry::new();
        a.store_bytes_read.add(100);
        a.store_bytes_skipped.add(40);
        a.query_latency.observe_secs(1e-6);
        let b = Registry::new();
        b.store_bytes_read.add(250);
        let ta = a.render_prometheus();
        let tb = b.render_prometheus();
        let merged = merge(&[
            Page::new(&[("node", "n0:1"), ("role", "node")], &ta),
            Page::new(&[("node", "n1:2"), ("role", "node")], &tb),
        ]);

        // metadata once per family, unlabeled
        assert_eq!(
            merged.matches("# TYPE lorif_store_bytes_read_total counter\n").count(),
            1
        );
        // one sample per page, labeled
        assert!(merged.contains("lorif_store_bytes_read_total{node=\"n0:1\",role=\"node\"} 100\n"));
        assert!(merged.contains("lorif_store_bytes_read_total{node=\"n1:2\",role=\"node\"} 250\n"));
        // histogram bucket: node labels first, `le` last, under the
        // histogram family's metadata (not a family of its own)
        assert!(merged.contains(
            "lorif_query_latency_seconds_bucket{node=\"n0:1\",role=\"node\",le=\"0.000001\"} 1\n"
        ));
        assert!(!merged.contains("# TYPE lorif_query_latency_seconds_bucket"));

        // family order is first-seen page order == the registry table order
        let first = merged.find("# TYPE lorif_store_bytes_read_total").unwrap();
        let later = merged.find("# TYPE lorif_query_latency_seconds h").unwrap();
        assert!(first < later);

        // parse-back: per-node values recoverable from the merged page
        assert_eq!(
            sample_value(&merged, "lorif_store_bytes_read_total", &[("node", "n0:1")]),
            Some(100.0)
        );
        assert_eq!(
            sample_value(&merged, "lorif_store_bytes_read_total", &[("node", "n1:2")]),
            Some(250.0)
        );
        let all = samples(&merged, "lorif_store_bytes_read_total");
        assert_eq!(all.len(), 2);
        assert_eq!(all.iter().map(|(_, v)| *v).sum::<f64>(), 350.0);
        // the n0 ledger reconciles: read + skipped == 140
        let skipped =
            sample_value(&merged, "lorif_store_bytes_skipped_total", &[("node", "n0:1")]);
        assert_eq!(skipped, Some(40.0));
    }

    /// Stable ordering across scrapes: merging the same fleet twice
    /// yields identical family order even if a later page declares a
    /// family the first page lacked.
    #[test]
    fn family_order_is_first_seen_and_deterministic() {
        let pa = "# HELP a ha\n# TYPE a counter\na 1\n";
        let pb = "# HELP b hb\n# TYPE b counter\nb 2\n# HELP a ha\n# TYPE a counter\na 3\n";
        let m1 = merge(&[Page::new(&[("node", "x")], pa), Page::new(&[("node", "y")], pb)]);
        let m2 = merge(&[Page::new(&[("node", "x")], pa), Page::new(&[("node", "y")], pb)]);
        assert_eq!(m1, m2);
        // `a` seen first (page order), so it renders before `b`
        assert!(m1.find("# TYPE a counter").unwrap() < m1.find("# TYPE b counter").unwrap());
        // both pages' `a` samples collected under one family block
        assert!(m1.contains("a{node=\"x\"} 1\n"));
        assert!(m1.contains("a{node=\"y\"} 3\n"));
    }

    /// A page label shadows a same-named label already on the sample —
    /// the scraper's identity wins over whatever the member claimed.
    #[test]
    fn page_label_shadows_existing_label() {
        let page = "# TYPE m counter\nm{role=\"imposter\",zone=\"z1\"} 9\n";
        let merged = merge(&[Page::new(&[("role", "node")], page)]);
        assert!(merged.contains("m{role=\"node\",zone=\"z1\"} 9\n"));
    }
}

//! Hierarchical trace spans emitted as Chrome trace-event JSON
//! (`catapult` format), viewable in Perfetto / `chrome://tracing`.
//!
//! Tracing is off unless `--trace-out <path>` installs the process-wide
//! writer; with no writer installed, [`span`] returns `None` and the
//! hot paths pay a single static load.  Each query pass gets a fresh
//! trace ID ([`TraceCtx::next_query`]) that rides the thread-local
//! telemetry context (`telemetry::with_ctx`) from the server through
//! the engine and executor down to per-chunk reads — the worker pool
//! re-installs the spawning thread's context inside each job, so the
//! shard fan-out stays attached to its query.
//!
//! Events are "complete" spans (`ph:"X"`, begin timestamp + duration in
//! microseconds) written one JSON object per line after an opening
//! `[` — the trace-event JSON-array format, which Perfetto accepts
//! without a closing bracket, so a crashed process still leaves a
//! loadable trace.  One span tree per query: the track ID (`tid`) is
//! `trace_id * 4096 + lane`, where lane 0 is the query root and lane
//! `1 + shard` carries that shard's chunk visits, so a query's fan-out
//! groups into adjacent tracks.  The event `pid` is the real OS process
//! ID, so per-process trace files from a coordinator and its shard
//! nodes concatenate into one timeline with distinct process groups —
//! and because the coordinator forwards its trace ID over the line
//! protocol (`"trace"` field), a node's `server_batch` track for a
//! scattered query carries the same `trace_id` as the coordinator's
//! scatter span.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Value;

/// Per-query trace identity carried in the thread-local telemetry
/// context: a process-unique query ID plus the lane (track) within
/// that query's span tree.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub id: u64,
    pub lane: u32,
}

static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

impl TraceCtx {
    /// Allocate a fresh trace ID for a new query pass (lane 0 = root).
    pub fn next_query() -> TraceCtx {
        TraceCtx { id: NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed), lane: 0 }
    }

    /// The same query on a different track (shard workers use
    /// `lane = 1 + shard` so each shard's chunk spans nest cleanly).
    pub fn with_lane(self, lane: u32) -> TraceCtx {
        TraceCtx { id: self.id, lane }
    }

    fn tid(self) -> u64 {
        self.id * 4096 + self.lane as u64
    }
}

/// A trace-event sink: one output file plus the monotonic epoch all
/// event timestamps are relative to.  Instantiable for tests; the
/// process-wide instance is installed once by [`init`].
pub struct TraceWriter {
    out: Mutex<BufWriter<File>>,
    epoch: Instant,
}

impl TraceWriter {
    pub fn create(path: &Path) -> std::io::Result<TraceWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "[")?;
        out.flush()?;
        Ok(TraceWriter { out: Mutex::new(out), epoch: Instant::now() })
    }

    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// `args` values must already be rendered JSON (numbers, or strings
    /// via [`Value::Str`]).
    fn render_args(args: &[(&'static str, String)], ctx: TraceCtx) -> String {
        let mut a = format!("\"trace_id\":{}", ctx.id);
        for (k, v) in args {
            a.push_str(&format!(",{}:{v}", Value::Str((*k).to_string())));
        }
        a
    }

    pub fn complete_event(
        &self,
        name: &str,
        ctx: TraceCtx,
        start_us: u64,
        dur_us: u64,
        args: &[(&'static str, String)],
    ) {
        let line = format!(
            "{{\"name\":{},\"cat\":\"lorif\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{start_us},\"dur\":{dur_us},\"args\":{{{}}}}}",
            Value::Str(name.to_string()),
            std::process::id(),
            ctx.tid(),
            Self::render_args(args, ctx),
        );
        self.write_line(&line);
    }

    /// Thread-scoped instant event (prune skips, cache hits, ...).
    pub fn instant_event(&self, name: &str, ctx: TraceCtx, args: &[(&'static str, String)]) {
        let line = format!(
            "{{\"name\":{},\"cat\":\"lorif\",\"ph\":\"i\",\"s\":\"t\",\"pid\":{},\"tid\":{},\"ts\":{},\"args\":{{{}}}}}",
            Value::Str(name.to_string()),
            std::process::id(),
            ctx.tid(),
            self.now_us(),
            Self::render_args(args, ctx),
        );
        self.write_line(&line);
    }

    fn write_line(&self, line: &str) {
        // a poisoned writer just means another emitter panicked mid-line;
        // tracing is diagnostic, so drop the event rather than propagate
        if let Ok(mut out) = self.out.lock() {
            let _ = writeln!(out, "{line},");
            let _ = out.flush();
        }
    }
}

static WRITER: OnceLock<TraceWriter> = OnceLock::new();

/// Install the process-wide trace writer (the `--trace-out` knob).
/// Idempotent: the first path wins, later calls are no-ops.
pub fn init(path: &Path) -> std::io::Result<()> {
    if WRITER.get().is_some() {
        return Ok(());
    }
    let w = TraceWriter::create(path)?;
    let _ = WRITER.set(w);
    Ok(())
}

pub fn enabled() -> bool {
    WRITER.get().is_some()
}

/// An in-flight span: emits one complete event on drop.  `None` when
/// tracing is disabled, so call sites write
/// `let _sp = trace::span("load");` and pay nothing in the common case.
pub struct Span {
    name: &'static str,
    ctx: TraceCtx,
    start_us: u64,
    t0: Instant,
    args: Vec<(&'static str, String)>,
}

/// Open a span on the current thread's trace track.
pub fn span(name: &'static str) -> Option<Span> {
    let ctx = WRITER.get().map(|_| super::current_ctx().trace)?;
    span_ctx(name, ctx)
}

/// Open a span on lane `lane` of the current query's track group —
/// shard workers use `lane = 1 + shard` so each shard's chunk visits
/// render on their own Perfetto track.
pub fn span_on(name: &'static str, lane: u32) -> Option<Span> {
    let ctx = WRITER.get().map(|_| super::current_ctx().trace.with_lane(lane))?;
    span_ctx(name, ctx)
}

fn span_ctx(name: &'static str, ctx: TraceCtx) -> Option<Span> {
    let w = WRITER.get()?;
    Some(Span { name, ctx, start_us: w.now_us(), t0: Instant::now(), args: Vec::new() })
}

impl Span {
    /// Attach a numeric argument (rendered as a bare JSON number).
    pub fn arg<T: std::fmt::Display>(&mut self, key: &'static str, value: T) {
        self.args.push((key, value.to_string()));
    }

    /// Attach a string argument (JSON-escaped).
    pub fn arg_str(&mut self, key: &'static str, value: &str) {
        self.args.push((key, Value::Str(value.to_string()).to_string()));
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(w) = WRITER.get() {
            let dur = self.t0.elapsed().as_micros() as u64;
            w.complete_event(self.name, self.ctx, self.start_us, dur, &self.args);
        }
    }
}

/// Emit an instant event on the current thread's trace track.
pub fn instant(name: &'static str, args: &[(&'static str, String)]) {
    if let Some(w) = WRITER.get() {
        w.instant_event(name, super::current_ctx().trace, args);
    }
}

/// Emit an instant event on lane `lane` of the current query's track
/// group (see [`span_on`]).
pub fn instant_on(name: &'static str, lane: u32, args: &[(&'static str, String)]) {
    if let Some(w) = WRITER.get() {
        w.instant_event(name, super::current_ctx().trace.with_lane(lane), args);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every line after the opening `[` must parse as a standalone JSON
    /// object (modulo the trailing comma) with the trace-event fields —
    /// that is exactly what Perfetto's tolerant array reader consumes.
    #[test]
    fn trace_file_lines_are_valid_trace_events() {
        let dir = std::env::temp_dir().join(format!("lorif-trace-test-{}", std::process::id()));
        let path = dir.join("trace.json");
        let w = TraceWriter::create(&path).unwrap();
        let ctx = TraceCtx { id: 7, lane: 0 };
        w.complete_event("query", ctx, 10, 25, &[("bytes", "4096".to_string())]);
        w.instant_event("prune_skip", ctx.with_lane(2), &[]);
        drop(w);

        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some("["));
        let events: Vec<Value> = lines
            .map(|l| Value::parse(l.trim_end_matches(',')).expect("event line parses"))
            .collect();
        assert_eq!(events.len(), 2);
        let q = &events[0];
        assert_eq!(q.get("name").and_then(Value::as_str), Some("query"));
        assert_eq!(q.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(q.get("ts").and_then(Value::as_f64), Some(10.0));
        assert_eq!(q.get("dur").and_then(Value::as_f64), Some(25.0));
        assert_eq!(q.get("tid").and_then(Value::as_f64), Some((7 * 4096) as f64));
        // pid is the real OS pid so multi-process traces merge cleanly
        assert_eq!(
            q.get("pid").and_then(Value::as_f64),
            Some(std::process::id() as f64)
        );
        assert_eq!(
            q.get("args").and_then(|a| a.get("trace_id")).and_then(Value::as_f64),
            Some(7.0)
        );
        assert_eq!(
            q.get("args").and_then(|a| a.get("bytes")).and_then(Value::as_f64),
            Some(4096.0)
        );
        let i = &events[1];
        assert_eq!(i.get("ph").and_then(Value::as_str), Some("i"));
        assert_eq!(i.get("tid").and_then(Value::as_f64), Some((7 * 4096 + 2) as f64));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_ids_are_unique_and_lanes_offset_the_track() {
        let a = TraceCtx::next_query();
        let b = TraceCtx::next_query();
        assert_ne!(a.id, b.id);
        assert_eq!(a.with_lane(3).tid(), a.id * 4096 + 3);
    }
}

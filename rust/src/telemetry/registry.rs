//! Lock-free metrics registry: atomic counters, gauges, and log-bucketed
//! latency histograms, plus Prometheus text exposition of the whole set.
//!
//! Every metric family is pre-registered as a plain struct field, so the
//! hot publish path is a single atomic RMW — no locks, no maps, no
//! allocation — and exposition always emits every family (with `# HELP`
//! and `# TYPE` lines) even when a counter is still zero.  That property
//! is load-bearing: `lorif metrics dump` in a fresh process must still
//! show the full schema so scrapers and CI greps can rely on the names.
//!
//! Naming follows Prometheus conventions: `lorif_` prefix, `_total`
//! suffix on counters, base units (bytes, seconds) in the name.  Time
//! counters and histogram samples are stored internally as integer
//! microseconds (atomics can't add f64s) and rendered as seconds.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotone event counter (u64).  Time-valued counters store integer
/// microseconds via [`Counter::add_secs`] and render as seconds.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Accumulate a duration in seconds (stored as integer microseconds).
    pub fn add_secs(&self, s: f64) {
        self.add((s.max(0.0) * 1e6).round() as u64);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// The accumulated value interpreted as seconds (for counters fed
    /// through [`Counter::add_secs`]).
    pub fn secs(&self) -> f64 {
        self.get() as f64 / 1e6
    }
}

/// Last-write-wins instantaneous value (queue depth, resident bytes, ...).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Saturating decrement (a racy extra `sub` must not wrap to 2^64).
    pub fn sub(&self, n: u64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(n))
        });
    }

    /// Raise the gauge to `v` if `v` is larger (high-water marks).
    pub fn max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log-spaced histogram buckets: bucket `i` covers latencies
/// up to `2^i` microseconds, so 32 buckets span 1µs .. ~36min.
pub const HIST_BUCKETS: usize = 32;

/// Log-bucketed latency histogram with lock-free `observe` and
/// p50/p95/p99 accessors.  A quantile is reported as the upper bound of
/// the bucket it lands in (a ≤2× overestimate by construction), which
/// is exactly the resolution Prometheus `le` buckets give a scraper.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// Smallest bucket index whose upper bound (`2^i` µs) holds `us`.
fn bucket_index(us: u64) -> usize {
    if us <= 1 {
        0
    } else {
        ((64 - (us - 1).leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Upper bound of bucket `i`, in microseconds.
fn bucket_bound_us(i: usize) -> u64 {
    1u64 << i
}

/// Render a microsecond quantity as seconds, fixed six decimals so the
/// exposition text (and its golden test) is deterministic.
fn fmt_secs(us: u64) -> String {
    format!("{:.6}", us as f64 / 1e6)
}

impl Histogram {
    pub fn observe_secs(&self, s: f64) {
        let us = (s.max(0.0) * 1e6).round() as u64;
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_dur(&self, d: Duration) {
        self.observe_secs(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Quantile in seconds: upper bound of the bucket holding the
    /// `q`-th sample (0 when the histogram is empty).
    pub fn quantile(&self, q: f64) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_bound_us(i) as f64 / 1e6;
            }
        }
        bucket_bound_us(HIST_BUCKETS - 1) as f64 / 1e6
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

/// The process-wide metric schema: every family the store reader, chunk
/// cache, pruning cursor, executor, worker pool, and server queue
/// publish into.  Plain struct fields keep the publish path lock-free
/// and make the full schema visible in one place; adding a metric means
/// adding a field here and a row to the exposition table in
/// [`Registry::render_prometheus`].
#[derive(Default)]
pub struct Registry {
    // -- store I/O (source: `StreamStats`, see `store::reader`) --
    pub store_bytes_read: Counter,
    pub store_bytes_skipped: Counter,
    pub store_bytes_from_cache: Counter,
    pub store_chunks_read: Counter,
    pub store_chunks_skipped: Counter,
    // -- chunk cache (source: `store::cache::ChunkCache`) --
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub cache_insertions: Counter,
    pub cache_evictions: Counter,
    pub cache_resident_bytes: Gauge,
    pub cache_capacity_bytes: Gauge,
    pub cache_entries: Gauge,
    // -- pruning (source: `sketch::prune` bounds + the chunk cursor) --
    pub prune_bound_evals: Counter,
    pub prune_chunks_skipped: Counter,
    pub prune_bytes_skipped: Counter,
    // -- executor phases (source: `attribution::exec::execute`) --
    pub exec_passes: Counter,
    pub exec_load_seconds: Counter,
    pub exec_compute_seconds: Counter,
    pub exec_precondition_seconds: Counter,
    pub exec_peak_sink_elems: Gauge,
    // -- worker pool (source: `util::pool::run`) --
    pub pool_jobs: Counter,
    pub pool_job_errors: Counter,
    // -- query latency (source: `query::engine::QueryEngine::run`) --
    pub query_latency: Histogram,
    // -- server queue (source: `query::server`) --
    pub server_submitted: Counter,
    pub server_served: Counter,
    pub server_shed: Counter,
    pub server_failed: Counter,
    pub server_dropped: Counter,
    pub server_batches: Counter,
    pub server_batch_errors: Counter,
    pub server_queue_depth: Gauge,
    pub server_workers: Gauge,
    pub server_batch_wall: Histogram,
    // -- distributed plane (source: `query::coordinator` + node mode) --
    pub coord_scatter: Counter,
    pub coord_gather: Counter,
    pub coord_retry: Counter,
    pub coord_failover: Counter,
    pub node_queries: Counter,
    pub node_shards: Gauge,
    /// Scatter requests sent straight to a replica because the health
    /// probe already marked the primary down (no io-timeout paid).
    pub coord_reroute: Counter,
    // -- fleet monitor (source: `query::fleet::Fleet`) --
    pub probe_attempts: Counter,
    pub probe_failures: Counter,
    pub probe_transitions: Counter,
    pub fleet_scrapes: Counter,
    pub fleet_scrape_errors: Counter,
    pub fleet_nodes_healthy: Gauge,
    pub fleet_nodes_degraded: Gauge,
    pub fleet_nodes_down: Gauge,
    // -- slow-query ring (source: `query::server` via `query::slowlog`) --
    pub slowlog_admitted: Counter,
    pub slowlog_entries: Gauge,
}

/// How a registry field renders: plain counter, seconds-valued counter,
/// gauge, or histogram.
enum Slot<'a> {
    C(&'a Counter),
    S(&'a Counter),
    G(&'a Gauge),
    H(&'a Histogram),
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The full exposition table: (metric name, help text, slot).
    /// Order here is the order families appear in the exposition.
    fn table(&self) -> Vec<(&'static str, &'static str, Slot<'_>)> {
        use Slot::*;
        vec![
            (
                "lorif_store_bytes_read_total",
                "Bytes read from the gradient store (on-disk encoded size).",
                C(&self.store_bytes_read),
            ),
            (
                "lorif_store_bytes_skipped_total",
                "Store bytes skipped without reading (pruned chunks, on-disk size).",
                C(&self.store_bytes_skipped),
            ),
            (
                "lorif_store_bytes_from_cache_total",
                "Store bytes served from the chunk cache instead of disk.",
                C(&self.store_bytes_from_cache),
            ),
            (
                "lorif_store_chunks_read_total",
                "Store chunks read (from disk or cache).",
                C(&self.store_chunks_read),
            ),
            (
                "lorif_store_chunks_skipped_total",
                "Store chunks skipped by pruning bounds.",
                C(&self.store_chunks_skipped),
            ),
            (
                "lorif_cache_hits_total",
                "Chunk-cache lookups that found the chunk resident.",
                C(&self.cache_hits),
            ),
            (
                "lorif_cache_misses_total",
                "Chunk-cache lookups that missed.",
                C(&self.cache_misses),
            ),
            (
                "lorif_cache_insertions_total",
                "Chunks inserted into the chunk cache.",
                C(&self.cache_insertions),
            ),
            (
                "lorif_cache_evictions_total",
                "Chunks evicted from the chunk cache by the CLOCK sweep.",
                C(&self.cache_evictions),
            ),
            (
                "lorif_cache_resident_bytes",
                "Bytes currently resident in the chunk cache.",
                G(&self.cache_resident_bytes),
            ),
            (
                "lorif_cache_capacity_bytes",
                "Configured chunk-cache byte budget.",
                G(&self.cache_capacity_bytes),
            ),
            (
                "lorif_cache_entries",
                "Chunks currently resident in the chunk cache.",
                G(&self.cache_entries),
            ),
            (
                "lorif_prune_bound_evals_total",
                "Per-chunk upper-bound evaluations performed by the pruner.",
                C(&self.prune_bound_evals),
            ),
            (
                "lorif_prune_chunks_skipped_total",
                "Chunks the pruner proved could not reach the threshold.",
                C(&self.prune_chunks_skipped),
            ),
            (
                "lorif_prune_bytes_skipped_total",
                "On-disk bytes of chunks skipped by the pruner.",
                C(&self.prune_bytes_skipped),
            ),
            (
                "lorif_exec_passes_total",
                "Completed executor scoring passes.",
                C(&self.exec_passes),
            ),
            (
                "lorif_exec_load_seconds_total",
                "Executor time spent loading/decoding store chunks.",
                S(&self.exec_load_seconds),
            ),
            (
                "lorif_exec_compute_seconds_total",
                "Executor time spent in score kernels.",
                S(&self.exec_compute_seconds),
            ),
            (
                "lorif_exec_precondition_seconds_total",
                "Executor time spent preconditioning queries.",
                S(&self.exec_precondition_seconds),
            ),
            (
                "lorif_exec_peak_sink_elems",
                "High-water mark of score-sink resident elements.",
                G(&self.exec_peak_sink_elems),
            ),
            (
                "lorif_pool_jobs_total",
                "Jobs executed by the scoped worker pool.",
                C(&self.pool_jobs),
            ),
            (
                "lorif_pool_job_errors_total",
                "Worker-pool jobs that returned an error or panicked.",
                C(&self.pool_job_errors),
            ),
            (
                "lorif_query_latency_seconds",
                "Wall time of one engine scoring pass (per query batch).",
                H(&self.query_latency),
            ),
            (
                "lorif_server_submitted_total",
                "Query submissions received by the attribution server.",
                C(&self.server_submitted),
            ),
            (
                "lorif_server_served_total",
                "Query submissions answered with scores.",
                C(&self.server_served),
            ),
            (
                "lorif_server_shed_total",
                "Query submissions shed by admission control (queue full).",
                C(&self.server_shed),
            ),
            (
                "lorif_server_failed_total",
                "Query submissions that failed in a scoring batch.",
                C(&self.server_failed),
            ),
            (
                "lorif_server_dropped_total",
                "Query submissions dropped at shutdown before scoring.",
                C(&self.server_dropped),
            ),
            (
                "lorif_server_batches_total",
                "Scoring batches executed by the server worker pool.",
                C(&self.server_batches),
            ),
            (
                "lorif_server_batch_errors_total",
                "Scoring batches that failed outright.",
                C(&self.server_batch_errors),
            ),
            (
                "lorif_server_queue_depth",
                "Submissions currently waiting in the admission queue.",
                G(&self.server_queue_depth),
            ),
            (
                "lorif_server_workers",
                "Scoring worker threads attached to the server.",
                G(&self.server_workers),
            ),
            (
                "lorif_server_batch_wall_seconds",
                "Wall time from batch admission to reply.",
                H(&self.server_batch_wall),
            ),
            (
                "lorif_coord_scatter_total",
                "Per-node scatter requests issued by the coordinator.",
                C(&self.coord_scatter),
            ),
            (
                "lorif_coord_gather_total",
                "Per-node replies gathered and merged by the coordinator.",
                C(&self.coord_gather),
            ),
            (
                "lorif_coord_retry_total",
                "Scatter attempts retried after a node error or timeout.",
                C(&self.coord_retry),
            ),
            (
                "lorif_coord_failover_total",
                "Scatter attempts answered by a replica after its primary failed.",
                C(&self.coord_failover),
            ),
            (
                "lorif_node_queries_total",
                "Query batches scored by this process in shard-node mode.",
                C(&self.node_queries),
            ),
            (
                "lorif_node_shards",
                "Manifest shards this process serves (node mode; 0 = all).",
                G(&self.node_shards),
            ),
            (
                "lorif_coord_reroute_total",
                "Scatter requests routed proactively to a replica of a probe-down primary.",
                C(&self.coord_reroute),
            ),
            (
                "lorif_probe_attempts_total",
                "Health probes issued by the fleet monitor.",
                C(&self.probe_attempts),
            ),
            (
                "lorif_probe_failures_total",
                "Health probes that failed (connect error, timeout, or bad reply).",
                C(&self.probe_failures),
            ),
            (
                "lorif_probe_transitions_total",
                "Endpoint health-state transitions (probe- or scatter-evidenced).",
                C(&self.probe_transitions),
            ),
            (
                "lorif_fleet_scrapes_total",
                "Federation scrapes of member metrics expositions.",
                C(&self.fleet_scrapes),
            ),
            (
                "lorif_fleet_scrape_errors_total",
                "Federation scrapes that failed.",
                C(&self.fleet_scrape_errors),
            ),
            (
                "lorif_fleet_nodes_healthy",
                "Monitored endpoints currently in the healthy state.",
                G(&self.fleet_nodes_healthy),
            ),
            (
                "lorif_fleet_nodes_degraded",
                "Monitored endpoints currently in the degraded state.",
                G(&self.fleet_nodes_degraded),
            ),
            (
                "lorif_fleet_nodes_down",
                "Monitored endpoints currently in the down state.",
                G(&self.fleet_nodes_down),
            ),
            (
                "lorif_slowlog_admitted_total",
                "Batches admitted into the slow-query ring.",
                C(&self.slowlog_admitted),
            ),
            (
                "lorif_slowlog_entries",
                "Entries currently resident in the slow-query ring.",
                G(&self.slowlog_entries),
            ),
        ]
    }

    /// Prometheus text exposition (version 0.0.4) of every family.
    pub fn render_prometheus(&self) -> String {
        self.render_prometheus_with(&[])
    }

    /// Exposition with a base label set attached to every sample line
    /// (`{node="host:port",role="node"}`).  `# HELP`/`# TYPE` lines are
    /// per-family and stay unlabeled; histogram samples merge the base
    /// labels with their `le` bucket label (base labels first, so a
    /// federated exposition groups by node before bucket).  An empty
    /// label set renders byte-identically to [`Registry::render_prometheus`].
    pub fn render_prometheus_with(&self, labels: &[(&str, &str)]) -> String {
        let lb = label_block(labels);
        let mut out = String::new();
        for (name, help, slot) in self.table() {
            out.push_str(&format!("# HELP {name} {help}\n"));
            match slot {
                Slot::C(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n"));
                    out.push_str(&format!("{name}{lb} {}\n", c.get()));
                }
                Slot::S(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n"));
                    out.push_str(&format!("{name}{lb} {}\n", fmt_secs(c.get())));
                }
                Slot::G(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n"));
                    out.push_str(&format!("{name}{lb} {}\n", g.get()));
                }
                Slot::H(h) => {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    render_histogram(&mut out, name, h, labels);
                }
            }
        }
        out
    }
}

/// Escape a label value per the Prometheus text format (0.0.4):
/// backslash, double quote, and newline get backslash escapes.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Render `{k="v",...}` with escaped values; empty input renders as the
/// empty string so unlabeled expositions keep their exact legacy shape.
pub fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{{{}}}", body.join(","))
}

/// Cumulative `_bucket{le=...}` lines up to the highest non-empty
/// bucket, then `+Inf`, `_sum`, `_count` — the standard histogram
/// exposition shape.  An empty histogram renders just the `+Inf`
/// bucket so the family is still present and parseable.
fn render_histogram(out: &mut String, name: &str, h: &Histogram, labels: &[(&str, &str)]) {
    let le_block = |bound: &str| {
        let mut pairs: Vec<(&str, &str)> = labels.to_vec();
        pairs.push(("le", bound));
        label_block(&pairs)
    };
    let lb = label_block(labels);
    let counts: Vec<u64> =
        h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
    let last = counts.iter().rposition(|&c| c > 0);
    let mut cum = 0u64;
    if let Some(last) = last {
        for (i, c) in counts.iter().enumerate().take(last + 1) {
            cum += c;
            out.push_str(&format!(
                "{name}_bucket{} {cum}\n",
                le_block(&fmt_secs(bucket_bound_us(i)))
            ));
        }
    }
    out.push_str(&format!("{name}_bucket{} {}\n", le_block("+Inf"), h.count()));
    out.push_str(&format!("{name}_sum{lb} {}\n", fmt_secs(h.sum_us.load(Ordering::Relaxed))));
    out.push_str(&format!("{name}_count{lb} {}\n", h.count()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        c.add_secs(0.5);
        assert_eq!(c.get(), 42 + 500_000);

        let g = Gauge::default();
        g.set(7);
        g.add(3);
        g.sub(4);
        assert_eq!(g.get(), 6);
        g.sub(100); // saturates, never wraps
        assert_eq!(g.get(), 0);
        g.max(9);
        g.max(2);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        assert_eq!(h.p50(), 0.0); // empty
        // 98 fast samples at ~1µs, 2 slow at ~1s (2^20us bucket).
        for _ in 0..98 {
            h.observe_secs(1e-6);
        }
        for _ in 0..2 {
            h.observe_secs(1.0);
        }
        assert_eq!(h.count(), 100);
        // p50/p95 land in the 1µs bucket; p99 lands in the slow bucket,
        // whose upper bound is 2^20µs = 1.048576s.
        assert!((h.p50() - 1e-6).abs() < 1e-12);
        assert!((h.p95() - 1e-6).abs() < 1e-12);
        assert!((h.p99() - 1.048576).abs() < 1e-9);
        assert!((h.sum_secs() - (98.0 * 1e-6 + 2.0)).abs() < 1e-6);
    }

    #[test]
    fn bucket_index_is_smallest_covering_power() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    /// Golden test for the exposition grammar: exact text for a family
    /// of each type, plus schema-wide invariants (every family emits
    /// `# HELP` then `# TYPE`, and the required families exist even in
    /// a fresh registry).
    #[test]
    fn golden_exposition_format() {
        let reg = Registry::new();
        reg.store_bytes_read.add(4096);
        reg.server_queue_depth.set(3);
        reg.query_latency.observe_secs(1e-6);
        reg.query_latency.observe_secs(3e-6);
        let text = reg.render_prometheus();

        // counter family, exact shape
        assert!(text.contains(
            "# HELP lorif_store_bytes_read_total Bytes read from the gradient store (on-disk encoded size).\n\
             # TYPE lorif_store_bytes_read_total counter\n\
             lorif_store_bytes_read_total 4096\n"
        ));
        // gauge family, exact shape
        assert!(text.contains(
            "# TYPE lorif_server_queue_depth gauge\nlorif_server_queue_depth 3\n"
        ));
        // histogram family: cumulative buckets, +Inf, sum, count
        assert!(text.contains(
            "# TYPE lorif_query_latency_seconds histogram\n\
             lorif_query_latency_seconds_bucket{le=\"0.000001\"} 1\n\
             lorif_query_latency_seconds_bucket{le=\"0.000002\"} 1\n\
             lorif_query_latency_seconds_bucket{le=\"0.000004\"} 2\n\
             lorif_query_latency_seconds_bucket{le=\"+Inf\"} 2\n\
             lorif_query_latency_seconds_sum 0.000004\n\
             lorif_query_latency_seconds_count 2\n"
        ));

        // schema-wide: every family present with HELP immediately
        // followed by TYPE, and seconds counters render as floats
        for family in [
            "lorif_store_bytes_skipped_total",
            "lorif_store_bytes_from_cache_total",
            "lorif_cache_hits_total",
            "lorif_prune_chunks_skipped_total",
            "lorif_exec_load_seconds_total",
            "lorif_pool_jobs_total",
            "lorif_server_submitted_total",
            "lorif_server_batch_wall_seconds",
            "lorif_coord_scatter_total",
            "lorif_coord_gather_total",
            "lorif_coord_retry_total",
            "lorif_coord_failover_total",
            "lorif_node_queries_total",
            "lorif_node_shards",
            "lorif_coord_reroute_total",
            "lorif_probe_attempts_total",
            "lorif_probe_failures_total",
            "lorif_probe_transitions_total",
            "lorif_fleet_scrapes_total",
            "lorif_fleet_scrape_errors_total",
            "lorif_fleet_nodes_healthy",
            "lorif_fleet_nodes_degraded",
            "lorif_fleet_nodes_down",
            "lorif_slowlog_admitted_total",
            "lorif_slowlog_entries",
        ] {
            assert!(text.contains(&format!("# HELP {family} ")), "{family} missing HELP");
            assert!(text.contains(&format!("# TYPE {family} ")), "{family} missing TYPE");
        }
        assert!(text.contains("lorif_exec_load_seconds_total 0.000000\n"));
        let helps = text.lines().filter(|l| l.starts_with("# HELP")).count();
        let types = text.lines().filter(|l| l.starts_with("# TYPE")).count();
        assert_eq!(helps, types);
        assert_eq!(helps, reg.table().len());
    }

    /// Base labels attach to every sample line but never to `# HELP` /
    /// `# TYPE`; histograms merge base labels ahead of `le`; values are
    /// escaped per Prometheus 0.0.4; and the empty label set renders
    /// byte-identically to the unlabeled exposition.
    #[test]
    fn labeled_exposition_and_escaping() {
        let reg = Registry::new();
        reg.store_bytes_read.add(7);
        reg.server_queue_depth.set(2);
        reg.query_latency.observe_secs(1e-6);
        let text = reg.render_prometheus_with(&[("node", "127.0.0.1:7001"), ("role", "node")]);

        assert!(text.contains(
            "# TYPE lorif_store_bytes_read_total counter\n\
             lorif_store_bytes_read_total{node=\"127.0.0.1:7001\",role=\"node\"} 7\n"
        ));
        assert!(text.contains(
            "lorif_server_queue_depth{node=\"127.0.0.1:7001\",role=\"node\"} 2\n"
        ));
        // histogram: base labels first, `le` last; sum/count labeled too
        assert!(text.contains(
            "lorif_query_latency_seconds_bucket{node=\"127.0.0.1:7001\",role=\"node\",le=\"0.000001\"} 1\n"
        ));
        assert!(text.contains(
            "lorif_query_latency_seconds_bucket{node=\"127.0.0.1:7001\",role=\"node\",le=\"+Inf\"} 1\n"
        ));
        assert!(text.contains(
            "lorif_query_latency_seconds_count{node=\"127.0.0.1:7001\",role=\"node\"} 1\n"
        ));
        // HELP/TYPE lines stay unlabeled
        for line in text.lines().filter(|l| l.starts_with('#')) {
            assert!(!line.contains('{'), "metadata line must be unlabeled: {line}");
        }

        assert_eq!(reg.render_prometheus(), reg.render_prometheus_with(&[]));
    }

    #[test]
    fn label_values_escape_backslash_quote_newline() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(escape_label_value("two\nlines"), "two\\nlines");
        assert_eq!(
            label_block(&[("k", "v\\\"\n")]),
            "{k=\"v\\\\\\\"\\n\"}"
        );
        assert_eq!(label_block(&[]), "");
    }

    /// The ledger shape survives a registry round trip: read + skipped
    /// published separately still sum to the full-scan total.
    #[test]
    fn ledger_sums_through_the_registry() {
        let reg = Registry::new();
        let full_scan = 1_000_000u64;
        reg.store_bytes_read.add(300_000);
        reg.store_bytes_skipped.add(700_000);
        assert_eq!(
            reg.store_bytes_read.get() + reg.store_bytes_skipped.get(),
            full_scan
        );
    }
}

//! Curvature approximations: dense Gauss-Newton (LoGRA baseline,
//! O(D^2)), truncated SVD + Woodbury (LoRIF, O(Dr)), and EK-FAC
//! (parameter-space contextual baseline).

pub mod dense;
pub mod ekfac;
pub mod truncated;

pub use dense::DenseCurvature;
pub use ekfac::Ekfac;
pub use truncated::{reconstruct_row, StoreLayerSource, TruncatedCurvature};

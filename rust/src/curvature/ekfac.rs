//! EK-FAC curvature (Grosse et al. 2023) — the parameter-space
//! contextual baseline of Table 1.
//!
//! Per linear layer with input covariance `A = E[x x^T]` and output-grad
//! covariance `S = E[dy dy^T]`, K-FAC approximates the GN Hessian as
//! `A ⊗ S`.  With eigendecompositions `A = Q_A D_A Q_A^T`,
//! `S = Q_S D_S Q_S^T`, EK-FAC replaces the Kronecker eigenvalues with
//! corrected per-entry values `Lam[i,j] = E[(Q_A^T G Q_S)_{ij}^2]`
//! estimated from per-example gradients.  The iHVP is then
//! `Q_A ((Q_A^T G Q_S) ./ (Lam + lambda)) Q_S^T`.

use crate::linalg::{eigh, Mat};

pub struct EkfacLayer {
    pub q_a: Mat, // (I, I)
    pub q_s: Mat, // (O, O)
    /// corrected eigenvalues, (I, O)
    pub lambda_corr: Mat,
    pub damping: f32,
}

pub struct Ekfac {
    pub layers: Vec<EkfacLayer>,
}

impl Ekfac {
    /// Build from covariances; `lambda_corr` starts as the Kronecker
    /// product of eigenvalues and is refined by `update_corrections`.
    pub fn from_covariances(covs: &[(Mat, Mat)], lambda_factor: f32) -> Ekfac {
        let layers = covs
            .iter()
            .map(|(a, s)| {
                let (da, q_a) = eigh::eigh(a);
                let (ds, q_s) = eigh::eigh(s);
                let (i_dim, o_dim) = (a.rows, s.rows);
                let mut lam = Mat::zeros(i_dim, o_dim);
                for i in 0..i_dim {
                    for j in 0..o_dim {
                        *lam.at_mut(i, j) = da[i].max(0.0) * ds[j].max(0.0);
                    }
                }
                let mean = lam.data.iter().sum::<f32>() / lam.data.len() as f32;
                EkfacLayer {
                    q_a,
                    q_s,
                    lambda_corr: lam,
                    damping: (lambda_factor * mean).max(1e-10),
                }
            })
            .collect();
        Ekfac { layers }
    }

    /// Eigenvalue correction pass: average (Q_A^T G Q_S)^2 over examples.
    /// `grads` yields per-example full gradients (I, O) for `layer`.
    pub fn set_corrections(&mut self, layer: usize, sq_mean: Mat, lambda_factor: f32) {
        let mean = sq_mean.data.iter().sum::<f32>() / sq_mean.data.len() as f32;
        self.layers[layer].damping = (lambda_factor * mean).max(1e-10);
        self.layers[layer].lambda_corr = sq_mean;
    }

    /// Rotate a gradient into the eigenbasis: Q_A^T G Q_S.
    pub fn rotate(&self, layer: usize, g: &Mat) -> Mat {
        let l = &self.layers[layer];
        l.q_a.matmul_tn(g).matmul(&l.q_s)
    }

    /// iHVP: precondition a full gradient (I, O) by the EK-FAC inverse.
    pub fn precondition(&self, layer: usize, g: &Mat) -> Mat {
        let l = &self.layers[layer];
        let mut rot = self.rotate(layer, g);
        for (x, lam) in rot.data.iter_mut().zip(&l.lambda_corr.data) {
            *x /= lam + l.damping;
        }
        // back: Q_A rot Q_S^T
        l.q_a.matmul(&rot).matmul_nt(&l.q_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::random_normal(n, n, 1.0, rng);
        let mut g = a.matmul_tn(&a);
        for i in 0..n {
            *g.at_mut(i, i) += 0.1;
        }
        g
    }

    #[test]
    fn precondition_inverts_kronecker() {
        // with exact Kronecker eigenvalues and damping -> 0, the iHVP of
        // (A (x) S) applied to a gradient must invert it:
        // precondition(A G S) ~= G
        let mut rng = Rng::new(1);
        let a = spd(4, &mut rng);
        let s = spd(3, &mut rng);
        let mut ek = Ekfac::from_covariances(&[(a.clone(), s.clone())], 1e-9);
        ek.layers[0].damping = 1e-9;
        let g = Mat::random_normal(4, 3, 1.0, &mut rng);
        // H g in kronecker form = A G S
        let hg = a.matmul(&g).matmul(&s);
        let back = ek.precondition(0, &hg);
        for (x, y) in back.data.iter().zip(&g.data) {
            assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn rotation_is_orthogonal() {
        let mut rng = Rng::new(2);
        let ek = Ekfac::from_covariances(&[(spd(5, &mut rng), spd(4, &mut rng))], 0.1);
        let g = Mat::random_normal(5, 4, 1.0, &mut rng);
        let rot = ek.rotate(0, &g);
        // Frobenius norm preserved by orthogonal rotations
        assert!((rot.frob_norm() - g.frob_norm()).abs() < 1e-3);
    }

    #[test]
    fn corrections_override() {
        let mut rng = Rng::new(3);
        let mut ek = Ekfac::from_covariances(&[(spd(3, &mut rng), spd(3, &mut rng))], 0.1);
        let corr = Mat::from_vec(3, 3, vec![1.0; 9]);
        ek.set_corrections(0, corr, 0.1);
        assert!((ek.layers[0].damping - 0.1).abs() < 1e-6);
        assert!(ek.layers[0].lambda_corr.data.iter().all(|&x| x == 1.0));
    }
}

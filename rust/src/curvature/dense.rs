//! Dense Gauss–Newton curvature for the LoGRA/TrackStar baselines
//! (paper Eq. 2–3): per layer, `K = (G^T G + lambda I)` factored with
//! Cholesky; queries are preconditioned by solving `K x = g_q`.
//!
//! Memory is O(D^2) per layer by construction — this is exactly the
//! bottleneck LoRIF removes, and the Table 8 "w/o truncated SVD OOM"
//! rows come from the guard below.

use crate::linalg::{Chol, Mat};
use crate::store::{ChunkLayer, ShardSet};

/// Refuse to build dense curvature above this many f32 elements per layer
/// (simulates the paper's OOM wall; override with LORIF_DENSE_LIMIT).
pub fn dense_limit() -> usize {
    std::env::var("LORIF_DENSE_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64_000_000) // 256 MB of f32
}

pub struct DenseCurvature {
    /// per layer Cholesky factor of (G^T G + lambda I)
    pub chols: Vec<Chol>,
    pub lambdas: Vec<f32>,
}

#[derive(Debug)]
pub struct OomError {
    pub layer: usize,
    pub need: usize,
    pub limit: usize,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dense curvature for layer {} needs {} floats > limit {} (OOM)",
            self.layer, self.need, self.limit
        )
    }
}

impl std::error::Error for OomError {}

impl DenseCurvature {
    /// Stream the (dense) store once, accumulating G^T G per layer.
    pub fn build(set: &ShardSet, lambda_factor: f32) -> anyhow::Result<DenseCurvature> {
        Self::build_with_limit(set, lambda_factor, dense_limit())
    }

    /// `build` with an explicit OOM-guard limit.  The public entry point
    /// reads the limit from the environment once; tests pass it directly
    /// so they never mutate process-global env (which races with any
    /// concurrently running test that calls `dense_limit`).
    pub fn build_with_limit(
        set: &ShardSet,
        lambda_factor: f32,
        limit: usize,
    ) -> anyhow::Result<DenseCurvature> {
        let dims = set.meta.layers.clone();
        // OOM guard (Table 8 behaviour)
        for (l, &(d1, d2)) in dims.iter().enumerate() {
            let need = (d1 * d2) * (d1 * d2);
            if need > limit {
                return Err(OomError { layer: l, need, limit }.into());
            }
        }
        let mut grams: Vec<Mat> =
            dims.iter().map(|&(d1, d2)| Mat::zeros(d1 * d2, d1 * d2)).collect();
        let c = set.meta.c;
        set.stream(256, false, |chunk| {
            for (l, layer) in chunk.layers.iter().enumerate() {
                let (d1, d2) = dims[l];
                match layer {
                    ChunkLayer::Dense { g } => {
                        crate::linalg::mat::gemm_tn_acc(&mut grams[l], g, g, 1.0);
                    }
                    ChunkLayer::Factored { u, v } => {
                        let mut g = Mat::zeros(chunk.count, d1 * d2);
                        for ex in 0..chunk.count {
                            super::truncated::reconstruct_row(
                                u.row(ex),
                                v.row(ex),
                                d1,
                                d2,
                                c,
                                g.row_mut(ex),
                            );
                        }
                        crate::linalg::mat::gemm_tn_acc(&mut grams[l], &g, &g, 1.0);
                    }
                }
            }
            Ok(())
        })?;

        let mut chols = Vec::with_capacity(grams.len());
        let mut lambdas = Vec::with_capacity(grams.len());
        for mut gram in grams {
            let d = gram.rows;
            // App. B.2 damping: lambda = factor * mean(eigenvalues) =
            // factor * trace / D (no eigendecomposition needed)
            let trace: f32 = (0..d).map(|i| gram.at(i, i)).sum();
            let lambda = (lambda_factor * trace / d as f32).max(1e-12);
            for i in 0..d {
                *gram.at_mut(i, i) += lambda;
            }
            chols.push(Chol::factor(&gram).map_err(|e| anyhow::anyhow!("{e}"))?);
            lambdas.push(lambda);
        }
        Ok(DenseCurvature { chols, lambdas })
    }

    /// Precondition a query gradient: x = K^{-1} g (per layer).
    pub fn precondition(&self, layer: usize, g: &[f32]) -> Vec<f32> {
        self.chols[layer].solve(g)
    }

    pub fn memory_floats(&self) -> usize {
        self.chols.iter().map(|c| c.dim() * c.dim()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::runtime::{ExtractBatch, LayerGrads};
    use crate::store::{ShardSet, StoreKind, StoreMeta, StoreWriter};
    use crate::util::prng::Rng;

    fn dense_store(n: usize, layers: &[(usize, usize)]) -> (std::path::PathBuf, Vec<Mat>) {
        let dir = std::env::temp_dir().join("lorif_curv_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join(format!("dense_{n}"));
        let meta = StoreMeta {
            kind: StoreKind::Dense,
            tier: "small".into(),
            f: 4,
            c: 1,
            layers: layers.to_vec(),
            n_examples: 0,
            shards: None,
            summary_chunk: None,
            codec: crate::store::CodecId::Bf16,
        };
        let mut rng = Rng::new(7);
        let gs: Vec<Mat> =
            layers.iter().map(|&(d1, d2)| Mat::random_normal(n, d1 * d2, 1.0, &mut rng)).collect();
        let mut w = StoreWriter::create(&base, meta).unwrap();
        let batch = ExtractBatch {
            losses: vec![0.0; n],
            layers: gs
                .iter()
                .map(|g| LayerGrads {
                    g: g.clone(),
                    u: Mat::zeros(n, 1),
                    v: Mat::zeros(n, 1),
                })
                .collect(),
            valid: n,
        };
        w.append(&batch).unwrap();
        w.finalize().unwrap();
        (base, gs)
    }

    #[test]
    fn gram_solve_matches_direct() {
        let (base, gs) = dense_store(30, &[(4, 5)]);
        let set = ShardSet::open(&base).unwrap();
        let curv = DenseCurvature::build(&set, 0.1).unwrap();
        // direct: K = G^T G + lambda I (within bf16 noise)
        let g = &gs[0];
        let mut gram = g.matmul_tn(g);
        let lambda = curv.lambdas[0];
        for i in 0..gram.rows {
            *gram.at_mut(i, i) += lambda;
        }
        let mut rng = Rng::new(9);
        let q = Mat::random_normal(20, 1, 1.0, &mut rng);
        let x = curv.precondition(0, &q.data);
        let kx = gram.matvec(&x);
        for i in 0..20 {
            // bf16 storage noise propagates; tolerance is loose but the
            // structure must hold: K x ~= q
            assert!((kx[i] - q.data[i]).abs() < 0.15 * (1.0 + q.data[i].abs()), "{i}");
        }
    }

    #[test]
    fn oom_guard_trips() {
        // inject the limit instead of set_var: env mutation is
        // process-global and races with parallel tests
        let (base, _) = dense_store(5, &[(8, 8)]);
        let set = ShardSet::open(&base).unwrap();
        let err = DenseCurvature::build_with_limit(&set, 0.1, 1000);
        assert!(err.is_err());
        let msg = format!("{}", err.err().unwrap());
        assert!(msg.contains("OOM"), "{msg}");
        // a (8*8)^2 = 4096-float layer fits under a 5000 limit
        DenseCurvature::build_with_limit(&set, 0.1, 5000).unwrap();
    }
}

//! LoRIF curvature: truncated SVD + Woodbury (paper §3.2).
//!
//! Stage 2 streams the factor store once per rSVD pass, reconstructing
//! rows of `G` from the rank-c factors without materializing the matrix
//! (paper: "reconstructing rows of G batch-by-batch from the stored
//! low-rank factors").  Per layer we keep only `sigma (r)` and
//! `V_r (D, r)` — O(Dr) memory instead of O(D^2) — plus, optionally, the
//! free `train_proj (N, r)` by-product for the cached-projection serving
//! mode (an extension over the paper; off by default).

use std::io::{Read, Write};
use std::path::Path;

use crate::linalg::rsvd::{rsvd, RowChunkSource, TruncatedSvd};
use crate::linalg::Mat;
use crate::store::{ChunkLayer, ShardSet, StoreKind};

/// Adapter: one layer of a gradient store as a stream of G-row chunks.
/// Streams shards sequentially in order, so chunk starts are global.
pub struct StoreLayerSource<'a> {
    pub set: &'a ShardSet,
    pub layer: usize,
    pub chunk_size: usize,
}

impl RowChunkSource for StoreLayerSource<'_> {
    fn n_rows(&self) -> usize {
        self.set.meta.n_examples
    }

    fn dim(&self) -> usize {
        let (d1, d2) = self.set.meta.layers[self.layer];
        d1 * d2
    }

    fn for_each_chunk(&mut self, f: &mut dyn FnMut(usize, &Mat)) -> anyhow::Result<()> {
        let (d1, d2) = self.set.meta.layers[self.layer];
        let c = self.set.meta.c;
        let layer = self.layer;
        self.set
            .stream(self.chunk_size, false, |chunk| {
                match &chunk.layers[layer] {
                    ChunkLayer::Dense { g } => f(chunk.start, g),
                    ChunkLayer::Factored { u, v } => {
                        // reconstruct rows: vec(u_i v_i^T) for each example
                        let mut g = Mat::zeros(chunk.count, d1 * d2);
                        for ex in 0..chunk.count {
                            reconstruct_row(
                                u.row(ex),
                                v.row(ex),
                                d1,
                                d2,
                                c,
                                g.row_mut(ex),
                            );
                        }
                        f(chunk.start, &g);
                    }
                }
                Ok(())
            })
            .map(|_| ())
    }
}

/// vec(u v^T) with u (d1*c), v (d2*c) in column-major factor layout
/// (row-major (d1, c) / (d2, c) matrices as written by the store).
#[inline]
pub fn reconstruct_row(u: &[f32], v: &[f32], d1: usize, d2: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), d1 * d2);
    out.fill(0.0);
    for a in 0..d1 {
        let dst = &mut out[a * d2..(a + 1) * d2];
        for k in 0..c {
            let ua = u[a * c + k];
            if ua != 0.0 {
                // v column k: strided access v[b*c + k]
                for b in 0..d2 {
                    dst[b] += ua * v[b * c + k];
                }
            }
        }
    }
}

/// Truncated curvature for all layers of an index.
pub struct TruncatedCurvature {
    /// per layer: the truncated SVD
    pub layers: Vec<TruncatedSvd>,
    /// per layer damping lambda (App. B.2 rule)
    pub lambdas: Vec<f32>,
    /// per layer Woodbury weights w_i = sigma_i^2/(lambda(lambda+sigma_i^2))
    pub weights: Vec<Vec<f32>>,
    pub r: usize,
}

impl TruncatedCurvature {
    /// Stage 2: run the streaming rSVD per layer over the store (either
    /// layout; shards are streamed in order, so the result is identical
    /// to the monolithic pass).
    pub fn build(
        set: &ShardSet,
        r: usize,
        oversample: usize,
        power_iters: usize,
        lambda_factor: f32,
        seed: u64,
    ) -> anyhow::Result<TruncatedCurvature> {
        anyhow::ensure!(
            set.meta.kind == StoreKind::Factored || set.meta.kind == StoreKind::Dense,
            "unsupported store kind"
        );
        let n_layers = set.meta.layers.len();
        let mut layers = Vec::with_capacity(n_layers);
        let mut lambdas = Vec::with_capacity(n_layers);
        let mut weights = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let (d1, d2) = set.meta.layers[l];
            let r_l = r.min(d1 * d2).min(set.meta.n_examples.saturating_sub(1)).max(1);
            let mut src = StoreLayerSource { set, layer: l, chunk_size: 256 };
            let t0 = std::time::Instant::now();
            let svd = rsvd(&mut src, r_l, oversample, power_iters, seed ^ l as u64)?;
            let lambda = svd.damping(lambda_factor);
            log::debug!(
                "layer {l}: rsvd r={r_l} D={} sigma0={:.3} lambda={:.4} ({:?})",
                d1 * d2,
                svd.sigma[0],
                lambda,
                t0.elapsed()
            );
            weights.push(svd.woodbury_weights(lambda));
            lambdas.push(lambda);
            layers.push(svd);
        }
        Ok(TruncatedCurvature { layers, lambdas, weights, r })
    }

    /// Project a dense per-layer gradient into the r-dim subspace:
    /// g' = V_r^T g (paper Eq. 8).
    pub fn project(&self, layer: usize, g: &[f32]) -> Vec<f32> {
        self.layers[layer].v.matvec_t(g)
    }

    /// Memory of the curvature representation in f32 counts (O(Dr)).
    pub fn memory_floats(&self) -> usize {
        self.layers.iter().map(|s| s.v.rows * s.v.cols + s.sigma.len()).sum()
    }

    // ---- persistence -------------------------------------------------------

    pub fn save(&self, path: &Path, with_train_proj: bool) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"LORIFCV1")?;
        f.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        f.write_all(&(self.r as u32).to_le_bytes())?;
        f.write_all(&[with_train_proj as u8, 0, 0, 0])?;
        for (l, svd) in self.layers.iter().enumerate() {
            f.write_all(&self.lambdas[l].to_le_bytes())?;
            f.write_all(&(svd.sigma.len() as u32).to_le_bytes())?;
            f.write_all(&(svd.v.rows as u32).to_le_bytes())?;
            for &s in &svd.sigma {
                f.write_all(&s.to_le_bytes())?;
            }
            write_f32s(&mut f, &svd.v.data)?;
            if with_train_proj {
                f.write_all(&(svd.train_proj.rows as u32).to_le_bytes())?;
                write_f32s(&mut f, &svd.train_proj.data)?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<TruncatedCurvature> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"LORIFCV1", "bad curvature magic");
        let n_layers = read_u32(&mut f)? as usize;
        let r = read_u32(&mut f)? as usize;
        let mut flags = [0u8; 4];
        f.read_exact(&mut flags)?;
        let with_proj = flags[0] != 0;
        let mut layers = Vec::with_capacity(n_layers);
        let mut lambdas = Vec::with_capacity(n_layers);
        let mut weights = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let mut b4 = [0u8; 4];
            f.read_exact(&mut b4)?;
            let lambda = f32::from_le_bytes(b4);
            let rl = read_u32(&mut f)? as usize;
            let d = read_u32(&mut f)? as usize;
            let mut sigma = vec![0.0f32; rl];
            for s in sigma.iter_mut() {
                f.read_exact(&mut b4)?;
                *s = f32::from_le_bytes(b4);
            }
            let v = Mat::from_vec(d, rl, read_f32s(&mut f, d * rl)?);
            let train_proj = if with_proj {
                let n = read_u32(&mut f)? as usize;
                Mat::from_vec(n, rl, read_f32s(&mut f, n * rl)?)
            } else {
                Mat::zeros(0, rl)
            };
            let svd = TruncatedSvd { sigma, v, train_proj };
            weights.push(svd.woodbury_weights(lambda));
            lambdas.push(lambda);
            layers.push(svd);
        }
        Ok(TruncatedCurvature { layers, lambdas, weights, r })
    }
}

fn write_f32s(f: &mut impl Write, xs: &[f32]) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn read_f32s(f: &mut impl Read, n: usize) -> anyhow::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconstruct_row_matches_outer_product() {
        // u: (d1, c) row-major, v: (d2, c) row-major
        let (d1, d2, c) = (3, 4, 2);
        let u: Vec<f32> = (0..d1 * c).map(|i| i as f32 * 0.5).collect();
        let v: Vec<f32> = (0..d2 * c).map(|i| 1.0 - i as f32 * 0.1).collect();
        let mut out = vec![0.0f32; d1 * d2];
        reconstruct_row(&u, &v, d1, d2, c, &mut out);
        for a in 0..d1 {
            for b in 0..d2 {
                let mut want = 0.0;
                for k in 0..c {
                    want += u[a * c + k] * v[b * c + k];
                }
                assert!((out[a * d2 + b] - want).abs() < 1e-6);
            }
        }
    }
}

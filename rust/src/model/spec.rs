//! Rust mirror of `python/compile/spec.py`: tier definitions, canonical
//! flat-parameter layout, tracked attribution layers.
//!
//! Cross-checked against the artifact manifest at load time (both sides
//! assert on `param_count`), so drift between the two spec files fails
//! loudly instead of silently mis-slicing parameters.

pub const VOCAB: usize = 64;
pub const SEQ_LEN: usize = 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Module {
    Attn,
    Mlp,
}

impl Module {
    pub fn as_str(self) -> &'static str {
        match self {
            Module::Attn => "attn",
            Module::Mlp => "mlp",
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrackedLayer {
    pub name: String,
    pub module: Module,
    pub in_dim: usize,
    pub out_dim: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tier {
    Small,
    Medium,
    Large,
}

impl Tier {
    pub fn parse(s: &str) -> anyhow::Result<Tier> {
        match s {
            "small" => Ok(Tier::Small),
            "medium" => Ok(Tier::Medium),
            "large" => Ok(Tier::Large),
            _ => anyhow::bail!("unknown tier '{s}' (small|medium|large)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Small => "small",
            Tier::Medium => "medium",
            Tier::Large => "large",
        }
    }

    pub fn spec(self) -> TierSpec {
        match self {
            // stands in for GPT2-small / OLMo-3-7B / Apertus-70B
            Tier::Small => TierSpec::new(self, 2, 64, 128, 2),
            Tier::Medium => TierSpec::new(self, 3, 128, 256, 4),
            Tier::Large => TierSpec::new(self, 4, 192, 384, 6),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TierSpec {
    pub tier: Tier,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
}

impl TierSpec {
    fn new(tier: Tier, n_layers: usize, d_model: usize, d_ff: usize, n_heads: usize) -> Self {
        TierSpec { tier, n_layers, d_model, d_ff, n_heads }
    }

    /// Linear layers tracked for attribution, canonical order.
    pub fn tracked_layers(&self) -> Vec<TrackedLayer> {
        let (d, f) = (self.d_model, self.d_ff);
        let mut out = Vec::with_capacity(4 * self.n_layers);
        for b in 0..self.n_layers {
            out.push(TrackedLayer {
                name: format!("blk{b}.attn_qkv"),
                module: Module::Attn,
                in_dim: d,
                out_dim: 3 * d,
            });
            out.push(TrackedLayer {
                name: format!("blk{b}.attn_out"),
                module: Module::Attn,
                in_dim: d,
                out_dim: d,
            });
            out.push(TrackedLayer {
                name: format!("blk{b}.mlp_in"),
                module: Module::Mlp,
                in_dim: d,
                out_dim: f,
            });
            out.push(TrackedLayer {
                name: format!("blk{b}.mlp_out"),
                module: Module::Mlp,
                in_dim: f,
                out_dim: d,
            });
        }
        out
    }

    /// Canonical flat parameter layout: (name, rows, cols).
    pub fn param_shapes(&self) -> Vec<(String, usize, usize)> {
        let (d, f) = (self.d_model, self.d_ff);
        let mut shapes = vec![
            ("embed".to_string(), VOCAB, d),
            ("pos".to_string(), SEQ_LEN, d),
        ];
        for b in 0..self.n_layers {
            shapes.push((format!("blk{b}.attn_qkv"), d, 3 * d));
            shapes.push((format!("blk{b}.attn_out"), d, d));
            shapes.push((format!("blk{b}.mlp_in"), d, f));
            shapes.push((format!("blk{b}.mlp_out"), f, d));
        }
        shapes.push(("unembed".to_string(), d, VOCAB));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|(_, r, c)| r * c).sum()
    }

    /// (d1, d2) per tracked layer under projection factor f (f=1: raw dims).
    pub fn proj_dims(&self, f: usize) -> Vec<(usize, usize)> {
        self.tracked_layers()
            .iter()
            .map(|l| {
                assert!(
                    l.in_dim % f == 0 && l.out_dim % f == 0,
                    "f={f} must divide layer dims ({}, {})",
                    l.in_dim,
                    l.out_dim
                );
                (l.in_dim / f, l.out_dim / f)
            })
            .collect()
    }

    /// Effective projection dimension D = sum_l d1 d2.
    pub fn total_proj_dim(&self, f: usize) -> usize {
        self.proj_dims(f).iter().map(|(a, b)| a * b).sum()
    }

    /// Per-example f32 count when stored densely (LoGRA) vs factored
    /// rank-c (LoRIF): the Table 1/2 storage columns.
    pub fn dense_floats_per_example(&self, f: usize) -> usize {
        self.total_proj_dim(f)
    }

    pub fn factored_floats_per_example(&self, f: usize, c: usize) -> usize {
        self.proj_dims(f).iter().map(|(d1, d2)| c * (d1 + d2)).sum()
    }

    /// Initialize parameters: N(0, 0.05) everywhere — matches the scale
    /// the python tests validate training against.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::prng::Rng::labeled(seed, "init");
        let mut flat = vec![0.0f32; self.param_count()];
        rng.fill_normal(&mut flat, 0.05);
        flat
    }
}

/// Paper App. B.2 power-iteration counts.
pub fn power_iters(c: usize) -> usize {
    if c == 1 {
        8
    } else {
        16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_python() {
        // values asserted by python/tests and the manifest
        assert_eq!(Tier::Small.spec().param_count(), 77_824);
        // medium: 2*128*(64)+... compute expected analytically
        let m = Tier::Medium.spec();
        let expect: usize = (VOCAB * 128)
            + (SEQ_LEN * 128)
            + 3 * (128 * 384 + 128 * 128 + 128 * 256 + 256 * 128)
            + 128 * VOCAB;
        assert_eq!(m.param_count(), expect);
    }

    #[test]
    fn tracked_layers_shape() {
        let s = Tier::Small.spec();
        let layers = s.tracked_layers();
        assert_eq!(layers.len(), 8);
        assert_eq!(layers[0].out_dim, 192);
        assert_eq!(layers[2].module, Module::Mlp);
    }

    #[test]
    fn proj_dims_divide() {
        for tier in [Tier::Small, Tier::Medium, Tier::Large] {
            for f in [1, 2, 4, 8, 16] {
                let dims = tier.spec().proj_dims(f);
                assert!(dims.iter().all(|&(a, b)| a > 0 && b > 0), "{tier:?} f={f}");
            }
        }
    }

    #[test]
    fn factored_smaller_than_dense() {
        let s = Tier::Small.spec();
        for f in [2, 4, 8] {
            assert!(s.factored_floats_per_example(f, 1) < s.dense_floats_per_example(f));
        }
        // compression ratio ~ min(d1,d2)/2c (paper §3.3)
        let f = 4;
        let ratio =
            s.dense_floats_per_example(f) as f64 / s.factored_floats_per_example(f, 1) as f64;
        assert!(ratio > 4.0, "ratio {ratio}");
    }

    #[test]
    fn init_deterministic_nonzero() {
        let s = Tier::Small.spec();
        let a = s.init_params(1);
        let b = s.init_params(1);
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x != 0.0));
        let c = s.init_params(2);
        assert_ne!(a, c);
    }
}

//! Model-side substrate: tier specs (mirroring python/compile/spec.py),
//! parameter init, and checkpoint persistence.

pub mod checkpoint;
pub mod spec;

pub use spec::{Tier, TierSpec};

//! Flat-parameter checkpoints (raw f32 LE + tiny header).
//!
//! Stores the trained base model, the LDS subset-retrained models, and
//! optimizer state between pipeline stages.

use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LORIFCK1";

pub struct Checkpoint {
    pub tier: String,
    pub step: u64,
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        let name = self.tier.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        // bulk write: reinterpret as LE bytes
        let mut buf = Vec::with_capacity(self.params.len() * 4);
        for &x in &self.params {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic in {}", path.display());
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let name_len = u32::from_le_bytes(b4) as usize;
        anyhow::ensure!(name_len < 256, "suspicious tier-name length");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let mut b8 = [0u8; 8];
        f.read_exact(&mut b8)?;
        let step = u64::from_le_bytes(b8);
        f.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let mut buf = vec![0u8; n * 4];
        f.read_exact(&mut buf)?;
        let params = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { tier: String::from_utf8_lossy(&name).into_owned(), step, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            tier: "small".into(),
            step: 300,
            params: (0..1000).map(|i| i as f32 * 0.5).collect(),
        };
        let dir = std::env::temp_dir().join("lorif_test_ck");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tier, "small");
        assert_eq!(back.step, 300);
        assert_eq!(back.params, ck.params);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_wrong_magic() {
        let dir = std::env::temp_dir().join("lorif_test_ck");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"LORIFDS1xxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

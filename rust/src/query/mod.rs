//! Query-time serving: the engine (scorer + top-k + latency breakdown),
//! the parallel shard-scoring machinery, and — with the `xla` feature —
//! the TCP attribution service with dynamic batching.

pub mod engine;
pub mod parallel;
#[cfg(feature = "xla")]
pub mod server;

pub use engine::{LatencyBreakdown, QueryEngine, QueryResult};
pub use parallel::{map_shards, merge_scores, merge_topk, ShardScores, TopK};
#[cfg(feature = "xla")]
pub use server::{serve, ServerConfig};

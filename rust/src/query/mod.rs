//! Query-time serving: the engine (scorer + top-k + latency breakdown),
//! the parallel shard-scoring machinery, and the concurrent TCP
//! attribution service (acceptor -> batcher -> scoring-worker pool with
//! admission control).  The server is pure CPU + std; only the
//! XLA-backed gradient source (`server::XlaGradSource`) needs the `xla`
//! feature.

pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod parallel;
pub mod plane;
pub mod server;
pub mod slowlog;

pub use coordinator::{parse_shard_list, NodeSpec, RemotePlane, TokenSource, Topology};
pub use engine::{LatencyBreakdown, QueryEngine, QueryResult};
pub use fleet::{Fleet, FleetOptions, Health};
pub use parallel::{map_shards, merge_scores, merge_topk, ShardScores, TopK};
pub use plane::{LocalPlane, NodeStat, PlaneBatch, PlaneReply, ShardPlane};
pub use server::{serve, GradSource, ServeSummary, Server, ServerConfig};
pub use slowlog::{SlowEntry, SlowLog};

//! Query-time serving: the engine (scorer + top-k + latency breakdown)
//! and the TCP attribution service with dynamic batching.

pub mod engine;
pub mod server;

pub use engine::{LatencyBreakdown, QueryEngine, QueryResult};
pub use server::{serve, ServerConfig};

//! Slow-query log: a fixed-capacity ring of the K slowest scored
//! batches, kept in memory by the server and served over the line
//! protocol (`{"cmd": "slowlog"}`) and the `lorif slowlog` CLI.
//!
//! Each entry captures everything needed to go from "that query was
//! slow" to "here is why": the full [`LatencyBreakdown`] of the pass
//! (phase seconds + byte/cache ledger), the per-node [`NodeStat`]s of a
//! scatter-gather pass (which node gated the gather, whether a failover
//! happened), and the batch's trace ID — the handle that finds the
//! matching span tree in a `--trace-out` Perfetto file.
//!
//! Admission keeps the K slowest batches seen so far, deterministically:
//!
//!   * below capacity, everything is admitted;
//!   * at capacity, a new batch is admitted iff its wall time is at
//!     least the current minimum, and it replaces that minimum —
//!     with ties at the minimum broken toward the OLDEST entry (lowest
//!     admission sequence number), so a stream of equal-wall batches
//!     rotates through the ring (newest wins) instead of pinning the
//!     first arrivals forever.
//!
//! [`snapshot_json`](SlowLog::snapshot_json) renders entries sorted
//! slowest-first (ties oldest-first), so the verb's reply is stable
//! under re-ordering of the internal ring.

use super::engine::LatencyBreakdown;
use super::plane::NodeStat;
use crate::util::json::{obj, Value};

/// One retained slow batch.
#[derive(Clone, Debug)]
pub struct SlowEntry {
    /// trace ID of the pass (matches the `trace_id` arg on the span
    /// tree in a `--trace-out` file; 0 when tracing never assigned one)
    pub trace_id: u64,
    /// reply latency of the batch: queue wait + window + extraction +
    /// scoring (what the admission decision ranks on)
    pub wall_s: f64,
    /// queries in the batch
    pub batch: usize,
    /// seconds since server start when the batch finished
    pub ts_s: f64,
    /// the pass's full phase/byte breakdown
    pub latency: LatencyBreakdown,
    /// per-node scatter accounting (empty on a local plane)
    pub nodes: Vec<NodeStat>,
    /// admission sequence number (monotone; breaks wall-time ties)
    pub seq: u64,
}

impl SlowEntry {
    /// JSON shape served by the `slowlog` verb: top-level wall/batch/
    /// trace fields plus the canonical breakdown and node objects.
    pub fn to_json(&self) -> Value {
        let mut fields: Vec<(&'static str, Value)> = vec![
            ("trace_id", (self.trace_id as usize).into()),
            ("wall_s", self.wall_s.into()),
            ("batch", self.batch.into()),
            ("ts_s", self.ts_s.into()),
            ("seq", (self.seq as usize).into()),
            ("latency", obj(self.latency.json_fields())),
        ];
        if !self.nodes.is_empty() {
            fields.push(("nodes", Value::Arr(self.nodes.iter().map(NodeStat::to_json).collect())));
        }
        obj(fields)
    }
}

/// The ring itself.  Not internally synchronized — the server holds it
/// behind a `Mutex` and touches it once per scored batch, far off any
/// hot path.
pub struct SlowLog {
    cap: usize,
    entries: Vec<SlowEntry>,
    seq: u64,
}

impl SlowLog {
    pub fn new(cap: usize) -> SlowLog {
        SlowLog { cap, entries: Vec::with_capacity(cap.min(64)), seq: 0 }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Offer one finished batch; returns whether it was admitted.  The
    /// `seq` field of `entry` is overwritten with the next admission
    /// sequence number (callers pass 0).
    pub fn offer(&mut self, mut entry: SlowEntry) -> bool {
        if self.cap == 0 {
            return false;
        }
        self.seq += 1;
        entry.seq = self.seq;
        if self.entries.len() < self.cap {
            self.entries.push(entry);
            return true;
        }
        // evict the minimum: slowest-ranked ring keeps the K largest
        // walls; ties at the minimum evict the OLDEST (lowest seq)
        let (idx, min_wall) = self
            .entries
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.wall_s
                    .partial_cmp(&b.wall_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.seq.cmp(&b.seq))
            })
            .map(|(i, e)| (i, e.wall_s))
            .expect("non-empty ring at capacity");
        if entry.wall_s >= min_wall {
            self.entries[idx] = entry;
            true
        } else {
            false
        }
    }

    /// Entries sorted slowest-first (ties oldest-first).
    pub fn snapshot(&self) -> Vec<SlowEntry> {
        let mut out = self.entries.clone();
        out.sort_by(|a, b| {
            b.wall_s
                .partial_cmp(&a.wall_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.seq.cmp(&b.seq))
        });
        out
    }

    /// The `slowlog` verb's payload: `[entry, ...]` slowest-first.
    pub fn snapshot_json(&self) -> Value {
        Value::Arr(self.snapshot().iter().map(SlowEntry::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(wall_s: f64) -> SlowEntry {
        SlowEntry {
            trace_id: 0,
            wall_s,
            batch: 1,
            ts_s: 0.0,
            latency: LatencyBreakdown {
                load_s: 0.0,
                compute_s: 0.0,
                precondition_s: 0.0,
                total_s: 0.0,
                wall_s,
                bytes_read: 0,
                bytes_skipped: 0,
                cache_hits: 0,
                cache_misses: 0,
                bytes_from_cache: 0,
            },
            nodes: Vec::new(),
            seq: 0,
        }
    }

    fn walls(log: &SlowLog) -> Vec<f64> {
        log.snapshot().iter().map(|e| e.wall_s).collect()
    }

    #[test]
    fn fills_to_capacity_then_keeps_the_slowest() {
        let mut log = SlowLog::new(3);
        assert!(log.is_empty());
        for w in [0.3, 0.1, 0.2] {
            assert!(log.offer(entry(w)), "below capacity admits everything");
        }
        assert_eq!(log.len(), 3);
        // faster than the min: rejected, ring unchanged
        assert!(!log.offer(entry(0.05)));
        assert_eq!(walls(&log), vec![0.3, 0.2, 0.1]);
        // slower than the min: evicts exactly the min
        assert!(log.offer(entry(0.5)));
        assert_eq!(walls(&log), vec![0.5, 0.3, 0.2]);
    }

    #[test]
    fn ties_at_the_minimum_evict_the_oldest_entry() {
        let mut log = SlowLog::new(2);
        assert!(log.offer(entry(0.2))); // seq 1
        assert!(log.offer(entry(0.2))); // seq 2
        // equal wall: admitted, replacing the OLDEST tied minimum
        // (seq 1), so the ring now holds seqs 2 and 3
        assert!(log.offer(entry(0.2))); // seq 3
        let snap = log.snapshot();
        let seqs: Vec<u64> = snap.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3], "ties rotate oldest-out, ordered oldest-first");
        // a strictly slower batch still evicts a tied minimum
        assert!(log.offer(entry(0.4))); // seq 4 evicts seq 2
        let seqs: Vec<u64> = log.snapshot().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![4, 3], "slowest-first, the 0.4 leads");
    }

    #[test]
    fn snapshot_sorts_slowest_first_and_zero_capacity_rejects() {
        let mut log = SlowLog::new(8);
        for w in [0.1, 0.4, 0.2, 0.3] {
            log.offer(entry(w));
        }
        assert_eq!(walls(&log), vec![0.4, 0.3, 0.2, 0.1]);
        let mut off = SlowLog::new(0);
        assert!(!off.offer(entry(9.0)), "cap 0 disables the log");
        assert!(off.is_empty());
    }

    #[test]
    fn entry_json_carries_trace_latency_and_nodes() {
        let mut e = entry(0.25);
        e.trace_id = 42;
        e.batch = 3;
        e.latency.bytes_read = 1024;
        e.nodes.push(NodeStat {
            addr: "127.0.0.1:7001".into(),
            shards: vec![0],
            wall_s: 0.2,
            retries: 0,
            failover: false,
            proactive: true,
        });
        e.seq = 7;
        let v = e.to_json();
        assert_eq!(v.get("trace_id").and_then(Value::as_usize), Some(42));
        assert_eq!(v.get("wall_s").and_then(Value::as_f64), Some(0.25));
        assert_eq!(v.get("batch").and_then(Value::as_usize), Some(3));
        assert_eq!(v.get("seq").and_then(Value::as_usize), Some(7));
        let lat = v.get("latency").expect("latency object");
        assert_eq!(lat.get("bytes_read").and_then(Value::as_usize), Some(1024));
        let nodes = v.get("nodes").and_then(Value::as_arr).expect("nodes array");
        assert_eq!(nodes.len(), 1);
        assert_eq!(nodes[0].get("proactive").and_then(Value::as_bool), Some(true));
        // local-plane entries omit the nodes field entirely
        let local = entry(0.1).to_json();
        assert!(local.get("nodes").is_none());
    }
}

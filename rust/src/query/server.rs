//! Attribution service: concurrent TCP line-protocol server with a
//! pipelined batcher and a pool of scoring workers.
//!
//! The serving-side payoff of LoRIF's design is that one streaming pass
//! over the factor store answers a whole *batch* of queries (the store
//! read amortizes across queries).  On top of that, serving under
//! concurrent traffic wants three more things, which this module's
//! acceptor -> batcher -> worker-pool pipeline provides:
//!
//!   * **Overlap**: the batcher extracts batch N+1's gradients while
//!     the scoring workers run batch N's store pass, and the workers
//!     share one `Arc`-held store (and decoded-chunk cache, see
//!     `crate::store::cache`), so hot chunks are read and decoded once
//!     across the whole pool.
//!   * **Admission control**: a bounded queue between the connection
//!     handlers and the batcher.  When it is full, the request is shed
//!     immediately with a structured `overloaded` error instead of
//!     buffering without bound.
//!   * **Fault isolation**: a failing batch (bad extraction, scoring
//!     error) answers exactly its own clients with a structured
//!     `batch_failed` error and the service keeps running; it never
//!     tears the server down.
//!
//! Protocol (newline-delimited JSON):
//!   -> {"tokens": [t0, t1, ...]}            (<= seq_len token ids)
//!      An optional `"trace": id` field (a positive integer) adopts the
//!      CALLER's trace ID for the batch this query lands in — the
//!      coordinator forwards its own ID so a node's `server_batch` span
//!      tree lands under the coordinator's scatter span when the
//!      per-process trace files are concatenated (see
//!      `telemetry::trace`).
//!   <- {"topk": [...], "scores": [...], "topk_bits": [[i, b], ...],
//!       "latency_s": x, "load_s": l, "compute_s": c2,
//!       "precondition_s": p, "batch": b, "bytes_read": n,
//!       "bytes_skipped": m, "cache_hits": h, "cache_misses": mm,
//!       "bytes_from_cache": c}
//!      (`topk_bits` pairs each original index with the f32 score's
//!      exact bit pattern — the lossless channel a scatter-gather
//!      coordinator merges on; `scores` is f64 and loses NaN to null)
//!   -> {"cmd": "stats"}
//!   <- {"served": n, "submitted": n, "shed": n, "failed": n,
//!       "batches": n, ..., "queue_depth": d, "cache_hit_rate": r,
//!       "workers": w, "uptime_s": t, "batch_wall_p50_s": x, ...}
//!   -> {"cmd": "metrics"}
//!   <- {"ok": true, "metrics": "# HELP lorif_...\n..."}
//!      (Prometheus text exposition of this server's registry, embedded
//!      as one JSON string — the newline-delimited protocol cannot
//!      carry raw multi-line text.  On a coordinator with a
//!      [`Fleet`](super::fleet::Fleet) attached this is the MERGED
//!      fleet exposition: the coordinator's own series labeled
//!      `{role="coordinator"}`, every scraped member page relabeled
//!      `{node="host:port",role="node"}`, plus per-endpoint
//!      `lorif_fleet_up` / scrape / health-state gauges)
//!   -> {"cmd": "health"}
//!   <- {"ok": true, "queue_depth": d, "workers": w, "served": n,
//!       "uptime_s": t, "shards": s}
//!      (cheap liveness probe answered straight from the handler
//!      thread — observable even when the scoring path is saturated;
//!      what the fleet monitor's probe loop polls)
//!   -> {"cmd": "slowlog"}
//!   <- {"ok": true, "slowlog": [entry, ...]}
//!      (the K slowest batches, slowest-first — see `query::slowlog`
//!      for the entry shape and the admission/eviction rules)
//!   -> {"cmd": "shutdown"}     (stops the server; used by tests)
//!   <- {"ok": true}
//!
//! Every server instance owns a PRIVATE telemetry [`Registry`]: the
//! scoring workers run each batch under `telemetry::with_ctx`, so the
//! store/cache/prune/executor families published during the pass land
//! in this server's registry (not the process global), and concurrent
//! servers — e.g. under `cargo test` — each expose coherent counters.
//! The `stats` verb is DERIVED from the same registry, so the JSON blob
//! and the exposition can never disagree, and
//! `served + shed + failed + dropped == submitted` reconciles exactly.
//! Errors are structured: {"error": msg, "code": c[, "index": i]} with
//! codes `bad_json`, `bad_request`, `invalid_tokens` (naming the first
//! offending token index), `overloaded` (load shed), `batch_failed`,
//! `timeout` (the connection sat idle/stalled past `--io-timeout-ms`),
//! and `shutdown`.
//!
//! Tokens are validated up front — non-numeric, non-integer,
//! out-of-vocab, and over-length requests are rejected with the
//! offending index rather than silently dropped, truncated, or passed
//! to the model.
//!
//! Serving always runs the scorers through the streaming top-k sink
//! (`SinkSpec::TopK`): a batch answer holds O(batch * topk) score
//! elements, never the full (batch, n_train) matrix, so the service
//! stays flat in memory against stores far larger than RAM.
//!
//! Gradient extraction stays on the batcher thread (with the XLA
//! backend, executables live there); socket threads only parse and
//! validate requests, and the scoring workers only run the CPU store
//! pass.  The `GradSource` trait is the seam: the CLI plugs in the
//! XLA-backed [`XlaGradSource`], tests plug in a CPU fake, so the whole
//! pipeline compiles and is exercised without the `xla` feature.
//!
//! Shutdown joins everything it started: the batcher flushes the
//! in-flight batch, the workers drain the job queue, and the acceptor
//! (a nonblocking poll loop, so it can never be stuck in `accept`) is
//! joined — so the listening port is released by the time `run`
//! returns (regression: the old server leaked the acceptor blocked in
//! `accept`, keeping the port bound and flaking any test that re-bound
//! the address).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use super::fleet::Fleet;
use super::plane::{LocalPlane, NodeStat, PlaneBatch, ShardPlane};
use super::slowlog::{SlowEntry, SlowLog};
use crate::attribution::{QueryGrads, Scorer};
use crate::telemetry::{self, Registry, TelemetryCtx, TraceCtx};
use crate::util::json::{obj, Value};

/// Source of query gradients for the serving pipeline.  `extract` runs
/// on the batcher thread only (single-threaded, pipelined against the
/// scoring workers), so implementations may hold thread-bound state
/// like XLA executables.
pub trait GradSource {
    /// Number of valid token ids; requests are validated to `[0, vocab)`.
    fn vocab(&self) -> usize;
    /// Fixed context length.  Shorter token rows are zero-padded,
    /// longer ones are rejected.
    fn seq_len(&self) -> usize;
    /// Extract gradients for `n` queries of `seq_len` tokens each
    /// (`tokens.len() == n * seq_len`).
    fn extract(&mut self, tokens: &[i32], n: usize) -> anyhow::Result<QueryGrads>;
}

/// The production source: AOT gradient-extraction graphs on the PJRT
/// runtime.
#[cfg(feature = "xla")]
pub struct XlaGradSource<'a> {
    pub rt: &'a crate::runtime::Runtime,
    pub extractor: &'a crate::runtime::GradExtractor,
    pub params: &'a xla::Literal,
}

#[cfg(feature = "xla")]
impl GradSource for XlaGradSource<'_> {
    fn vocab(&self) -> usize {
        crate::model::spec::VOCAB
    }

    fn seq_len(&self) -> usize {
        crate::model::spec::SEQ_LEN
    }

    fn extract(&mut self, tokens: &[i32], n: usize) -> anyhow::Result<QueryGrads> {
        // ad-hoc dataset from the batched query tokens
        let ds = crate::corpus::Dataset {
            seq_len: self.seq_len(),
            tokens: tokens.to_vec(),
            topics: vec![0; n],
            templates: vec![vec![]; n],
        };
        QueryGrads::extract(self.rt, self.extractor, self.params, &ds)
    }
}

pub struct ServerConfig {
    pub addr: String,
    pub max_batch: usize,
    pub window_ms: u64,
    pub topk: usize,
    /// Admission-control bound: queries queued between the connection
    /// handlers and the batcher.  A full queue sheds new requests with
    /// a structured `overloaded` error (`--queue-cap`).
    pub queue_cap: usize,
    /// Per-connection socket read/write timeout in milliseconds
    /// (`--io-timeout-ms`; 0 = never time out).  A peer that stalls
    /// mid-line gets a structured `timeout` error and its connection
    /// closed, so it can no longer pin a handler thread — and, in node
    /// mode, can no longer hang a coordinator's gather.
    pub io_timeout_ms: u64,
    /// Manifest shards this process serves (`--node-shards`; 0 = all).
    /// Purely informational at this layer — published as the
    /// `lorif_node_shards` gauge so a scrape identifies shard nodes.
    pub shards_served: usize,
    /// Capacity of the slow-query log (`--slowlog`; 0 disables it).
    /// The K slowest batches stay inspectable via the `slowlog` verb.
    pub slowlog_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7979".into(),
            max_batch: 16,
            window_ms: 20,
            topk: 10,
            queue_cap: 64,
            io_timeout_ms: 0,
            shards_served: 0,
            slowlog_cap: 32,
        }
    }
}

/// What `run` returns after a clean shutdown.  Every submitted request
/// lands in exactly one of `served`/`shed`/`failed`/`dropped` — a
/// request racing the final queue drain is counted `dropped` whether it
/// died at the closed admission queue or in the drain itself — so the
/// counts reconcile against client-side totals, and against the
/// registry's `lorif_server_submitted_total` (asserted through the
/// metrics exposition in `tests/server.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    /// queries answered with scores
    pub served: usize,
    /// queries shed by admission control (`overloaded` replies)
    pub shed: usize,
    /// queries answered with a `batch_failed` error
    pub failed: usize,
    /// queries still queued at shutdown, answered with a `shutdown` error
    pub dropped: usize,
    /// batches dispatched to the scoring workers
    pub batches: usize,
}

/// Per-server telemetry: a private [`Registry`] every counter lives in,
/// plus the start instant for `uptime_s`.  The `stats` verb READS the
/// registry (including the cache/store families the scoring passes
/// publish under `with_ctx`), so the JSON stats blob, the `metrics`
/// exposition, and the final [`ServeSummary`] are three views of one
/// ledger.
struct ServerStats {
    reg: Arc<Registry>,
    start: Instant,
    /// slow-query ring (see `query::slowlog`); touched once per scored
    /// batch and read by the `slowlog` verb
    slow: Mutex<SlowLog>,
}

impl ServerStats {
    fn new(slowlog_cap: usize) -> ServerStats {
        ServerStats {
            reg: Arc::new(Registry::new()),
            start: Instant::now(),
            slow: Mutex::new(SlowLog::new(slowlog_cap)),
        }
    }

    fn snapshot_json(&self, workers: usize) -> Value {
        let r = &self.reg;
        let hits = r.cache_hits.get();
        let misses = r.cache_misses.get();
        let rate = if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        };
        let wall = &r.server_batch_wall;
        obj([
            ("served", (r.server_served.get() as usize).into()),
            ("submitted", (r.server_submitted.get() as usize).into()),
            ("shed", (r.server_shed.get() as usize).into()),
            ("failed", (r.server_failed.get() as usize).into()),
            ("dropped", (r.server_dropped.get() as usize).into()),
            ("batches", (r.server_batches.get() as usize).into()),
            ("batch_errors", (r.server_batch_errors.get() as usize).into()),
            ("queue_depth", (r.server_queue_depth.get() as usize).into()),
            ("cache_hits", (hits as usize).into()),
            ("cache_misses", (misses as usize).into()),
            ("cache_hit_rate", rate.into()),
            ("bytes_from_cache", (r.store_bytes_from_cache.get() as usize).into()),
            ("bytes_read", (r.store_bytes_read.get() as usize).into()),
            ("workers", workers.into()),
            ("uptime_s", self.start.elapsed().as_secs_f64().into()),
            ("batch_wall_p50_s", wall.p50().into()),
            ("batch_wall_p95_s", wall.p95().into()),
            ("batch_wall_p99_s", wall.p99().into()),
        ])
    }
}

enum Incoming {
    Query {
        tokens: Vec<i32>,
        reply: mpsc::Sender<String>,
        /// when the request was admitted — reply latency covers queue
        /// wait + batching window + extraction + scoring
        arrived: Instant,
        /// caller-supplied trace ID (the coordinator forwards its own
        /// so a node's span tree nests under the coordinator's)
        trace: Option<u64>,
    },
    Shutdown,
}

/// One validated batch handed from the batcher to the scoring workers:
/// extracted gradients for a local plane, raw token rows for a remote
/// one (`ShardPlane::wants_grads` picks the variant).
struct Job {
    batch: PlaneBatch,
    replies: Vec<mpsc::Sender<String>>,
    /// when the batch's first query was ADMITTED (not when the batcher
    /// dequeued it): reply latency covers queue wait under overload,
    /// the batching window, extraction, and scoring
    t0: Instant,
    /// adopted trace ID: the batch's FIRST query names the track (one
    /// span tree per batch; a batch mixing traced and untraced queries
    /// follows its first)
    trace: Option<u64>,
}

/// A bound attribution service.  `bind` first, read `local_addr` (tests
/// bind port 0), then `run` the accept/batch/score pipeline until a
/// shutdown command arrives.
pub struct Server {
    listener: TcpListener,
    local: SocketAddr,
    cfg: ServerConfig,
    /// fleet monitor, coordinator mode only (`set_fleet`): starts the
    /// probe/scrape loops with `run`, federates the `metrics` verb, and
    /// extends the `stats` verb with per-endpoint health
    fleet: Option<Arc<Fleet>>,
}

/// Bind + run in one call (the CLI path).
pub fn serve<G: GradSource>(
    source: G,
    scorers: Vec<Box<dyn Scorer + Send>>,
    cfg: ServerConfig,
) -> anyhow::Result<ServeSummary> {
    Server::bind(cfg)?.run(source, scorers)
}

impl Server {
    pub fn bind(cfg: ServerConfig) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local = listener.local_addr()?;
        Ok(Server { listener, local, cfg, fleet: None })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Attach a fleet monitor (coordinator mode).  `run` starts its
    /// probe/scrape loops scoped to this server's registry and stops
    /// them at shutdown; share the SAME `Arc` with the `RemotePlane`s
    /// so scatter legs route on the probes' verdicts.
    pub fn set_fleet(&mut self, fleet: Arc<Fleet>) {
        self.fleet = Some(fleet);
    }

    /// Run until a shutdown command arrives.  One scoring worker per
    /// scorer instance; build them over one `Arc<ShardSet>` (see
    /// `app::build_store_scorer_pool`) so the pool shares the store and
    /// chunk cache.
    pub fn run<G: GradSource>(
        self,
        source: G,
        scorers: Vec<Box<dyn Scorer + Send>>,
    ) -> anyhow::Result<ServeSummary> {
        let planes = scorers
            .into_iter()
            .map(|scorer| Box::new(LocalPlane { scorer }) as Box<dyn ShardPlane + Send>)
            .collect();
        self.run_planes(source, planes)
    }

    /// Run the pipeline over an explicit set of shard planes — the seam
    /// the coordinator uses (`query::coordinator::RemotePlane` plus a
    /// `TokenSource`).  All planes must agree on `wants_grads`: the
    /// batcher either extracts gradients once per batch or forwards the
    /// raw token rows, not both.
    pub fn run_planes<G: GradSource>(
        self,
        mut source: G,
        planes: Vec<Box<dyn ShardPlane + Send>>,
    ) -> anyhow::Result<ServeSummary> {
        anyhow::ensure!(!planes.is_empty(), "serve needs at least one scoring worker");
        let wants_grads = planes[0].wants_grads();
        anyhow::ensure!(
            planes.iter().all(|p| p.wants_grads() == wants_grads),
            "mixed local/remote planes in one server"
        );
        let cfg = &self.cfg;
        let seq_len = source.seq_len();
        let vocab = source.vocab();
        let n_workers = planes.len();
        let stats = Arc::new(ServerStats::new(cfg.slowlog_cap));
        stats.reg.server_workers.set(n_workers as u64);
        stats.reg.node_shards.set(cfg.shards_served as u64);
        // coordinator mode: start the probe/scrape loops now, scoped to
        // THIS server's registry (the ctx is captured here and
        // re-installed inside each monitor thread — the same pattern as
        // the worker pool and the reader prefetch thread — so probe and
        // federation metrics land next to the serving counters)
        let fleet = self.fleet.clone();
        let fleet_threads = fleet.as_ref().map(|f| {
            f.start(TelemetryCtx {
                registry: Some(Arc::clone(&stats.reg)),
                trace: TraceCtx::default(),
            })
        });
        let io_timeout = (cfg.io_timeout_ms > 0)
            .then(|| Duration::from_millis(cfg.io_timeout_ms));
        // shared with the (detached) conn handlers too: once set, they
        // stop admitting queries, which closes most of the window where
        // a request could race the final queue drain
        let shutting_down = Arc::new(AtomicBool::new(false));
        log::info!(
            "attribution service on {} (batch <= {}, window {}ms, {} workers, queue {})",
            self.local,
            cfg.max_batch,
            cfg.window_ms,
            n_workers,
            cfg.queue_cap
        );

        // conn handlers -> batcher: the bounded admission queue
        let (tx, rx) = mpsc::sync_channel::<Incoming>(cfg.queue_cap.max(1));
        // batcher -> workers: depth 1 on top of the workers' own slots,
        // so extraction of batch N+1 overlaps scoring of batch N
        // without piling extracted batches up in memory
        let (jtx, jrx) = mpsc::sync_channel::<Job>(1);
        let jrx = Arc::new(Mutex::new(jrx));
        let listener = &self.listener;
        let local = self.local;
        let shutting_down = &shutting_down;

        // nonblocking accepts: the acceptor polls with a short sleep, so
        // shutdown never depends on successfully waking a blocked
        // accept(), and a persistent accept error (e.g. EMFILE under a
        // connection burst) backs off instead of busy-spinning
        self.listener.set_nonblocking(true)?;

        let summary = std::thread::scope(|s| -> anyhow::Result<ServeSummary> {
            // if anything in this closure PANICS (e.g. inside
            // GradSource::extract on the batcher path), the guard still
            // raises the shutdown flag while unwinding — otherwise
            // thread::scope would block forever joining the acceptor,
            // swallowing the panic and keeping the port bound
            let _shutdown_on_unwind = ShutdownGuard(shutting_down.as_ref());

            // acceptor: polls until shutdown; one detached handler
            // thread per connection (handlers own no server state
            // beyond channel ends and the stats Arc)
            let acceptor = {
                let tx = tx.clone();
                let stats = Arc::clone(&stats);
                let fleet = fleet.clone();
                s.spawn(move || {
                    while !shutting_down.load(Ordering::SeqCst) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                // accepted sockets must block (the
                                // nonblocking flag is inherited on some
                                // platforms)
                                if stream.set_nonblocking(false).is_err() {
                                    continue;
                                }
                                let tx = tx.clone();
                                let stats = Arc::clone(&stats);
                                let flag = Arc::clone(shutting_down);
                                let fleet = fleet.clone();
                                std::thread::spawn(move || {
                                    let _ = handle_conn(
                                        stream, tx, stats, flag, seq_len, vocab, n_workers,
                                        io_timeout, fleet,
                                    );
                                });
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(e) => {
                                // EMFILE and friends: back off, keep serving
                                log::warn!("accept failed: {e}");
                                std::thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                })
            };

            // scoring workers: each owns one plane; the shared
            // receiver hands jobs to whichever worker is free
            let topk = cfg.topk;
            let workers: Vec<_> = planes
                .into_iter()
                .map(|mut plane| {
                    let jrx = Arc::clone(&jrx);
                    let stats = Arc::clone(&stats);
                    s.spawn(move || loop {
                        let job = {
                            let guard = jrx.lock().expect("job queue lock");
                            guard.recv()
                        };
                        let Ok(job) = job else { break };
                        score_job(plane.as_mut(), job, topk, &stats);
                    })
                })
                .collect();
            // only the workers may keep the job Receiver alive: if every
            // worker dies (panic), the channel disconnects and the
            // batcher's send fails instead of blocking forever
            drop(jrx);

            // batcher (this thread): collect a window, extract, dispatch
            loop {
                let (first, t0, trace) = match rx.recv() {
                    Ok(Incoming::Query { tokens, reply, arrived, trace }) => {
                        stats.reg.server_queue_depth.sub(1);
                        ((tokens, reply), arrived, trace)
                    }
                    Ok(Incoming::Shutdown) | Err(_) => break,
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + Duration::from_millis(cfg.window_ms);
                let mut shutdown_after = false;
                while batch.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(Incoming::Query { tokens, reply, .. }) => {
                            stats.reg.server_queue_depth.sub(1);
                            batch.push((tokens, reply));
                        }
                        Ok(Incoming::Shutdown) => {
                            shutdown_after = true;
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(_) => {
                            shutdown_after = true;
                            break;
                        }
                    }
                }
                let workers_alive = dispatch_batch(
                    &mut source, batch, seq_len, wants_grads, t0, trace, &jtx, &stats,
                );
                if shutdown_after || !workers_alive {
                    break;
                }
            }

            // orderly teardown: drain the workers, then wake + join the
            // acceptor so the port is free when we return.  The acceptor
            // is ALWAYS woken before any early error return — a scoped
            // thread left blocked in accept() would deadlock the scope.
            drop(jtx);
            let mut worker_panicked = false;
            for w in workers {
                worker_panicked |= w.join().is_err();
            }
            shutting_down.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(local); // nudge a pending accept along
            let acceptor_panicked = acceptor.join().is_err();
            // drain + count queries still queued at shutdown so the
            // summary reconciles (their handlers get a structured
            // `shutdown` error when the reply senders drop)
            while let Ok(msg) = rx.try_recv() {
                if let Incoming::Query { .. } = msg {
                    stats.reg.server_queue_depth.sub(1);
                    stats.reg.server_dropped.inc();
                }
            }
            drop(rx);
            anyhow::ensure!(!worker_panicked, "scoring worker panicked");
            anyhow::ensure!(!acceptor_panicked, "acceptor thread panicked");
            Ok(ServeSummary {
                served: stats.reg.server_served.get() as usize,
                shed: stats.reg.server_shed.get() as usize,
                failed: stats.reg.server_failed.get() as usize,
                dropped: stats.reg.server_dropped.get() as usize,
                batches: stats.reg.server_batches.get() as usize,
            })
        });
        // monitor loops are plain (unscoped) threads holding only the
        // fleet Arc; stop + join them whether the scope succeeded or
        // not so `run` never leaks probers against a dead topology
        if let Some(f) = &fleet {
            f.stop();
        }
        if let Some(handles) = fleet_threads {
            for h in handles {
                let _ = h.join();
            }
        }
        let summary = summary?;
        log::info!(
            "attribution service stopped: {} served, {} shed, {} failed, {} dropped \
             over {} batches",
            summary.served,
            summary.shed,
            summary.failed,
            summary.dropped,
            summary.batches
        );
        Ok(summary)
        // self.listener drops here -> the port is released
    }
}

/// Prepare a batch for the planes — extract its gradients (local
/// planes) or package the raw token rows (remote planes) — and hand it
/// to the scoring workers.  An extraction failure answers exactly this
/// batch's clients with a structured error — one poisoned batch must
/// never kill the service.  Returns `false` when the scoring workers
/// are gone (all panicked), which tells the batcher to stop instead of
/// serving a dead pipeline.
#[allow(clippy::too_many_arguments)]
fn dispatch_batch<G: GradSource>(
    source: &mut G,
    batch: Vec<(Vec<i32>, mpsc::Sender<String>)>,
    seq_len: usize,
    wants_grads: bool,
    t0: Instant,
    trace: Option<u64>,
    jtx: &mpsc::SyncSender<Job>,
    stats: &ServerStats,
) -> bool {
    if batch.is_empty() {
        return true;
    }
    let n = batch.len();
    let mut tokens = Vec::with_capacity(n * seq_len);
    let mut replies = Vec::with_capacity(n);
    for (t, r) in batch {
        tokens.extend_from_slice(&t);
        replies.push(r);
    }
    let prepared = if wants_grads {
        source.extract(&tokens, n).map(PlaneBatch::Grads)
    } else {
        Ok(PlaneBatch::Tokens { tokens, n, seq_len })
    };
    match prepared {
        Ok(batch) => {
            stats.reg.server_batches.inc();
            if jtx.send(Job { batch, replies, t0, trace }).is_err() {
                // every worker died: the handlers see the dropped reply
                // senders and answer with `shutdown`; stop the batcher
                // so run() reports the worker panic
                stats.reg.server_dropped.add(n as u64);
                log::error!("batch of {n} dropped: all scoring workers stopped");
                return false;
            }
        }
        Err(e) => {
            stats.reg.server_batch_errors.inc();
            stats.reg.server_failed.add(n as u64);
            log::warn!("gradient extraction failed for a batch of {n}: {e:#}");
            let resp =
                error_json(&format!("gradient extraction failed: {e}"), "batch_failed", None)
                    .to_string();
            for r in &replies {
                let _ = r.send(resp.clone());
            }
        }
    }
    true
}

/// Score one batch on a worker — through whatever plane the worker
/// owns, in-process or scatter-gather — and answer its clients.  A
/// scoring error answers this batch's clients with `batch_failed` and
/// the worker keeps pulling jobs.
fn score_job(plane: &mut dyn ShardPlane, job: Job, k: usize, stats: &ServerStats) {
    let n = job.replies.len();
    // the whole pass runs scoped to THIS server's registry (so the
    // executor/reader/cache families a local plane publishes — and the
    // coord_* families a remote plane publishes — land here, not in
    // the process global) and on one trace track per batch: a
    // caller-forwarded `"trace"` ID is adopted (so a node's span tree
    // shares the coordinator's trace ID), otherwise a fresh one
    let trace = job
        .trace
        .map(|id| TraceCtx { id, lane: 0 })
        .unwrap_or_else(TraceCtx::next_query);
    let ctx = TelemetryCtx { registry: Some(Arc::clone(&stats.reg)), trace };
    let result = telemetry::with_ctx(ctx, || {
        let mut sp = telemetry::trace::span("server_batch");
        if let Some(s) = sp.as_mut() {
            s.arg("batch", n);
            s.arg_str("plane", plane.name());
        }
        plane.score_topk(&job.batch, k)
    });
    match result {
        Ok(rep) => {
            let lat = &rep.latency;
            let latency = job.t0.elapsed().as_secs_f64();
            // counters land BEFORE the replies so a client that probes
            // `stats` right after its answer sees itself counted (the
            // cache/byte families were published by the pass itself)
            stats.reg.server_batch_wall.observe_secs(latency);
            stats.reg.server_served.add(n as u64);
            stats.reg.node_queries.add(n as u64);
            // offer the finished batch to the slow-query ring (keeps
            // the K slowest; the trace ID ties an entry back to its
            // span tree in a --trace-out file)
            if let Ok(mut slow) = stats.slow.lock() {
                let admitted = slow.offer(SlowEntry {
                    trace_id: trace.id,
                    wall_s: latency,
                    batch: n,
                    ts_s: stats.start.elapsed().as_secs_f64(),
                    latency: rep.latency.clone(),
                    nodes: rep.nodes.clone(),
                    seq: 0,
                });
                if admitted {
                    stats.reg.slowlog_admitted.inc();
                }
                stats.reg.slowlog_entries.set(slow.len() as u64);
            }
            // per-node stats of a scatter-gather pass; empty (and
            // omitted from replies) on the local plane
            let node_stats: Vec<Value> = rep.nodes.iter().map(NodeStat::to_json).collect();
            for (q, reply) in job.replies.iter().enumerate() {
                let top = rep.topk[q].entries();
                // `scores` (f64) is for humans and loses NaN to JSON's
                // null; `topk_bits` carries each f32 score's exact bit
                // pattern as an integer (integers <= 2^32 survive the
                // f64 JSON number path bit-for-bit), which is what lets
                // a coordinator rebuild this node's heaps and merge
                // them IDENTICALLY to a local pass
                let bits = top
                    .iter()
                    .map(|&(s, i)| {
                        Value::Arr(vec![i.into(), (s.to_bits() as usize).into()])
                    })
                    .collect();
                let mut fields = vec![
                    ("topk", Value::Arr(top.iter().map(|&(_, i)| i.into()).collect())),
                    (
                        "scores",
                        Value::Arr(top.iter().map(|&(s, _)| (s as f64).into()).collect()),
                    ),
                    ("topk_bits", Value::Arr(bits)),
                    ("latency_s", latency.into()),
                    // per-phase CPU seconds of the pass, so a
                    // coordinator can aggregate a cross-node
                    // LatencyBreakdown (sum phases, max walls)
                    ("load_s", lat.load_s.into()),
                    ("compute_s", lat.compute_s.into()),
                    ("precondition_s", lat.precondition_s.into()),
                    ("batch", n.into()),
                    ("bytes_read", (lat.bytes_read as usize).into()),
                    ("bytes_skipped", (lat.bytes_skipped as usize).into()),
                    ("cache_hits", lat.cache_hits.into()),
                    ("cache_misses", lat.cache_misses.into()),
                    ("bytes_from_cache", (lat.bytes_from_cache as usize).into()),
                ];
                if !node_stats.is_empty() {
                    fields.push(("nodes", Value::Arr(node_stats.clone())));
                }
                let resp = obj(fields);
                let _ = reply.send(resp.to_string());
            }
            log::info!("served batch of {n} in {latency:.3}s via the {} plane", plane.name());
        }
        Err(e) => {
            stats.reg.server_batch_errors.inc();
            stats.reg.server_failed.add(n as u64);
            log::warn!("scoring failed for a batch of {n}: {e:#}");
            let resp =
                error_json(&format!("scoring failed: {e}"), "batch_failed", None).to_string();
            for reply in &job.replies {
                let _ = reply.send(resp.clone());
            }
        }
    }
}

/// Raises the shutdown flag when dropped — including on panic unwind,
/// which is what keeps the polling acceptor joinable (see `Server::run`).
struct ShutdownGuard<'a>(&'a AtomicBool);

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

fn error_json(msg: &str, code: &str, index: Option<usize>) -> Value {
    let mut fields: Vec<(&'static str, Value)> =
        vec![("error", msg.to_string().into()), ("code", code.to_string().into())];
    if let Some(i) = index {
        fields.push(("index", i.into()));
    }
    obj(fields)
}

/// Validate a request's `tokens` field: must be an array of at most
/// `seq_len` integer ids in `[0, vocab)`.  Returns the zero-padded row
/// or `(message, offending index)` — no silent drops (`filter_map`),
/// truncation, or out-of-vocab pass-through.
fn parse_tokens(
    v: &Value,
    seq_len: usize,
    vocab: usize,
) -> Result<Vec<i32>, (String, Option<usize>)> {
    let Some(arr) = v.get("tokens").and_then(Value::as_arr) else {
        return Err(("missing or non-array 'tokens' field".to_string(), None));
    };
    if arr.len() > seq_len {
        return Err((
            format!(
                "too many tokens: got {}, context length is {seq_len} (first excess at index {seq_len})",
                arr.len()
            ),
            Some(seq_len),
        ));
    }
    let mut out = Vec::with_capacity(seq_len);
    for (i, t) in arr.iter().enumerate() {
        let Some(x) = t.as_f64() else {
            return Err((format!("non-numeric token at index {i}"), Some(i)));
        };
        if x.fract() != 0.0 || !x.is_finite() {
            return Err((format!("non-integer token {x} at index {i}"), Some(i)));
        }
        if x < 0.0 || x >= vocab as f64 {
            return Err((
                format!("token {x} at index {i} outside vocab range [0, {vocab})"),
                Some(i),
            ));
        }
        out.push(x as i32);
    }
    out.resize(seq_len, 0);
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    tx: mpsc::SyncSender<Incoming>,
    stats: Arc<ServerStats>,
    shutting_down: Arc<AtomicBool>,
    seq_len: usize,
    vocab: usize,
    workers: usize,
    io_timeout: Option<Duration>,
    fleet: Option<Arc<Fleet>>,
) -> anyhow::Result<()> {
    let peer = stream.peer_addr()?;
    // a peer that stalls mid-line (or never writes) trips the socket
    // timeout instead of pinning this handler thread forever
    stream.set_read_timeout(io_timeout)?;
    stream.set_write_timeout(io_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // connection closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // structured goodbye, then close: the peer held the
                // connection open past the io timeout without
                // completing a request line
                log::warn!("closing idle/stalled connection from {peer}");
                let _ = writeln!(
                    stream,
                    "{}",
                    error_json("connection idle past the io timeout", "timeout", None)
                );
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        let v = match Value::parse(line.trim()) {
            Ok(v) => v,
            Err(e) => {
                let _ = writeln!(stream, "{}", error_json(&format!("{e}"), "bad_json", None));
                continue;
            }
        };
        match v.get("cmd").and_then(Value::as_str) {
            Some("shutdown") => {
                // ack first: the enqueue below may block briefly behind
                // a full admission queue while the batcher drains it
                let _ = writeln!(stream, "{}", obj([("ok", true.into())]));
                let _ = tx.send(Incoming::Shutdown);
                return Ok(());
            }
            Some("stats") => {
                // served straight from the handler: stats stay
                // observable even when the scoring path is saturated.
                // With a fleet attached, a `fleet` array extends the
                // blob with per-endpoint health (state, consecutive
                // failures, probe/scrape ages, failover counts).
                let mut v = stats.snapshot_json(workers);
                if let (Some(f), Value::Obj(m)) = (&fleet, &mut v) {
                    m.insert("fleet".to_string(), f.health_json());
                }
                let _ = writeln!(stream, "{v}");
                continue;
            }
            Some("metrics") => {
                // the full Prometheus exposition of this server's
                // registry, embedded as one JSON string — the
                // newline-delimited protocol can't carry raw multi-line
                // text (a scraping sidecar unescapes `metrics`).  In
                // coordinator mode this is the MERGED fleet page: own
                // series labeled {role="coordinator"}, scraped member
                // pages relabeled {node=...,role="node"}, plus the
                // synthesized lorif_fleet_* per-endpoint gauges.
                let text = match &fleet {
                    Some(f) => f.federate(&stats.reg),
                    None => stats.reg.render_prometheus(),
                };
                let resp = obj([("ok", true.into()), ("metrics", text.into())]);
                let _ = writeln!(stream, "{resp}");
                continue;
            }
            Some("health") => {
                // the probe loop's target: cheap, handler-local, and
                // meaningful even while the scoring path is saturated
                let r = &stats.reg;
                let resp = obj([
                    ("ok", true.into()),
                    ("queue_depth", (r.server_queue_depth.get() as usize).into()),
                    ("workers", workers.into()),
                    ("served", (r.server_served.get() as usize).into()),
                    ("uptime_s", stats.start.elapsed().as_secs_f64().into()),
                    ("shards", (r.node_shards.get() as usize).into()),
                ]);
                let _ = writeln!(stream, "{resp}");
                continue;
            }
            Some("slowlog") => {
                let entries = stats
                    .slow
                    .lock()
                    .map(|s| s.snapshot_json())
                    .unwrap_or_else(|_| Value::Arr(Vec::new()));
                let resp = obj([("ok", true.into()), ("slowlog", entries)]);
                let _ = writeln!(stream, "{resp}");
                continue;
            }
            Some(other) => {
                let _ = writeln!(
                    stream,
                    "{}",
                    error_json(&format!("unknown cmd '{other}'"), "bad_request", None)
                );
                continue;
            }
            None => {}
        }
        let tokens = match parse_tokens(&v, seq_len, vocab) {
            Ok(t) => t,
            Err((msg, idx)) => {
                let _ = writeln!(stream, "{}", error_json(&msg, "invalid_tokens", idx));
                continue;
            }
        };
        // optional caller trace ID: a positive integer adopts the
        // caller's span-tree identity for this query's batch; anything
        // malformed is ignored (tracing is diagnostic, never a reason
        // to reject a valid query)
        let trace = v
            .get("trace")
            .and_then(Value::as_f64)
            .filter(|x| x.fract() == 0.0 && *x >= 1.0 && *x <= u64::MAX as f64)
            .map(|x| x as u64);
        if shutting_down.load(Ordering::SeqCst) {
            // stop admitting during teardown so queries cannot race the
            // final queue drain and escape the summary accounting
            let _ = writeln!(stream, "{}", error_json("server stopped", "shutdown", None));
            return Ok(());
        }
        let (rtx, rrx) = mpsc::channel();
        // `submitted` counts every validated query reaching admission,
        // whatever its fate — the reconciliation the concurrent-load
        // test reads back through the exposition:
        // served + shed + failed + dropped == submitted
        stats.reg.server_submitted.inc();
        // count before sending so the depth never underflows; undone on
        // the shed path (the batcher decrements accepted entries)
        stats.reg.server_queue_depth.add(1);
        match tx.try_send(Incoming::Query {
            tokens,
            reply: rtx,
            arrived: Instant::now(),
            trace,
        }) {
            Ok(()) => {}
            Err(mpsc::TrySendError::Full(_)) => {
                stats.reg.server_queue_depth.sub(1);
                stats.reg.server_shed.inc();
                let depth = stats.reg.server_queue_depth.get() as usize;
                // sheds are fleet-level incidents too: with an event
                // log attached, each one lands as a JSONL line next to
                // node_down/failover so overload and failure correlate
                if let Some(f) = &fleet {
                    f.event("shed", "coordinator", vec![("queue_depth", depth.into())]);
                }
                let resp = obj([
                    ("error", "server overloaded: admission queue full".into()),
                    ("code", "overloaded".into()),
                    ("queue_depth", depth.into()),
                ]);
                let _ = writeln!(stream, "{resp}");
                continue;
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                // the queue closed mid-admission: count the query
                // dropped so `submitted` still reconciles
                stats.reg.server_queue_depth.sub(1);
                stats.reg.server_dropped.inc();
                let _ = writeln!(stream, "{}", error_json("server stopped", "shutdown", None));
                return Ok(());
            }
        }
        match rrx.recv() {
            Ok(resp) => writeln!(stream, "{resp}")?,
            Err(_) => {
                let _ = writeln!(
                    stream,
                    "{}",
                    error_json("server stopped before this query was scored", "shutdown", None)
                );
                return Ok(());
            }
        }
        log::debug!("answered query from {peer}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens_value(items: &str) -> Value {
        Value::parse(&format!("{{\"tokens\": {items}}}")).unwrap()
    }

    #[test]
    fn parse_tokens_pads_and_validates() {
        let v = tokens_value("[1, 2, 3]");
        assert_eq!(parse_tokens(&v, 5, 64).unwrap(), vec![1, 2, 3, 0, 0]);
        // exactly seq_len is fine
        let v = tokens_value("[1, 2, 3, 4, 5]");
        assert_eq!(parse_tokens(&v, 5, 64).unwrap(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parse_tokens_rejects_overlength_instead_of_truncating() {
        let v = tokens_value("[1, 2, 3, 4, 5, 6]");
        let (msg, idx) = parse_tokens(&v, 5, 64).unwrap_err();
        assert!(msg.contains("too many tokens"), "{msg}");
        assert_eq!(idx, Some(5), "first excess index");
    }

    #[test]
    fn parse_tokens_rejects_non_numeric_naming_index() {
        let v = tokens_value("[1, \"a\", 3]");
        let (msg, idx) = parse_tokens(&v, 5, 64).unwrap_err();
        assert!(msg.contains("non-numeric"), "{msg}");
        assert_eq!(idx, Some(1));
    }

    #[test]
    fn parse_tokens_rejects_fractional_and_out_of_vocab() {
        let (msg, idx) = parse_tokens(&tokens_value("[1.5]"), 5, 64).unwrap_err();
        assert!(msg.contains("non-integer"), "{msg}");
        assert_eq!(idx, Some(0));
        let (msg, idx) = parse_tokens(&tokens_value("[0, -1]"), 5, 64).unwrap_err();
        assert!(msg.contains("outside vocab"), "{msg}");
        assert_eq!(idx, Some(1));
        let (msg, idx) = parse_tokens(&tokens_value("[0, 64]"), 5, 64).unwrap_err();
        assert!(msg.contains("outside vocab"), "{msg}");
        assert_eq!(idx, Some(1));
        // boundary ids pass
        assert!(parse_tokens(&tokens_value("[0, 63]"), 5, 64).is_ok());
    }

    #[test]
    fn parse_tokens_rejects_missing_field() {
        let v = Value::parse("{\"cmd\": \"x\"}").unwrap();
        let (msg, idx) = parse_tokens(&v, 5, 64).unwrap_err();
        assert!(msg.contains("tokens"), "{msg}");
        assert_eq!(idx, None);
    }

    #[test]
    fn error_json_is_structured() {
        let e = error_json("bad token", "invalid_tokens", Some(3));
        assert_eq!(e.get("error").and_then(Value::as_str), Some("bad token"));
        assert_eq!(e.get("code").and_then(Value::as_str), Some("invalid_tokens"));
        assert_eq!(e.get("index").and_then(Value::as_usize), Some(3));
        let e = error_json("oops", "batch_failed", None);
        assert!(e.get("index").is_none());
    }

    #[test]
    fn stats_snapshot_has_the_documented_fields() {
        let stats = ServerStats::new(32);
        stats.reg.server_served.add(5);
        stats.reg.cache_hits.add(3);
        stats.reg.cache_misses.add(1);
        stats.reg.server_batch_wall.observe_secs(0.25);
        let v = stats.snapshot_json(2);
        assert_eq!(v.get("served").and_then(Value::as_usize), Some(5));
        assert_eq!(v.get("workers").and_then(Value::as_usize), Some(2));
        assert!((v.get("cache_hit_rate").and_then(Value::as_f64).unwrap() - 0.75).abs() < 1e-9);
        assert!(v.get("uptime_s").and_then(Value::as_f64).unwrap() >= 0.0);
        // one 0.25s batch: every percentile reports its bucket bound
        for p in ["batch_wall_p50_s", "batch_wall_p95_s", "batch_wall_p99_s"] {
            let x = v.get(p).and_then(Value::as_f64).unwrap();
            assert!(x >= 0.25 && x < 1.0, "{p} = {x}");
        }
        for key in [
            "submitted",
            "shed",
            "failed",
            "dropped",
            "batches",
            "batch_errors",
            "queue_depth",
            "bytes_read",
        ] {
            assert!(v.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn metrics_exposition_from_a_fresh_server_registry_has_all_families() {
        // what the `{"cmd":"metrics"}` verb serves on a fresh instance:
        // every family pre-registered, so a scrape before the first
        // query still sees the full schema at zero
        let stats = ServerStats::new(32);
        let text = stats.reg.render_prometheus();
        for family in
            ["lorif_server_submitted_total", "lorif_server_batch_wall_seconds", "lorif_cache_hits_total"]
        {
            assert!(text.contains(family), "missing {family}");
        }
    }
}

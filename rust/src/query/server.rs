//! Attribution service: TCP line-protocol server with dynamic batching.
//!
//! The serving-side payoff of LoRIF's design is that one streaming pass
//! over the factor store answers a whole *batch* of queries (the store
//! read amortizes across queries).  The batcher therefore collects
//! concurrent requests for up to `window_ms` (or `max_batch`), extracts
//! their gradients, and runs one scorer pass.
//!
//! Protocol (newline-delimited JSON):
//!   -> {"tokens": [t0, t1, ...]}            (seq_len token ids)
//!   <- {"topk": [...], "scores": [...], "latency_s": x, "batch": b,
//!       "bytes_read": n, "bytes_skipped": m}
//! (`bytes_skipped` counts store bytes the chunk pruner proved
//! irrelevant to this batch's top-k and never read; see crate::sketch)
//! Send `{"cmd": "shutdown"}` to stop the server (used by tests).
//!
//! Serving always runs the scorer through the streaming top-k sink
//! (`SinkSpec::TopK`): a batch answer holds O(batch * topk) score
//! elements, never the full (batch, n_train) matrix, so the service
//! stays flat in memory against stores far larger than RAM.
//!
//! XLA executables live on the serving thread; socket threads only parse
//! requests and forward them over channels.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::attribution::{QueryGrads, Scorer, SinkSpec};
use crate::corpus::Dataset;
use crate::model::spec::SEQ_LEN;
use crate::runtime::{GradExtractor, Runtime};
use crate::util::json::{obj, Value};

pub struct ServerConfig {
    pub addr: String,
    pub max_batch: usize,
    pub window_ms: u64,
    pub topk: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7979".into(), max_batch: 16, window_ms: 20, topk: 10 }
    }
}

enum Incoming {
    Query { tokens: Vec<i32>, reply: mpsc::Sender<String> },
    Shutdown,
}

/// Run the attribution service until a shutdown command arrives.
/// Returns the number of queries served.
pub fn serve<S: Scorer>(
    rt: &Runtime,
    extractor: &GradExtractor,
    params: &xla::Literal,
    mut scorer: S,
    cfg: ServerConfig,
) -> anyhow::Result<usize> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let local = listener.local_addr()?;
    log::info!("attribution service on {local} (batch<= {}, window {}ms)", cfg.max_batch, cfg.window_ms);
    let (tx, rx) = mpsc::channel::<Incoming>();

    // acceptor thread: one handler thread per connection
    let acceptor = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx);
            });
        }
    });

    let mut served = 0usize;
    'outer: loop {
        // block for the first query of a batch
        let first = match rx.recv() {
            Ok(Incoming::Query { tokens, reply }) => (tokens, reply),
            Ok(Incoming::Shutdown) | Err(_) => break 'outer,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + Duration::from_millis(cfg.window_ms);
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Incoming::Query { tokens, reply }) => batch.push((tokens, reply)),
                Ok(Incoming::Shutdown) => {
                    respond_batch(rt, extractor, params, &mut scorer, &cfg, &batch)?;
                    served += batch.len();
                    break 'outer;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(_) => break 'outer,
            }
        }
        respond_batch(rt, extractor, params, &mut scorer, &cfg, &batch)?;
        served += batch.len();
    }
    drop(acceptor); // acceptor thread exits when process does; not joined
    Ok(served)
}

fn respond_batch<S: Scorer>(
    rt: &Runtime,
    extractor: &GradExtractor,
    params: &xla::Literal,
    scorer: &mut S,
    cfg: &ServerConfig,
    batch: &[(Vec<i32>, mpsc::Sender<String>)],
) -> anyhow::Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    let t0 = Instant::now();
    // build an ad-hoc dataset from the batched query tokens
    let mut tokens = Vec::with_capacity(batch.len() * SEQ_LEN);
    for (t, _) in batch {
        tokens.extend_from_slice(t);
    }
    let ds = Dataset {
        seq_len: SEQ_LEN,
        tokens,
        topics: vec![0; batch.len()],
        templates: vec![vec![]; batch.len()],
    };
    let queries = QueryGrads::extract(rt, extractor, params, &ds)?;
    // streaming top-k sink: the same merged-heap path the engine and
    // parallel shard scoring use, never the full score matrix
    let report = scorer.score_sink(&queries, SinkSpec::TopK(cfg.topk))?;
    let topk = report.topk_with_scores(cfg.topk);
    let latency = t0.elapsed().as_secs_f64();
    for (q, (_, reply)) in batch.iter().enumerate() {
        let top = &topk[q];
        let resp = obj([
            ("topk", Value::Arr(top.iter().map(|&(i, _)| i.into()).collect())),
            (
                "scores",
                Value::Arr(top.iter().map(|&(_, s)| (s as f64).into()).collect()),
            ),
            ("latency_s", latency.into()),
            ("batch", batch.len().into()),
            ("bytes_read", (report.bytes_read as usize).into()),
            ("bytes_skipped", (report.bytes_skipped as usize).into()),
        ]);
        let _ = reply.send(resp.to_string());
    }
    log::info!("served batch of {} in {:.3}s", batch.len(), latency);
    Ok(())
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Incoming>) -> anyhow::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // connection closed
        }
        let v = match Value::parse(line.trim()) {
            Ok(v) => v,
            Err(e) => {
                let _ = writeln!(stream, "{}", obj([("error", format!("{e}").into())]));
                continue;
            }
        };
        if v.get("cmd").and_then(Value::as_str) == Some("shutdown") {
            let _ = tx.send(Incoming::Shutdown);
            let _ = writeln!(stream, "{}", obj([("ok", true.into())]));
            return Ok(());
        }
        let Some(toks) = v.get("tokens").and_then(Value::as_arr) else {
            let _ = writeln!(stream, "{}", obj([("error", "missing tokens".into())]));
            continue;
        };
        let mut tokens: Vec<i32> =
            toks.iter().filter_map(|t| t.as_f64().map(|x| x as i32)).collect();
        // pad/truncate to the fixed context length
        tokens.resize(SEQ_LEN, 0);
        let (rtx, rrx) = mpsc::channel();
        if tx.send(Incoming::Query { tokens, reply: rtx }).is_err() {
            return Ok(());
        }
        match rrx.recv() {
            Ok(resp) => writeln!(stream, "{resp}")?,
            Err(_) => {
                let _ = writeln!(stream, "{}", obj([("error", "server stopped".into())]));
                return Ok(());
            }
        }
        log::debug!("answered query from {peer}");
    }
}

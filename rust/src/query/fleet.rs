//! Fleet monitor: health probes, the endpoint state machine, the
//! federation scrape loop, and the structured JSONL event log.
//!
//! One [`Fleet`] is shared between the coordinator's serving loop and
//! its `RemotePlane`s.  Two background threads (started by
//! [`Fleet::start`] under the server's captured telemetry ctx, so their
//! metrics land in the server's scoped registry like every pool thread):
//!
//! - the **probe loop** issues `{"cmd":"health"}` to every primary and
//!   replica on `probe_interval` with its own short `probe_timeout`
//!   (independent of `--io-timeout-ms`), feeding the per-endpoint
//!   state machine below;
//! - the **scrape loop** issues `{"cmd":"metrics"}` on
//!   `scrape_interval` and stores each member's exposition verbatim, so
//!   [`Fleet::federate`] can merge the whole fleet into one labeled
//!   page (`telemetry::federation`) with synthesized
//!   `lorif_fleet_up` / `lorif_fleet_scrape_duration_seconds` /
//!   `lorif_fleet_scrape_age_seconds` / `lorif_fleet_health_state`
//!   per-node gauges.
//!
//! # State machine
//!
//! `Healthy → Degraded` on the first failure, `→ Down` after
//! `fail_threshold` CONSECUTIVE failures (or any failure while
//! half-open).  A success while `Down` re-opens the endpoint HALF-OPEN
//! (state `Degraded`): one more success promotes it to `Healthy`, one
//! failure sends it straight back to `Down` without burning the full
//! threshold again.  Scatter outcomes ([`Fleet::observe`]) feed the same
//! machine as probes, so a batch-visible failure counts as evidence
//! between probe ticks.  [`Fleet::route`] consults the machine: a
//! `Down` primary with a not-`Down` replica routes proactively to the
//! replica — the scatter never touches the primary, so a hung node
//! costs nothing per batch instead of one io-timeout each.
//!
//! # Event log
//!
//! `--event-log PATH` appends one JSON object per line:
//! `{"ts_ms": <monotonic ms since fleet start>, "seq": n, "event":
//! "node_up|node_down|failover|shed|timeout", "node": "host:port", ...}`.
//! Timestamps are monotonic (not wall-clock) so ordering survives NTP
//! steps; `seq` breaks ties within one millisecond.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::coordinator::{connect, NodeSpec, Topology};
use crate::telemetry::{self, federation, trace, Registry, TelemetryCtx};
use crate::util::json::{obj, Value};

/// Endpoint health as seen by the probe state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
    Down,
}

impl Health {
    pub fn as_str(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Down => "down",
        }
    }

    /// Numeric encoding for the `lorif_fleet_health_state` gauge.
    fn as_level(self) -> u64 {
        match self {
            Health::Healthy => 0,
            Health::Degraded => 1,
            Health::Down => 2,
        }
    }
}

/// Knobs for the monitor loops (`--probe-interval-ms` etc.).
#[derive(Clone, Debug)]
pub struct FleetOptions {
    pub probe_interval: Duration,
    /// connect/read timeout for ONE probe — deliberately much shorter
    /// than `--io-timeout-ms`, so a hung node is detected in probe time
    pub probe_timeout: Duration,
    pub scrape_interval: Duration,
    /// consecutive probe/scatter failures before `Degraded → Down`
    pub fail_threshold: u32,
    pub event_log: Option<PathBuf>,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            probe_interval: Duration::from_millis(1000),
            probe_timeout: Duration::from_millis(250),
            scrape_interval: Duration::from_millis(5000),
            fail_threshold: 3,
            event_log: None,
        }
    }
}

/// Mutable monitor state for one primary or replica endpoint.
struct Endpoint {
    addr: String,
    node: usize,
    is_replica: bool,
    health: Health,
    /// `Down` endpoint answered one probe; next observation decides
    half_open: bool,
    consecutive_failures: u32,
    failovers: u64,
    last_probe: Option<Instant>,
    last_scrape: Option<Instant>,
    last_scrape_ok: bool,
    scrape_duration_s: f64,
    exposition: Option<String>,
    /// queue depth + served count from the last good health reply
    probe_depth: Option<u64>,
    probe_served: Option<u64>,
    last_error: Option<String>,
}

impl Endpoint {
    fn new(addr: String, node: usize, is_replica: bool) -> Endpoint {
        Endpoint {
            addr,
            node,
            is_replica,
            health: Health::Healthy,
            half_open: false,
            consecutive_failures: 0,
            failovers: 0,
            last_probe: None,
            last_scrape: None,
            last_scrape_ok: false,
            scrape_duration_s: 0.0,
            exposition: None,
            probe_depth: None,
            probe_served: None,
            last_error: None,
        }
    }
}

/// One observation through the state machine.  Pure so the transition
/// table is unit-testable without sockets; returns the new
/// `(health, half_open, consecutive_failures)`.
fn step(
    health: Health,
    half_open: bool,
    fails: u32,
    ok: bool,
    threshold: u32,
) -> (Health, bool, u32) {
    if ok {
        match health {
            // a down endpoint answered: half-open trial, not yet healthy
            Health::Down => (Health::Degraded, true, 0),
            Health::Degraded | Health::Healthy => (Health::Healthy, false, 0),
        }
    } else {
        let fails = fails.saturating_add(1);
        if half_open {
            // failed its half-open trial: straight back down
            (Health::Down, false, fails)
        } else {
            match health {
                Health::Down => (Health::Down, false, fails),
                _ if fails >= threshold => (Health::Down, false, fails),
                _ => (Health::Degraded, false, fails),
            }
        }
    }
}

/// The shared fleet monitor (see module docs).
pub struct Fleet {
    topology: Topology,
    opts: FleetOptions,
    endpoints: Mutex<Vec<Endpoint>>,
    stop: AtomicBool,
    epoch: Instant,
    events: Option<Mutex<BufWriter<File>>>,
    seq: AtomicU64,
}

impl Fleet {
    pub fn new(topology: Topology, opts: FleetOptions) -> anyhow::Result<Arc<Fleet>> {
        let mut endpoints = Vec::new();
        for (i, node) in topology.nodes.iter().enumerate() {
            endpoints.push(Endpoint::new(node.addr.clone(), i, false));
            if let Some(r) = &node.replica {
                endpoints.push(Endpoint::new(r.clone(), i, true));
            }
        }
        let events = match &opts.event_log {
            Some(path) => {
                if let Some(dir) = path.parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                let f = File::create(path).map_err(|e| {
                    anyhow::anyhow!("--event-log {}: {e}", path.display())
                })?;
                Some(Mutex::new(BufWriter::new(f)))
            }
            None => None,
        };
        Ok(Arc::new(Fleet {
            topology,
            opts,
            endpoints: Mutex::new(endpoints),
            stop: AtomicBool::new(false),
            epoch: Instant::now(),
            events,
            seq: AtomicU64::new(0),
        }))
    }

    pub fn options(&self) -> &FleetOptions {
        &self.opts
    }

    /// Spawn the probe and scrape loops.  `ctx` is the SPAWNING scope's
    /// telemetry ctx, captured by the caller and re-installed inside
    /// each thread (the same pattern as `util::pool::run` and the
    /// reader prefetch thread), so probe/scrape metrics land in the
    /// server's scoped registry rather than the process-global one.
    pub fn start(self: &Arc<Self>, ctx: TelemetryCtx) -> Vec<JoinHandle<()>> {
        let probe = {
            let fleet = Arc::clone(self);
            let ctx = ctx.clone();
            std::thread::Builder::new()
                .name("lorif-fleet-probe".into())
                .spawn(move || {
                    telemetry::with_ctx(ctx, || {
                        // probe immediately so a dead node is detected
                        // within the first interval, not after it
                        while !fleet.stop.load(Ordering::Relaxed) {
                            fleet.probe_round();
                            fleet.sleep(fleet.opts.probe_interval);
                        }
                    })
                })
                .expect("spawn probe loop")
        };
        let scrape = {
            let fleet = Arc::clone(self);
            std::thread::Builder::new()
                .name("lorif-fleet-scrape".into())
                .spawn(move || {
                    telemetry::with_ctx(ctx, || {
                        while !fleet.stop.load(Ordering::Relaxed) {
                            fleet.scrape_round();
                            fleet.sleep(fleet.opts.scrape_interval);
                        }
                    })
                })
                .expect("spawn scrape loop")
        };
        vec![probe, scrape]
    }

    /// Signal the loops to exit (join the handles from [`Fleet::start`]
    /// afterwards).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Interruptible sleep: wakes within ~10ms of [`Fleet::stop`].
    fn sleep(&self, d: Duration) {
        let deadline = Instant::now() + d;
        while Instant::now() < deadline && !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(10).min(d));
        }
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    // -- routing + evidence (called from the scatter path) -------------

    /// Pick the endpoint a scatter leg should try FIRST: the primary,
    /// unless probes marked it `Down` and its replica is not — then the
    /// replica, flagged proactive.  A node whose endpoints are all down
    /// still returns the primary (the leg must try something; reactive
    /// failover remains as the backstop).
    pub fn route(&self, node: &NodeSpec) -> (String, bool) {
        let eps = self.endpoints.lock().unwrap();
        let primary_down = eps
            .iter()
            .find(|e| !e.is_replica && e.addr == node.addr)
            .map(|e| e.health == Health::Down)
            .unwrap_or(false);
        if primary_down {
            if let Some(replica) = &node.replica {
                let replica_down = eps
                    .iter()
                    .find(|e| e.is_replica && e.addr == *replica)
                    .map(|e| e.health == Health::Down)
                    .unwrap_or(false);
                if !replica_down {
                    return (replica.clone(), true);
                }
            }
        }
        (node.addr.clone(), false)
    }

    /// Feed one scatter attempt's outcome into the state machine (same
    /// transitions as a probe, without the probe counters).
    pub fn observe(&self, addr: &str, ok: bool) {
        self.apply(addr, ok, None);
    }

    /// Record a failover decision against the node's primary endpoint
    /// and log it (`proactive` = the replica was chosen before any
    /// attempt, off probe evidence alone).
    pub fn note_failover(&self, primary: &str, answered_by: &str, proactive: bool) {
        {
            let mut eps = self.endpoints.lock().unwrap();
            if let Some(ep) = eps.iter_mut().find(|e| !e.is_replica && e.addr == primary) {
                ep.failovers += 1;
            }
        }
        self.event(
            "failover",
            primary,
            vec![
                ("replica", answered_by.to_string().into()),
                ("proactive", proactive.into()),
            ],
        );
    }

    // -- state machine --------------------------------------------------

    /// Apply one observation to `addr`'s endpoint.  `error` doubles as
    /// the probe/scrape error detail kept for the stats verb.
    fn apply(&self, addr: &str, ok: bool, error: Option<String>) {
        let reg = telemetry::current_registry();
        let mut transition: Option<(Health, Health)> = None;
        {
            let mut eps = self.endpoints.lock().unwrap();
            let Some(ep) = eps.iter_mut().find(|e| e.addr == addr) else {
                return;
            };
            let from = ep.health;
            let (health, half_open, fails) = step(
                from,
                ep.half_open,
                ep.consecutive_failures,
                ok,
                self.opts.fail_threshold,
            );
            ep.health = health;
            ep.half_open = half_open;
            ep.consecutive_failures = fails;
            if !ok {
                ep.last_error = error;
            } else {
                ep.last_error = None;
            }
            if from != health {
                transition = Some((from, health));
            }
            self.publish_state_gauges(&reg, &eps);
        }
        if let Some((from, to)) = transition {
            reg.probe_transitions.inc();
            let kind = match to {
                Health::Down => Some("node_down"),
                Health::Healthy => Some("node_up"),
                Health::Degraded => None,
            };
            trace::instant(
                "health_transition",
                &[
                    ("from", Value::Str(from.as_str().into()).to_string()),
                    ("to", Value::Str(to.as_str().into()).to_string()),
                ],
            );
            log::info!("fleet: {addr} {} -> {}", from.as_str(), to.as_str());
            if let Some(kind) = kind {
                self.event(
                    kind,
                    addr,
                    vec![("from", from.as_str().to_string().into())],
                );
            }
        }
    }

    fn publish_state_gauges(&self, reg: &Registry, eps: &[Endpoint]) {
        let count = |h: Health| eps.iter().filter(|e| e.health == h).count() as u64;
        reg.fleet_nodes_healthy.set(count(Health::Healthy));
        reg.fleet_nodes_degraded.set(count(Health::Degraded));
        reg.fleet_nodes_down.set(count(Health::Down));
    }

    // -- probe loop -----------------------------------------------------

    fn probe_round(&self) {
        let reg = telemetry::current_registry();
        let mut sp = trace::span("probe_round");
        let addrs: Vec<String> = {
            let mut eps = self.endpoints.lock().unwrap();
            let now = Instant::now();
            for ep in eps.iter_mut() {
                ep.last_probe = Some(now);
            }
            eps.iter().map(|e| e.addr.clone()).collect()
        };
        let mut failures = 0usize;
        for addr in &addrs {
            reg.probe_attempts.inc();
            match self.exchange(addr, "health", self.opts.probe_timeout) {
                Ok((v, _)) if v.get("ok").and_then(Value::as_bool) == Some(true) => {
                    let depth = v.get("queue_depth").and_then(Value::as_usize);
                    let served = v.get("served").and_then(Value::as_usize);
                    {
                        let mut eps = self.endpoints.lock().unwrap();
                        if let Some(ep) = eps.iter_mut().find(|e| e.addr == *addr) {
                            ep.probe_depth = depth.map(|d| d as u64);
                            ep.probe_served = served.map(|s| s as u64);
                        }
                    }
                    self.apply(addr, true, None);
                }
                Ok(_) => {
                    reg.probe_failures.inc();
                    failures += 1;
                    self.apply(addr, false, Some("health verb answered not-ok".into()));
                }
                Err(e) => {
                    reg.probe_failures.inc();
                    failures += 1;
                    self.apply(addr, false, Some(format!("{e:#}")));
                }
            }
        }
        if let Some(sp) = sp.as_mut() {
            sp.arg("endpoints", addrs.len());
            sp.arg("failures", failures);
        }
    }

    // -- scrape loop ----------------------------------------------------

    fn scrape_round(&self) {
        let reg = telemetry::current_registry();
        let mut sp = trace::span("scrape_round");
        let addrs: Vec<String> = {
            let eps = self.endpoints.lock().unwrap();
            eps.iter().map(|e| e.addr.clone()).collect()
        };
        let mut errors = 0usize;
        for addr in &addrs {
            reg.fleet_scrapes.inc();
            let t0 = Instant::now();
            // scrapes reuse the probe timeout: a metrics page is small
            // and a slow scrape must never wedge the loop for a round
            let got = self.exchange(addr, "metrics", self.opts.probe_timeout);
            let dur = t0.elapsed().as_secs_f64();
            let mut eps = self.endpoints.lock().unwrap();
            let Some(ep) = eps.iter_mut().find(|e| e.addr == *addr) else { continue };
            ep.last_scrape = Some(Instant::now());
            ep.scrape_duration_s = dur;
            match got {
                Ok((v, _)) => match v.get("metrics").and_then(Value::as_str) {
                    Some(text) => {
                        ep.exposition = Some(text.to_string());
                        ep.last_scrape_ok = true;
                    }
                    None => {
                        reg.fleet_scrape_errors.inc();
                        errors += 1;
                        ep.last_scrape_ok = false;
                    }
                },
                Err(_) => {
                    reg.fleet_scrape_errors.inc();
                    errors += 1;
                    ep.last_scrape_ok = false;
                }
            }
        }
        if let Some(sp) = sp.as_mut() {
            sp.arg("endpoints", addrs.len());
            sp.arg("errors", errors);
        }
    }

    /// One `{"cmd": <verb>}` round trip with `timeout` on connect,
    /// write, and read.
    fn exchange(
        &self,
        addr: &str,
        verb: &str,
        timeout: Duration,
    ) -> anyhow::Result<(Value, Duration)> {
        let t0 = Instant::now();
        let stream = connect(addr, Some(timeout))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut stream = stream;
        writeln!(stream, "{}", obj([("cmd", verb.into())]))
            .map_err(|e| anyhow::anyhow!("{addr}: write: {e}"))?;
        stream.flush().map_err(|e| anyhow::anyhow!("{addr}: flush: {e}"))?;
        let mut line = String::new();
        let n = reader
            .read_line(&mut line)
            .map_err(|e| anyhow::anyhow!("{addr}: read: {e}"))?;
        anyhow::ensure!(n > 0, "{addr}: connection closed before reply");
        let v = Value::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("{addr}: unparseable reply: {e}"))?;
        Ok((v, t0.elapsed()))
    }

    // -- event log ------------------------------------------------------

    /// Append one structured JSONL event (no-op without `--event-log`).
    pub fn event(&self, kind: &str, node: &str, extra: Vec<(&'static str, Value)>) {
        let Some(events) = &self.events else { return };
        let mut fields: Vec<(&'static str, Value)> = vec![
            ("ts_ms", (self.now_ms() as usize).into()),
            ("seq", (self.seq.fetch_add(1, Ordering::Relaxed) as usize).into()),
            ("event", kind.to_string().into()),
            ("node", node.to_string().into()),
        ];
        fields.extend(extra);
        if let Ok(mut out) = events.lock() {
            let _ = writeln!(out, "{}", obj(fields));
            let _ = out.flush();
        }
    }

    // -- views ----------------------------------------------------------

    /// Per-endpoint health snapshot for the coordinator `stats` verb:
    /// state, consecutive failures, last probe/scrape age, failover
    /// count, and the last good health-reply numbers.
    pub fn health_json(&self) -> Value {
        let eps = self.endpoints.lock().unwrap();
        let age = |t: Option<Instant>| -> Value {
            match t {
                Some(t) => t.elapsed().as_secs_f64().into(),
                None => Value::Null,
            }
        };
        Value::Arr(
            eps.iter()
                .map(|ep| {
                    obj([
                        ("addr", ep.addr.clone().into()),
                        ("node", ep.node.into()),
                        (
                            "role",
                            if ep.is_replica { "replica" } else { "primary" }.into(),
                        ),
                        ("state", ep.health.as_str().into()),
                        ("half_open", ep.half_open.into()),
                        ("consecutive_failures", (ep.consecutive_failures as usize).into()),
                        ("failovers", (ep.failovers as usize).into()),
                        ("last_probe_age_s", age(ep.last_probe)),
                        ("last_scrape_age_s", age(ep.last_scrape)),
                        (
                            "queue_depth",
                            ep.probe_depth.map(|d| (d as usize).into()).unwrap_or(Value::Null),
                        ),
                        (
                            "served",
                            ep.probe_served.map(|s| (s as usize).into()).unwrap_or(Value::Null),
                        ),
                        (
                            "last_error",
                            ep.last_error.clone().map(Value::Str).unwrap_or(Value::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// The merged fleet exposition: the coordinator's own registry
    /// labeled `{role="coordinator"}`, every scraped member page
    /// relabeled `{node="host:port",role="node"}`, plus the synthesized
    /// per-endpoint fleet gauges.  One scrape of the coordinator shows
    /// the whole fleet.
    pub fn federate(&self, coord: &Registry) -> String {
        let own = coord.render_prometheus_with(&[("role", "coordinator")]);
        let eps = self.endpoints.lock().unwrap();
        let mut pages = vec![federation::Page::new(&[("role", "coordinator")], &own)];
        for ep in eps.iter() {
            if let Some(text) = &ep.exposition {
                pages.push(federation::Page {
                    labels: vec![
                        ("node".to_string(), ep.addr.clone()),
                        ("role".to_string(), "node".to_string()),
                    ],
                    text,
                });
            }
        }
        let mut out = federation::merge(&pages);
        // synthesized per-endpoint gauges (one family block each)
        let fam = |out: &mut String, name: &str, help: &str| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        };
        let lb = |ep: &Endpoint| {
            format!("{{node=\"{}\"}}", telemetry::escape_label_value(&ep.addr))
        };
        fam(
            &mut out,
            "lorif_fleet_up",
            "Whether the last scrape of this endpoint succeeded.",
        );
        for ep in eps.iter() {
            out.push_str(&format!(
                "lorif_fleet_up{} {}\n",
                lb(ep),
                if ep.last_scrape_ok { 1 } else { 0 }
            ));
        }
        fam(
            &mut out,
            "lorif_fleet_scrape_duration_seconds",
            "Duration of the last scrape of this endpoint.",
        );
        for ep in eps.iter() {
            out.push_str(&format!(
                "lorif_fleet_scrape_duration_seconds{} {:.6}\n",
                lb(ep),
                ep.scrape_duration_s
            ));
        }
        fam(
            &mut out,
            "lorif_fleet_scrape_age_seconds",
            "Seconds since this endpoint was last scraped.",
        );
        for ep in eps.iter() {
            let age = ep.last_scrape.map(|t| t.elapsed().as_secs_f64());
            out.push_str(&format!(
                "lorif_fleet_scrape_age_seconds{} {:.6}\n",
                lb(ep),
                age.unwrap_or(-1.0)
            ));
        }
        fam(
            &mut out,
            "lorif_fleet_health_state",
            "Probe state machine position (0=healthy, 1=degraded, 2=down).",
        );
        for ep in eps.iter() {
            out.push_str(&format!(
                "lorif_fleet_health_state{} {}\n",
                lb(ep),
                ep.health.as_level()
            ));
        }
        out
    }

    /// The topology this fleet monitors (shared with the planes).
    pub fn topology(&self) -> &Topology {
        &self.topology
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(threshold: u32, event_log: Option<PathBuf>) -> Arc<Fleet> {
        let topo = Topology::parse("p:1=0/r:1,q:2=1", Some(2)).unwrap();
        Fleet::new(
            topo,
            FleetOptions { fail_threshold: threshold, event_log, ..FleetOptions::default() },
        )
        .unwrap()
    }

    fn state_of(f: &Fleet, addr: &str) -> (String, bool, usize) {
        let v = f.health_json();
        let arr = v.as_arr().unwrap();
        let ep = arr
            .iter()
            .find(|e| e.get("addr").and_then(Value::as_str) == Some(addr))
            .unwrap();
        (
            ep.get("state").and_then(Value::as_str).unwrap().to_string(),
            ep.get("half_open").and_then(Value::as_bool).unwrap(),
            ep.get("consecutive_failures").and_then(Value::as_usize).unwrap(),
        )
    }

    /// The transition table: healthy → degraded on the first failure,
    /// → down at the threshold, half-open on the first success while
    /// down, healthy after the second, and straight back down on a
    /// failed half-open trial.
    #[test]
    fn state_machine_thresholds_and_half_open() {
        assert_eq!(
            step(Health::Healthy, false, 0, false, 3),
            (Health::Degraded, false, 1)
        );
        assert_eq!(
            step(Health::Degraded, false, 1, false, 3),
            (Health::Degraded, false, 2)
        );
        assert_eq!(step(Health::Degraded, false, 2, false, 3), (Health::Down, false, 3));
        // down stays down on more failures
        assert_eq!(step(Health::Down, false, 3, false, 3), (Health::Down, false, 4));
        // first success while down: half-open degraded
        assert_eq!(step(Health::Down, false, 4, true, 3), (Health::Degraded, true, 0));
        // half-open success: healthy
        assert_eq!(
            step(Health::Degraded, true, 0, true, 3),
            (Health::Healthy, false, 0)
        );
        // half-open FAILURE: straight back down, no threshold grace
        assert_eq!(step(Health::Degraded, true, 0, false, 3), (Health::Down, false, 1));
        // a plain degraded endpoint recovers in one success
        assert_eq!(
            step(Health::Degraded, false, 1, true, 3),
            (Health::Healthy, false, 0)
        );
        // threshold 1: first failure goes straight down
        assert_eq!(step(Health::Healthy, false, 0, false, 1), (Health::Down, false, 1));
    }

    #[test]
    fn observe_drives_states_and_routing() {
        let f = fleet(2, None);
        let node = f.topology().nodes[0].clone();
        // healthy primary routes to itself
        assert_eq!(f.route(&node), ("p:1".to_string(), false));
        f.observe("p:1", false);
        assert_eq!(state_of(&f, "p:1").0, "degraded");
        // degraded still routes to the primary (only Down reroutes)
        assert_eq!(f.route(&node), ("p:1".to_string(), false));
        f.observe("p:1", false);
        assert_eq!(state_of(&f, "p:1").0, "down");
        // down primary + live replica: proactive reroute
        assert_eq!(f.route(&node), ("r:1".to_string(), true));
        // replica down too: fall back to trying the primary
        f.observe("r:1", false);
        f.observe("r:1", false);
        assert_eq!(f.route(&node), ("p:1".to_string(), false));
        // primary recovers through half-open
        f.observe("p:1", true);
        let (state, half_open, fails) = state_of(&f, "p:1");
        assert_eq!((state.as_str(), half_open, fails), ("degraded", true, 0));
        f.observe("p:1", true);
        assert_eq!(state_of(&f, "p:1").0, "healthy");
        // a node with no replica entry never reroutes
        let lone = f.topology().nodes[1].clone();
        f.observe("q:2", false);
        f.observe("q:2", false);
        assert_eq!(f.route(&lone), ("q:2".to_string(), false));
    }

    /// Transitions and failovers land in the JSONL event log with
    /// monotone timestamps and the documented schema.
    #[test]
    fn event_log_records_transitions_and_failovers() {
        let dir = std::env::temp_dir().join(format!("lorif-fleet-ev-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let f = fleet(2, Some(path.clone()));
        f.observe("p:1", false);
        f.observe("p:1", false); // -> down  => node_down
        f.note_failover("p:1", "r:1", true); // => failover
        f.observe("p:1", true); // -> half-open degraded (no event)
        f.observe("p:1", true); // -> healthy => node_up
        f.event("shed", "client", vec![("queue_depth", 9.into())]);

        let text = std::fs::read_to_string(&path).unwrap();
        let events: Vec<Value> =
            text.lines().map(|l| Value::parse(l).expect("jsonl line parses")).collect();
        assert_eq!(events.len(), 4);
        let kinds: Vec<&str> =
            events.iter().map(|e| e.get("event").and_then(Value::as_str).unwrap()).collect();
        assert_eq!(kinds, vec!["node_down", "failover", "node_up", "shed"]);
        // schema: every event has monotone ts_ms + seq + node
        let mut prev = (0.0, -1.0);
        for e in &events {
            let ts = e.get("ts_ms").and_then(Value::as_f64).unwrap();
            let seq = e.get("seq").and_then(Value::as_f64).unwrap();
            assert!(e.get("node").and_then(Value::as_str).is_some());
            assert!(ts >= prev.0, "ts_ms must be monotone");
            assert!(seq > prev.1, "seq must strictly increase");
            prev = (ts, seq);
        }
        assert_eq!(
            events[1].get("proactive").and_then(Value::as_bool),
            Some(true)
        );
        assert_eq!(events[1].get("replica").and_then(Value::as_str), Some("r:1"));
        assert_eq!(events[3].get("queue_depth").and_then(Value::as_f64), Some(9.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// State gauges land in the SCOPED registry installed at observe
    /// time (the ctx-capture contract the serving loop relies on).
    #[test]
    fn state_gauges_publish_into_the_scoped_registry() {
        let f = fleet(1, None);
        let reg = Arc::new(Registry::new());
        telemetry::with_registry(reg.clone(), || {
            f.observe("p:1", false); // threshold 1: down immediately
        });
        assert_eq!(reg.fleet_nodes_down.get(), 1);
        assert_eq!(reg.fleet_nodes_healthy.get(), 2);
        assert_eq!(reg.probe_transitions.get(), 1);
    }

    /// `federate` with no scrapes yet still yields a valid page: the
    /// coordinator's own labeled series plus the synthesized fleet
    /// gauges for every endpoint.
    #[test]
    fn federate_renders_own_page_and_synthesized_gauges() {
        let f = fleet(3, None);
        let reg = Registry::new();
        reg.server_served.add(4);
        let page = f.federate(&reg);
        assert!(page.contains("lorif_server_served_total{role=\"coordinator\"} 4\n"));
        assert!(page.contains("# TYPE lorif_fleet_up gauge\n"));
        for addr in ["p:1", "r:1", "q:2"] {
            assert!(
                page.contains(&format!("lorif_fleet_up{{node=\"{addr}\"}} 0\n")),
                "missing up sample for {addr}"
            );
            assert!(page.contains(&format!("lorif_fleet_health_state{{node=\"{addr}\"}} 0\n")));
        }
        // never-scraped endpoints report age -1
        assert!(page.contains("lorif_fleet_scrape_age_seconds{node=\"p:1\"} -1.000000\n"));
    }
}

//! Parallel shard-scoring primitives.
//!
//! The paper's Figure 3 shows query latency dominated by streaming the
//! gradient store; a single reader thread leaves every other core idle.
//! This module provides the worker-pool fan-out (`map_shards`) and the
//! merge half of the story: per-shard score column blocks merged into
//! the global matrix (`merge_scores`), and per-shard bounded top-k
//! heaps merged into global per-query heaps (`merge_topk`).  The
//! streaming pass itself lives in `attribution::exec` — the single
//! `map_shards` call site shared by every store scorer.
//!
//! The bounded `TopK` accumulator is provably equal to a stable
//! descending sort of the full score row under `f32::total_cmp` (see
//! `tests/prop.rs`), including on NaN scores.

use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

use crate::linalg::Mat;
use crate::store::{ShardSet, StoreReader};
use crate::util::pool;
use crate::util::timer::PhaseTimer;

/// Cross-shard streaming top-k threshold: one atomic cell per query
/// holding the best (highest) k-th-best score ANY shard worker has
/// published so far.  Without it each shard prunes against only its own
/// heap, so pruning is weakest exactly when sharding is widest; with
/// it, the first heap to fill tightens every other shard's skip test.
///
/// Soundness for the MERGED output: the merged top-k of the union
/// contains k entries each scoring at least any single shard's current
/// k-th best `t` (that shard alone already holds k such entries, and
/// its threshold only rises as the scan proceeds).  An example pruned
/// under the executor's STRICT test (`bound < t`) therefore scores
/// strictly below k merged entries and cannot appear in the merged
/// top-k under any tie-break — even if the pruning shard's own heap
/// never fills.
///
/// Scores are stored as monotonically encoded bits (the sign-flip
/// transform of IEEE-754 totalOrder, matching `f32::total_cmp`), so
/// `fetch_max` on the `u32` is `max` under the score order and the
/// whole structure is a single lock-free word per query.  Workers only
/// publish FINITE thresholds: a NaN threshold (all-NaN heap) encodes
/// above +inf and would poison every other shard into never skipping
/// below it — and non-finite chunks are unprunable anyway.
pub struct SharedThreshold {
    cells: Vec<AtomicU32>,
}

/// Monotone encoding: `a.total_cmp(&b) == key(a).cmp(&key(b))`.
fn key(f: f32) -> u32 {
    let b = f.to_bits();
    if b & 0x8000_0000 != 0 {
        !b
    } else {
        b | 0x8000_0000
    }
}

fn unkey(k: u32) -> f32 {
    if k & 0x8000_0000 != 0 {
        f32::from_bits(k & 0x7fff_ffff)
    } else {
        f32::from_bits(!k)
    }
}

impl SharedThreshold {
    /// One empty cell per query.  Cell value 0 encodes the bottom of
    /// the total order (negative NaN), which no finite publication can
    /// produce — it doubles as the "nothing published yet" state.
    pub fn new(nq: usize) -> SharedThreshold {
        SharedThreshold { cells: (0..nq).map(|_| AtomicU32::new(0)).collect() }
    }

    /// Raise query `q`'s published threshold to `t` (no-op if a higher
    /// one is already posted, or if `t` is not finite).
    pub fn publish(&self, q: usize, t: f32) {
        if t.is_finite() {
            self.cells[q].fetch_max(key(t), Ordering::Relaxed);
        }
    }

    /// The best threshold published for query `q` so far, if any.
    pub fn get(&self, q: usize) -> Option<f32> {
        let raw = self.cells[q].load(Ordering::Relaxed);
        (raw != 0).then(|| unkey(raw))
    }
}

/// Per-shard partial result of a scorer's streaming pass.
pub struct ShardScores {
    /// global index of the shard's first example (column offset)
    pub start: usize,
    /// (n_query, shard_count) score columns
    pub scores: Mat,
    /// disk read + decode time for this shard
    pub io: Duration,
    /// scoring compute time for this shard
    pub compute: Duration,
    pub bytes: u64,
}

/// Run `f` once per shard on the worker pool (threads = 0 means all
/// cores), returning results in shard order.
pub fn map_shards<T, F>(set: &ShardSet, threads: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize, StoreReader) -> anyhow::Result<T> + Sync,
{
    pool::run(threads, set.n_shards(), |i| f(i, set.reader(i)))
}

/// Merge per-shard score columns and timings into the global score
/// matrix.  Phase times SUM across shards (CPU time, matching how the
/// sequential path accounts a full pass), as does `bytes`.
pub fn merge_scores(nq: usize, n_total: usize, parts: Vec<ShardScores>) -> (Mat, PhaseTimer, u64) {
    let mut sp = crate::telemetry::trace::span("merge_scores");
    if let Some(s) = sp.as_mut() {
        s.arg("shards", parts.len());
    }
    let mut scores = Mat::zeros(nq, n_total);
    let mut io = Duration::ZERO;
    let mut compute = Duration::ZERO;
    let mut bytes = 0u64;
    for p in parts {
        debug_assert_eq!(p.scores.rows, nq);
        for q in 0..nq {
            let cols = p.scores.cols;
            scores.row_mut(q)[p.start..p.start + cols].copy_from_slice(p.scores.row(q));
        }
        io += p.io;
        compute += p.compute;
        bytes += p.bytes;
    }
    let mut timer = PhaseTimer::new();
    timer.add("load", io);
    timer.add("compute", compute);
    (scores, timer, bytes)
}

/// Bounded top-k accumulator over (index, score) pairs.
///
/// Keeps the `k` highest-scoring entries, ordered descending by score
/// with ties broken toward the LOWER index — exactly the order a stable
/// descending sort of the full score row produces, so merged per-shard
/// accumulators reproduce the global top-k bit-for-bit.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// sorted: descending score, ascending index on ties
    entries: Vec<(f32, usize)>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, entries: Vec::with_capacity(k.min(1024) + 1) }
    }

    pub fn push(&mut self, index: usize, score: f32) {
        if self.k == 0 {
            return;
        }
        // `total_cmp` gives NaN a defined place in the order (above
        // +inf for positive NaN, below -inf for negative) instead of
        // panicking mid-stream, and matches the argsort path
        // (`ScoreReport::topk`) bit for bit.
        let pos = self.entries.partition_point(|&(s, i)| {
            match s.total_cmp(&score) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => i < index,
                std::cmp::Ordering::Less => false,
            }
        });
        if pos >= self.k {
            return;
        }
        self.entries.insert(pos, (score, index));
        self.entries.truncate(self.k);
    }

    /// Fold another accumulator's entries into this one.
    pub fn merge(&mut self, other: &TopK) {
        for &(s, i) in &other.entries {
            self.push(i, s);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The heap's budget (the `k` it was created with).
    pub fn k(&self) -> usize {
        self.k
    }

    /// The score a NEW candidate (at a HIGHER index than everything
    /// already pushed) must exceed to enter: the current k-th best once
    /// the heap is full, `None` while it still has room.  This is the
    /// pruning threshold of the streaming top-k sink — sound because
    /// ties break toward the lower index, so an equal-scoring later
    /// example cannot displace an entry.  A `k = 0` heap accepts
    /// nothing and its threshold is +inf.
    pub fn threshold(&self) -> Option<f32> {
        if self.k == 0 {
            return Some(f32::INFINITY);
        }
        (self.entries.len() == self.k).then(|| self.entries[self.k - 1].0)
    }

    /// The accumulated `(score, index)` entries, best first.
    pub fn entries(&self) -> &[(f32, usize)] {
        &self.entries
    }

    /// The accumulated indices, best first.
    pub fn into_indices(self) -> Vec<usize> {
        self.entries.into_iter().map(|(_, i)| i).collect()
    }
}

/// Merge per-shard heap vectors (one `Vec<TopK>` of length `nq` per
/// shard) into the global per-query heaps — the reduction step of the
/// streaming top-k sink.
pub fn merge_topk(nq: usize, k: usize, parts: Vec<Vec<TopK>>) -> Vec<TopK> {
    let mut sp = crate::telemetry::trace::span("merge_topk");
    if let Some(s) = sp.as_mut() {
        s.arg("shards", parts.len());
    }
    let mut merged: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
    for part in &parts {
        debug_assert_eq!(part.len(), nq);
        for (m, h) in merged.iter_mut().zip(part) {
            m.merge(h);
        }
    }
    merged
}

/// Top-k training indices per query, computed by splitting the score
/// columns into per-worker blocks, building block-local accumulators in
/// parallel, and merging — the same merge the sharded scorers rely on.
/// Equivalent to `ScoreReport::topk` (a stable descending argsort).
pub fn topk(scores: &Mat, k: usize, threads: usize) -> Vec<Vec<usize>> {
    let nq = scores.rows;
    let n = scores.cols;
    let k = k.min(n);
    if nq == 0 || n == 0 || k == 0 {
        return vec![Vec::new(); nq];
    }
    let workers = pool::effective_threads(threads).min(n).max(1);
    let block = (n + workers - 1) / workers;
    let parts: Vec<Vec<TopK>> = pool::run(threads, workers, |b| {
        let lo = b * block;
        let hi = (lo + block).min(n);
        let mut local: Vec<TopK> = (0..nq).map(|_| TopK::new(k)).collect();
        for q in 0..nq {
            let row = scores.row(q);
            let acc = &mut local[q];
            for t in lo..hi {
                acc.push(t, row[t]);
            }
        }
        Ok(local)
    })
    .expect("topk blocks are infallible");
    merge_topk(nq, k, parts).into_iter().map(TopK::into_indices).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::ScoreReport;
    use crate::util::prng::Rng;

    #[test]
    fn topk_accumulator_keeps_best_sorted() {
        let mut acc = TopK::new(3);
        for (i, s) in [(0, 1.0f32), (1, 5.0), (2, -2.0), (3, 5.0), (4, 3.0)] {
            acc.push(i, s);
        }
        // ties (1 and 3 at 5.0) resolve toward the lower index
        assert_eq!(acc.into_indices(), vec![1, 3, 4]);
    }

    #[test]
    fn topk_merge_equals_single_pass() {
        let mut rng = Rng::new(3);
        let scores: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
        let mut whole = TopK::new(7);
        for (i, &s) in scores.iter().enumerate() {
            whole.push(i, s);
        }
        let mut left = TopK::new(7);
        let mut right = TopK::new(7);
        for (i, &s) in scores.iter().enumerate() {
            if i < 40 {
                left.push(i, s);
            } else {
                right.push(i, s);
            }
        }
        left.merge(&right);
        assert_eq!(left.into_indices(), whole.into_indices());
    }

    #[test]
    fn parallel_topk_matches_report_argsort() {
        let mut rng = Rng::new(11);
        let scores = Mat::random_normal(4, 333, 1.0, &mut rng);
        let rep = ScoreReport::full(scores.clone(), Default::default(), 0);
        let want = rep.topk(10);
        for threads in [1, 2, 5] {
            assert_eq!(topk(&scores, 10, threads), want, "threads = {threads}");
        }
    }

    #[test]
    fn topk_survives_nan_scores() {
        // regression: both selection paths used partial_cmp().unwrap()
        // and panicked on a single corrupted score.  With total_cmp a
        // positive NaN ranks above +inf, a negative below -inf, and the
        // heap path agrees with the argsort path exactly.
        let mut scores = Mat::from_vec(1, 6, vec![0.5, f32::NAN, -1.0, 2.0, -f32::NAN, 1.0]);
        let rep = ScoreReport::full(scores.clone(), Default::default(), 0);
        let want = rep.topk(4);
        assert_eq!(want[0], vec![1, 3, 5, 0], "positive NaN first, -NaN last");
        for threads in [1, 3] {
            assert_eq!(topk(&scores, 4, threads), want);
        }
        // all-NaN row still selects without panicking
        for x in scores.row_mut(0) {
            *x = f32::NAN;
        }
        assert_eq!(topk(&scores, 3, 2)[0], vec![0, 1, 2]);
    }

    #[test]
    fn merge_topk_across_shards_equals_single_heap() {
        let mut rng = Rng::new(21);
        let vals: Vec<f32> = (0..90).map(|_| rng.normal() as f32).collect();
        let mut whole = TopK::new(6);
        for (i, &s) in vals.iter().enumerate() {
            whole.push(i, s);
        }
        // three "shards" of 30 columns each
        let parts: Vec<Vec<TopK>> = (0..3)
            .map(|p| {
                let mut h = TopK::new(6);
                for (i, &s) in vals.iter().enumerate().skip(p * 30).take(30) {
                    h.push(i, s);
                }
                vec![h]
            })
            .collect();
        let merged = merge_topk(1, 6, parts);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[0].entries(), whole.entries());
    }

    #[test]
    fn topk_edge_cases() {
        let m = Mat::zeros(2, 0);
        assert_eq!(topk(&m, 5, 2), vec![Vec::<usize>::new(), Vec::new()]);
        let mut rng = Rng::new(1);
        let m = Mat::random_normal(1, 5, 1.0, &mut rng);
        // k larger than n clamps
        assert_eq!(topk(&m, 50, 3)[0].len(), 5);
        assert_eq!(topk(&m, 0, 3), vec![Vec::<usize>::new()]);
    }

    #[test]
    fn shared_threshold_encoding_is_monotone_and_roundtrips() {
        let vals = [
            f32::NEG_INFINITY,
            -1.0e30,
            -2.5,
            -0.0,
            0.0,
            1.0e-30,
            3.25,
            1.0e30,
            f32::INFINITY,
        ];
        for w in vals.windows(2) {
            assert!(key(w[0]) <= key(w[1]), "{} vs {}", w[0], w[1]);
        }
        for &v in &vals {
            assert_eq!(unkey(key(v)).total_cmp(&v), std::cmp::Ordering::Equal, "{v}");
        }
    }

    #[test]
    fn shared_threshold_keeps_the_max_and_ignores_non_finite() {
        let st = SharedThreshold::new(2);
        assert_eq!(st.get(0), None);
        st.publish(0, -3.0);
        assert_eq!(st.get(0), Some(-3.0));
        st.publish(0, 1.5);
        st.publish(0, 0.25); // lower: ignored
        assert_eq!(st.get(0), Some(1.5));
        // NaN/inf never poison the cell
        st.publish(0, f32::NAN);
        st.publish(0, f32::INFINITY);
        assert_eq!(st.get(0), Some(1.5));
        // per-query isolation
        assert_eq!(st.get(1), None);
    }

    #[test]
    fn merge_scores_places_columns_and_sums_latency() {
        let mk = |start: usize, cols: usize, fill: f32| ShardScores {
            start,
            scores: Mat::from_vec(2, cols, vec![fill; 2 * cols]),
            io: Duration::from_millis(10),
            compute: Duration::from_millis(5),
            bytes: 100,
        };
        let (scores, timer, bytes) =
            merge_scores(2, 7, vec![mk(0, 3, 1.0), mk(3, 2, 2.0), mk(5, 2, 3.0)]);
        assert_eq!(scores.row(0), &[1.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        assert_eq!(scores.row(1), &[1.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0]);
        assert_eq!(timer.get("load"), Duration::from_millis(30));
        assert_eq!(timer.get("compute"), Duration::from_millis(15));
        assert_eq!(bytes, 300);
    }
}

//! Scatter-gather coordinator: the remote half of the shard plane.
//!
//! A shard NODE is just the existing attribution server started over a
//! subset-opened store (`ShardSet::open_subset`, `lorif serve --node
//! --node-shards lo-hi`): because subset spans keep their GLOBAL start
//! offsets, every heap entry a node returns already carries the
//! original example index.  The COORDINATOR (`lorif serve --coordinator
//! --nodes ...`) runs the same server pipeline with a [`RemotePlane`]:
//! each admitted batch's validated token rows are forwarded — NOT
//! gradients; each node re-extracts deterministically, so nothing lossy
//! crosses the wire — to every node in parallel, the per-node top-k
//! heaps are rebuilt from the replies' `topk_bits` (exact f32 bit
//! patterns), and `query::parallel::merge_topk` folds them with the
//! same descending-score / ascending-index tie-break the local executor
//! uses.
//!
//! **Exactness.** A local pass computes per-shard heaps and merges them
//! once.  The distributed pass merges each node's shard heaps on the
//! node, then merges the node heaps here — a two-level application of
//! the same associative reduction (property-tested in `tests/prop.rs`),
//! over the same per-shard inputs (deterministic extraction, global
//! coordinates, exact prune mode).  Distributed ≡ local, bit for bit.
//!
//! **Failover.** Each node may declare a replica serving the same shard
//! subset.  A scatter leg that fails (connect refused, io timeout, bad
//! reply) is retried once against the replica; only if both fail does
//! the batch fail.  Retries and failovers are counted in the
//! `lorif_coord_*` families and surfaced per node in the reply's
//! `"nodes"` array.  With a [`Fleet`] monitor attached (`query::fleet`),
//! routing becomes PROACTIVE: a primary the health probes already
//! marked down is skipped entirely and its replica queried first
//! (`lorif_coord_reroute_total`, `NodeStat::proactive`), so a hung
//! primary costs nothing per batch instead of one `--io-timeout-ms`
//! penalty each; scatter outcomes feed back into the fleet's health
//! state machine and JSONL event log.
//!
//! **Traces.** Each scatter leg forwards the coordinator query's trace
//! ID over the line protocol (`"trace"` field), so the node-side
//! `server_batch` span tree lands under the same `trace_id` as the
//! coordinator's `scatter` span in a merged Perfetto timeline.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::engine::LatencyBreakdown;
use super::fleet::Fleet;
use super::parallel::{merge_topk, TopK};
use super::plane::{NodeStat, PlaneBatch, PlaneReply, ShardPlane};
use super::server::GradSource;
use crate::attribution::QueryGrads;
use crate::telemetry;
use crate::util::json::{obj, Value};

/// One shard node: the address that serves `shards`, plus an optional
/// replica serving the same subset.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub addr: String,
    /// manifest shard indices this node serves (sorted, deduplicated)
    pub shards: Vec<usize>,
    pub replica: Option<String>,
}

/// A validated cluster layout: every shard in `[0, total_shards)` owned
/// by exactly one node.
#[derive(Clone, Debug)]
pub struct Topology {
    pub nodes: Vec<NodeSpec>,
    pub total_shards: usize,
}

impl Topology {
    /// Parse and validate a `--nodes` spec:
    /// `addr=shards[/replica],addr=shards,...` where `shards` is
    /// `+`-joined terms, each a single index (`3`) or an inclusive
    /// range (`0-2`).  E.g.
    /// `127.0.0.1:7001=0-2/127.0.0.1:7101,127.0.0.1:7002=3+5`.
    ///
    /// Validation happens HERE, at startup, not on the first query:
    /// duplicate shard ownership, shards outside `[0, total_shards)`,
    /// uncovered shards, and `replica == primary` are all clean errors.
    /// `total_shards = None` infers the total as `max listed + 1`
    /// (interior gaps are still rejected).
    pub fn parse(spec: &str, total_shards: Option<usize>) -> anyhow::Result<Topology> {
        let mut nodes: Vec<NodeSpec> = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((addr, rest)) = part.split_once('=') else {
                anyhow::bail!("node spec '{part}' is missing '=<shards>'");
            };
            let addr = addr.trim();
            anyhow::ensure!(!addr.is_empty(), "node spec '{part}' has an empty address");
            let (shard_spec, replica) = match rest.split_once('/') {
                Some((s, r)) => {
                    let r = r.trim();
                    anyhow::ensure!(
                        !r.is_empty(),
                        "node {addr}: empty replica after '/'"
                    );
                    anyhow::ensure!(
                        r != addr,
                        "node {addr}: replica must differ from the primary"
                    );
                    (s, Some(r.to_string()))
                }
                None => (rest, None),
            };
            let shards = parse_shard_list(shard_spec)
                .map_err(|e| anyhow::anyhow!("node {addr}: {e}"))?;
            nodes.push(NodeSpec { addr: addr.to_string(), shards, replica });
        }
        anyhow::ensure!(!nodes.is_empty(), "--nodes names no nodes");

        // exactly-once ownership over [0, total)
        let total = match total_shards {
            Some(t) => t,
            None => 1 + nodes.iter().flat_map(|n| &n.shards).copied().max().unwrap(),
        };
        let mut owner: Vec<Option<&str>> = vec![None; total];
        for n in &nodes {
            for &s in &n.shards {
                anyhow::ensure!(
                    s < total,
                    "node {} claims shard {s}, but the store has {total} shards",
                    n.addr
                );
                if let Some(prev) = owner[s] {
                    anyhow::bail!(
                        "shard {s} is owned by both {prev} and {} — every shard \
                         must have exactly one primary",
                        n.addr
                    );
                }
                owner[s] = Some(&n.addr);
            }
        }
        let uncovered: Vec<usize> = (0..total).filter(|&s| owner[s].is_none()).collect();
        anyhow::ensure!(
            uncovered.is_empty(),
            "shards {uncovered:?} are not served by any node (store has {total} shards)"
        );
        Ok(Topology { nodes, total_shards: total })
    }
}

/// Parse a `+`-joined shard list: each term is a single manifest index
/// (`3`) or an inclusive range (`0-2`), so `0-2+5` → `[0, 1, 2, 5]`.
/// Sorted and deduplicated.  This is the shared grammar of a node's
/// `--node-shards` flag and each `--nodes` entry.
pub fn parse_shard_list(spec: &str) -> anyhow::Result<Vec<usize>> {
    let mut shards = Vec::new();
    for term in spec.split('+') {
        let term = term.trim();
        match term.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad shard range '{term}'"))?;
                let hi: usize = hi
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad shard range '{term}'"))?;
                anyhow::ensure!(lo <= hi, "empty shard range '{term}'");
                shards.extend(lo..=hi);
            }
            None => shards
                .push(term.parse().map_err(|_| anyhow::anyhow!("bad shard index '{term}'"))?),
        }
    }
    anyhow::ensure!(!shards.is_empty(), "empty shard list");
    shards.sort_unstable();
    shards.dedup();
    Ok(shards)
}

/// A `GradSource` for coordinator mode: it knows the vocabulary and
/// context length (so admission validates tokens exactly as a node
/// will), but never extracts — the `RemotePlane` forwards raw tokens,
/// so the coordinator needs no model runtime and builds pure-CPU.
pub struct TokenSource {
    pub vocab: usize,
    pub seq_len: usize,
}

impl GradSource for TokenSource {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn extract(&mut self, _tokens: &[i32], _n: usize) -> anyhow::Result<QueryGrads> {
        anyhow::bail!("coordinator mode forwards tokens; local extraction is never run")
    }
}

/// The network plane: scatter each batch's token rows to every node,
/// gather and merge their heaps.  One instance per scoring worker; each
/// scatter opens fresh connections (nodes may come and go between
/// batches — that is what failover is for).
pub struct RemotePlane {
    pub topology: Topology,
    /// connect/read/write timeout for each node leg (`--io-timeout-ms`;
    /// `None` = block forever, which disables timeout-driven failover)
    pub io_timeout: Option<Duration>,
    /// health monitor shared with the serving loop: routes scatters
    /// around probe-down primaries and receives scatter-outcome
    /// evidence (`None` = reactive-only failover, the pre-fleet path)
    pub fleet: Option<Arc<Fleet>>,
}

/// One node's gathered answer.
struct NodeAnswer {
    heaps: Vec<TopK>,
    breakdown: LatencyBreakdown,
    stat: NodeStat,
}

impl ShardPlane for RemotePlane {
    fn name(&self) -> &'static str {
        "remote"
    }

    fn wants_grads(&self) -> bool {
        false
    }

    fn score_topk(&mut self, batch: &PlaneBatch, k: usize) -> anyhow::Result<PlaneReply> {
        let PlaneBatch::Tokens { tokens, n, seq_len } = batch else {
            anyhow::bail!("remote plane forwards tokens; got extracted gradients");
        };
        let (n, seq_len) = (*n, *seq_len);
        anyhow::ensure!(n > 0 && tokens.len() == n * seq_len, "malformed token batch");
        let t0 = Instant::now();
        // capture the FULL telemetry ctx HERE: the scatter legs run on
        // fresh threads, where the thread-local scope would otherwise
        // fall back to the process-global registry — and the trace ID
        // must ride along so each leg's span (and the trace ID the leg
        // forwards to its node) stays attached to this query
        let ctx = telemetry::current_ctx();
        let timeout = self.io_timeout;
        let fleet = self.fleet.clone();
        let answers: Vec<anyhow::Result<NodeAnswer>> = {
            let mut sp = telemetry::trace::span("scatter");
            if let Some(sp) = sp.as_mut() {
                sp.arg("nodes", self.topology.nodes.len());
                sp.arg("queries", n);
            }
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .topology
                    .nodes
                    .iter()
                    .enumerate()
                    .map(|(i, node)| {
                        let ctx = ctx.clone();
                        let fleet = fleet.as_deref();
                        s.spawn(move || {
                            telemetry::with_ctx(ctx, || {
                                query_node(node, i, tokens, n, seq_len, timeout, fleet)
                            })
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        h.join().unwrap_or_else(|_| {
                            Err(anyhow::anyhow!("scatter thread panicked"))
                        })
                    })
                    .collect()
            })
        };

        let mut gsp = telemetry::trace::span("gather_merge");
        let mut parts = Vec::with_capacity(answers.len());
        let mut breakdowns = Vec::with_capacity(answers.len());
        let mut nodes = Vec::with_capacity(answers.len());
        for a in answers {
            let a = a?;
            parts.push(a.heaps);
            breakdowns.push(a.breakdown);
            nodes.push(a.stat);
        }
        if let Some(sp) = gsp.as_mut() {
            sp.arg("heaps", parts.len());
        }
        let topk = merge_topk(n, k, parts);
        drop(gsp);
        // coordinator overhead = everything the slowest node's own wall
        // doesn't explain: scatter fan-out, network, rebuild, merge
        let slowest = breakdowns.iter().fold(0.0f64, |m, b| m.max(b.wall_s));
        let overhead = (t0.elapsed().as_secs_f64() - slowest).max(0.0);
        let latency = LatencyBreakdown::merge_distributed(&breakdowns, overhead);
        Ok(PlaneReply { topk, latency, nodes })
    }
}

/// Run one node's scatter leg.  Without a fleet: primary first, then
/// (on any failure) its replica.  With a fleet: [`Fleet::route`] may
/// send the leg straight to the replica of a probe-down primary
/// (proactive reroute — no io-timeout paid), with the primary as the
/// fall-back.  Counts `lorif_coord_scatter/gather/retry/failover/
/// reroute` and reports every attempt's outcome to the fleet.
fn query_node(
    node: &NodeSpec,
    node_idx: usize,
    tokens: &[i32],
    n: usize,
    seq_len: usize,
    timeout: Option<Duration>,
    fleet: Option<&Fleet>,
) -> anyhow::Result<NodeAnswer> {
    let t0 = Instant::now();
    let reg = telemetry::current_registry();
    let trace_id = telemetry::current_ctx().trace.id;
    let (first, proactive) = match fleet {
        Some(f) => f.route(node),
        None => (node.addr.clone(), false),
    };
    let mut sp = telemetry::trace::span_on("scatter_node", 1 + node_idx as u32);
    if let Some(sp) = sp.as_mut() {
        sp.arg_str("addr", &first);
        sp.arg("proactive", proactive);
        sp.arg("queries", n);
    }
    reg.coord_scatter.inc();
    if proactive {
        reg.coord_reroute.inc();
    }
    match talk(&first, tokens, n, seq_len, timeout, trace_id) {
        Ok((heaps, breakdown)) => {
            reg.coord_gather.inc();
            if let Some(f) = fleet {
                f.observe(&first, true);
                if proactive {
                    f.note_failover(&node.addr, &first, true);
                }
            }
            if proactive {
                reg.coord_failover.inc();
            }
            let stat = NodeStat {
                addr: first,
                shards: node.shards.clone(),
                wall_s: t0.elapsed().as_secs_f64(),
                retries: 0,
                failover: proactive,
                proactive,
            };
            Ok(NodeAnswer { heaps, breakdown, stat })
        }
        Err(first_err) => {
            let timed_out = format!("{first_err:#}").contains("timed out");
            if let Some(f) = fleet {
                f.observe(&first, false);
                if timed_out {
                    f.event("timeout", &first, vec![]);
                }
            }
            // the alternate endpoint: normally the replica; the primary
            // itself when the proactive route already chose the replica
            let alt = if proactive { Some(node.addr.clone()) } else { node.replica.clone() };
            let Some(alt) = alt else {
                return Err(first_err
                    .context(format!("node {} failed (no replica configured)", node.addr)));
            };
            log::warn!(
                "node {}: endpoint {first} failed ({first_err:#}); retrying its \
                 shards on {alt}",
                node.addr
            );
            reg.coord_retry.inc();
            reg.coord_scatter.inc();
            match talk(&alt, tokens, n, seq_len, timeout, trace_id) {
                Ok((heaps, breakdown)) => {
                    reg.coord_gather.inc();
                    if let Some(f) = fleet {
                        f.observe(&alt, true);
                    }
                    // answered by the replica after the primary failed =
                    // classic reactive failover; answered by the PRIMARY
                    // after a proactive reroute bounced is a fail-back
                    let failover = !proactive;
                    if failover {
                        reg.coord_failover.inc();
                        if let Some(f) = fleet {
                            f.note_failover(&node.addr, &alt, false);
                        }
                    }
                    let stat = NodeStat {
                        addr: alt,
                        shards: node.shards.clone(),
                        wall_s: t0.elapsed().as_secs_f64(),
                        retries: 1,
                        failover,
                        proactive: false,
                    };
                    Ok(NodeAnswer { heaps, breakdown, stat })
                }
                Err(alt_err) => {
                    if let Some(f) = fleet {
                        f.observe(&alt, false);
                    }
                    Err(anyhow::anyhow!(
                        "node {}: {first} failed ({first_err:#}) and {alt} failed \
                         too ({alt_err:#})",
                        node.addr
                    ))
                }
            }
        }
    }
}

/// One complete conversation with one address: pipeline the batch's
/// `n` query lines, then read the `n` replies in order, rebuilding the
/// per-query heaps from `topk_bits` and summing the per-reply ledgers
/// into one per-node breakdown (the replies are sequential on the node,
/// so summing `latency_s` into `wall_s` is the sequential-merge case).
/// A nonzero `trace_id` rides each query line as the `"trace"` field,
/// so the node scores the batch on the coordinator query's trace track.
fn talk(
    addr: &str,
    tokens: &[i32],
    n: usize,
    seq_len: usize,
    timeout: Option<Duration>,
    trace_id: u64,
) -> anyhow::Result<(Vec<TopK>, LatencyBreakdown)> {
    let stream = connect(addr, timeout)?;
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    for row in tokens.chunks(seq_len) {
        let mut pairs = vec![(
            "tokens",
            Value::Arr(row.iter().map(|&t| (t as usize).into()).collect()),
        )];
        if trace_id != 0 {
            pairs.push(("trace", (trace_id as usize).into()));
        }
        let line = obj(pairs);
        writeln!(stream, "{line}").map_err(io_ctx(addr, "write"))?;
    }
    stream.flush().map_err(io_ctx(addr, "flush"))?;

    let mut heaps = Vec::with_capacity(n);
    let mut breakdown: Option<LatencyBreakdown> = None;
    let mut line = String::new();
    for q in 0..n {
        line.clear();
        let read = reader.read_line(&mut line).map_err(io_ctx(addr, "read"))?;
        anyhow::ensure!(read > 0, "{addr}: connection closed after {q} of {n} replies");
        let v = Value::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("{addr}: unparseable reply: {e}"))?;
        if let Some(msg) = v.get("error").and_then(Value::as_str) {
            let code = v.get("code").and_then(Value::as_str).unwrap_or("?");
            anyhow::bail!("{addr}: node error for query {q}: {msg} (code {code})");
        }
        heaps.push(parse_heap(&v, addr)?);
        let b = parse_breakdown(&v);
        match breakdown.as_mut() {
            Some(acc) => acc.merge(&b),
            None => breakdown = Some(b),
        }
    }
    Ok((heaps, breakdown.unwrap_or_else(zero_breakdown)))
}

/// Open a connection with an optional connect timeout (shared with the
/// fleet monitor's probe/scrape loops).
pub(crate) fn connect(addr: &str, timeout: Option<Duration>) -> anyhow::Result<TcpStream> {
    match timeout {
        None => TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("{addr}: connect: {e}")),
        Some(t) => {
            use std::net::ToSocketAddrs;
            let sa = addr
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("{addr}: resolve: {e}"))?
                .next()
                .ok_or_else(|| anyhow::anyhow!("{addr}: resolves to no address"))?;
            TcpStream::connect_timeout(&sa, t)
                .map_err(|e| anyhow::anyhow!("{addr}: connect: {e}"))
        }
    }
}

fn io_ctx(addr: &str, what: &'static str) -> impl Fn(std::io::Error) -> anyhow::Error + '_ {
    move |e| {
        if matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ) {
            anyhow::anyhow!("{addr}: {what} timed out")
        } else {
            anyhow::anyhow!("{addr}: {what}: {e}")
        }
    }
}

/// Rebuild one query's heap from a reply's `topk_bits` — `[index,
/// f32-bit-pattern]` pairs, best first.  Ordered pushes into a fresh
/// heap reproduce the node's heap exactly, NaNs and tie-breaks
/// included (integers ≤ 2^32 cross the f64 JSON number path
/// bit-for-bit; the f64 `scores` field would have lost NaN to null).
fn parse_heap(v: &Value, addr: &str) -> anyhow::Result<TopK> {
    let Some(arr) = v.get("topk_bits").and_then(Value::as_arr) else {
        anyhow::bail!(
            "{addr}: reply has no topk_bits — is the node running an older build?"
        );
    };
    let mut heap = TopK::new(arr.len());
    for pair in arr {
        let entry = pair.as_arr().filter(|p| p.len() == 2);
        let (Some(i), Some(bits)) = (
            entry.and_then(|p| p[0].as_usize()),
            entry.and_then(|p| p[1].as_f64()),
        ) else {
            anyhow::bail!("{addr}: malformed topk_bits entry");
        };
        anyhow::ensure!(
            bits.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&bits),
            "{addr}: topk_bits pattern {bits} is not a u32"
        );
        heap.push(i, f32::from_bits(bits as u32));
    }
    Ok(heap)
}

fn zero_breakdown() -> LatencyBreakdown {
    LatencyBreakdown::merge_distributed(&[], 0.0)
}

/// Pull one reply's ledger fields into a breakdown (missing fields read
/// as zero, so a terse node reply still merges cleanly).
fn parse_breakdown(v: &Value) -> LatencyBreakdown {
    let f = |k: &str| v.get(k).and_then(Value::as_f64).unwrap_or(0.0);
    let u = |k: &str| v.get(k).and_then(Value::as_usize).unwrap_or(0);
    let (load, compute, pre) = (f("load_s"), f("compute_s"), f("precondition_s"));
    LatencyBreakdown {
        load_s: load,
        compute_s: compute,
        precondition_s: pre,
        total_s: load + compute + pre,
        wall_s: f("latency_s"),
        bytes_read: u("bytes_read") as u64,
        bytes_skipped: u("bytes_skipped") as u64,
        cache_hits: u("cache_hits"),
        cache_misses: u("cache_misses"),
        bytes_from_cache: u("bytes_from_cache") as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_parses_ranges_lists_and_replicas() {
        let t = Topology::parse(
            "127.0.0.1:7001=0-2/127.0.0.1:7101, 127.0.0.1:7002=3+5, 127.0.0.1:7003=4",
            Some(6),
        )
        .unwrap();
        assert_eq!(t.total_shards, 6);
        assert_eq!(t.nodes.len(), 3);
        assert_eq!(t.nodes[0].addr, "127.0.0.1:7001");
        assert_eq!(t.nodes[0].shards, vec![0, 1, 2]);
        assert_eq!(t.nodes[0].replica.as_deref(), Some("127.0.0.1:7101"));
        assert_eq!(t.nodes[1].shards, vec![3, 5]);
        assert_eq!(t.nodes[1].replica, None);
        assert_eq!(t.nodes[2].shards, vec![4]);
    }

    #[test]
    fn topology_infers_total_when_unspecified() {
        let t = Topology::parse("a:1=0-1,b:2=2", None).unwrap();
        assert_eq!(t.total_shards, 3);
        // an interior gap is still rejected under inference
        let err = Topology::parse("a:1=0,b:2=2", None).unwrap_err();
        assert!(format!("{err}").contains("[1]"), "{err}");
    }

    #[test]
    fn topology_rejects_duplicate_ownership() {
        let err = Topology::parse("a:1=0-2,b:2=2-3", Some(4)).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("shard 2"), "{msg}");
        assert!(msg.contains("a:1") && msg.contains("b:2"), "{msg}");
    }

    #[test]
    fn topology_rejects_uncovered_and_out_of_range_shards() {
        let err = Topology::parse("a:1=0,b:2=1", Some(4)).unwrap_err();
        assert!(format!("{err}").contains("[2, 3]"), "{err}");
        let err = Topology::parse("a:1=0-5", Some(3)).unwrap_err();
        assert!(format!("{err}").contains("shard 3"), "{err}");
    }

    #[test]
    fn topology_rejects_replica_equal_to_primary() {
        let err = Topology::parse("a:1=0/a:1", Some(1)).unwrap_err();
        assert!(format!("{err}").contains("replica"), "{err}");
    }

    #[test]
    fn topology_rejects_malformed_specs() {
        for bad in [
            "",
            "a:1",        // no '='
            "a:1=",       // no shards
            "=0",         // no addr
            "a:1=x",      // non-numeric
            "a:1=3-1",    // inverted range
            "a:1=0/",     // empty replica
        ] {
            assert!(Topology::parse(bad, None).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn shard_list_grammar_sorts_and_dedups() {
        assert_eq!(parse_shard_list("0-2+5").unwrap(), vec![0, 1, 2, 5]);
        assert_eq!(parse_shard_list("3").unwrap(), vec![3]);
        assert_eq!(parse_shard_list("2+0-2").unwrap(), vec![0, 1, 2]);
        for bad in ["", "x", "3-1", "1+"] {
            assert!(parse_shard_list(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn token_source_never_extracts() {
        let mut s = TokenSource { vocab: 64, seq_len: 8 };
        assert_eq!(s.vocab(), 64);
        assert_eq!(s.seq_len(), 8);
        assert!(s.extract(&[0; 8], 1).is_err());
    }

    #[test]
    fn parse_heap_round_trips_bits_including_nan() {
        let nan = f32::NAN.to_bits();
        let v = Value::parse(&format!(
            "{{\"topk_bits\": [[7, {nan}], [2, {}], [9, {}]]}}",
            1.5f32.to_bits(),
            (-2.0f32).to_bits()
        ))
        .unwrap();
        let h = parse_heap(&v, "t").unwrap();
        let e = h.entries();
        assert_eq!(e.len(), 3);
        assert_eq!(e[0].1, 7);
        assert!(e[0].0.is_nan(), "NaN survives the wire (total_cmp ranks it first)");
        assert_eq!(e[1], (1.5, 2));
        assert_eq!(e[2], (-2.0, 9));
        // missing field and malformed entries are clean errors
        assert!(parse_heap(&Value::parse("{}").unwrap(), "t").is_err());
        let bad = Value::parse("{\"topk_bits\": [[1, 0.5]]}").unwrap();
        assert!(parse_heap(&bad, "t").is_err());
    }

    #[test]
    fn parse_breakdown_reads_reply_fields() {
        let v = Value::parse(
            "{\"latency_s\": 0.5, \"load_s\": 0.2, \"compute_s\": 0.1, \
             \"precondition_s\": 0.05, \"bytes_read\": 100, \"bytes_skipped\": 50, \
             \"cache_hits\": 3, \"cache_misses\": 1, \"bytes_from_cache\": 10}",
        )
        .unwrap();
        let b = parse_breakdown(&v);
        assert!((b.wall_s - 0.5).abs() < 1e-12);
        assert!((b.total_s - 0.35).abs() < 1e-12);
        assert_eq!(b.bytes_read + b.bytes_skipped, 150);
        assert_eq!(b.cache_hits, 3);
        assert_eq!(b.bytes_from_cache, 10);
        // terse reply: everything zero, nothing panics
        let z = parse_breakdown(&Value::parse("{}").unwrap());
        assert_eq!(z.bytes_read, 0);
        assert_eq!(z.wall_s, 0.0);
    }
}

//! Query engine: runs a scorer over a query batch and packages scores,
//! top-k proponents, and the latency breakdown (Fig 3 / Tables 1–2).
//!
//! The engine's `sink` selects between the classic full-matrix pass
//! (eval and the figure benches need every score) and the streaming
//! top-k sink, which never materializes the `(n_query, n_train)`
//! matrix — O(Nq·k) score memory regardless of the store size.

use crate::attribution::{QueryGrads, ScoreReport, Scorer, SinkMode, SinkSpec};
use crate::linalg::Mat;
use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct LatencyBreakdown {
    pub load_s: f64,
    pub compute_s: f64,
    pub precondition_s: f64,
    /// Sum of the per-phase times.  Phase times accumulate ACROSS
    /// parallel shard workers (CPU seconds), so on a multi-threaded pass
    /// `total_s` exceeds the elapsed time — report `wall_s` for that.
    pub total_s: f64,
    /// Wall-clock elapsed for the pass, measured at the call site
    /// (`<= total_s` whenever shards scored in parallel).
    pub wall_s: f64,
    pub bytes_read: u64,
    /// store bytes the chunk pruner seeked past (`crate::sketch`);
    /// `bytes_read + bytes_skipped` = the full-scan byte count
    pub bytes_skipped: u64,
    /// chunks served by / decoded past the decoded-chunk cache
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// portion of `bytes_read` served from the cache (never hit disk)
    pub bytes_from_cache: u64,
}

impl LatencyBreakdown {
    /// Build from a report plus the wall-clock time the pass actually
    /// took (measured around the scorer call; phase times alone cannot
    /// recover it because they sum across parallel shard workers).
    pub fn from_report(r: &ScoreReport, wall: std::time::Duration) -> LatencyBreakdown {
        let load = r.timer.get("load").as_secs_f64();
        let compute = r.timer.get("compute").as_secs_f64();
        let pre = r.timer.get("precondition").as_secs_f64()
            + r.timer.get("recompute").as_secs_f64();
        LatencyBreakdown {
            load_s: load,
            compute_s: compute,
            precondition_s: pre,
            total_s: load + compute + pre,
            wall_s: wall.as_secs_f64(),
            bytes_read: r.bytes_read,
            bytes_skipped: r.bytes_skipped,
            cache_hits: r.cache_hits,
            cache_misses: r.cache_misses,
            bytes_from_cache: r.bytes_from_cache,
        }
    }

    /// Field-wise aggregation utility for rolling up breakdowns (e.g.
    /// per-shard or per-batch figures in reporting code).  The scorers'
    /// own shard aggregation happens earlier, at the `PhaseTimer` level
    /// in `query::parallel::merge_scores`.  `wall_s` sums too, which is
    /// correct for SEQUENTIAL passes (batches); concurrent passes need
    /// their own elapsed measurement.
    pub fn merge(&mut self, other: &LatencyBreakdown) {
        self.load_s += other.load_s;
        self.compute_s += other.compute_s;
        self.precondition_s += other.precondition_s;
        self.total_s += other.total_s;
        self.wall_s += other.wall_s;
        self.bytes_read += other.bytes_read;
        self.bytes_skipped += other.bytes_skipped;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.bytes_from_cache += other.bytes_from_cache;
    }

    /// Aggregate breakdowns from nodes that ran CONCURRENTLY (the
    /// coordinator's scatter-gather): CPU-style fields (phase times,
    /// byte/cache ledgers) still sum — the work genuinely happened on
    /// every node, and `bytes_read + bytes_skipped` summed over nodes
    /// reconciles to the full-scan byte count exactly as a local pass
    /// does — but `wall_s` is the MAX over nodes plus the coordinator's
    /// own overhead (scatter + gather + merge), because the slowest
    /// node gates the gather and the others overlap inside it.
    pub fn merge_distributed(
        nodes: &[LatencyBreakdown],
        coord_overhead_s: f64,
    ) -> LatencyBreakdown {
        let mut out = LatencyBreakdown {
            load_s: 0.0,
            compute_s: 0.0,
            precondition_s: 0.0,
            total_s: 0.0,
            wall_s: 0.0,
            bytes_read: 0,
            bytes_skipped: 0,
            cache_hits: 0,
            cache_misses: 0,
            bytes_from_cache: 0,
        };
        let mut slowest = 0.0f64;
        for n in nodes {
            out.merge(n);
            slowest = slowest.max(n.wall_s);
        }
        out.wall_s = slowest + coord_overhead_s;
        out
    }

    /// The breakdown as JSON object fields — one canonical
    /// serialization shared by the slow-query log (`query::slowlog`)
    /// and reporting paths, so the field names can never drift between
    /// the `slowlog` verb and the documented reply schema.
    pub fn json_fields(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("load_s", self.load_s.into()),
            ("compute_s", self.compute_s.into()),
            ("precondition_s", self.precondition_s.into()),
            ("total_s", self.total_s.into()),
            ("wall_s", self.wall_s.into()),
            ("bytes_read", (self.bytes_read as usize).into()),
            ("bytes_skipped", (self.bytes_skipped as usize).into()),
            ("cache_hits", self.cache_hits.into()),
            ("cache_misses", self.cache_misses.into()),
            ("bytes_from_cache", (self.bytes_from_cache as usize).into()),
        ]
    }

    /// Share of the pass's CPU time spent on store I/O (load / total).
    /// Both operands sum across parallel shard workers, so the ratio is
    /// a CPU-time share, not a share of elapsed time.
    pub fn io_fraction(&self) -> f64 {
        if self.total_s <= 0.0 {
            0.0
        } else {
            self.load_s / self.total_s
        }
    }
}

pub struct QueryResult {
    /// Full `(n_query, n_train)` matrix; `None` when the engine ran
    /// with the streaming top-k sink (only `topk` is materialized).
    pub scores: Option<Mat>,
    pub topk: Vec<Vec<usize>>,
    pub latency: LatencyBreakdown,
}

pub struct QueryEngine<S: Scorer> {
    pub scorer: S,
    pub k: usize,
    /// worker threads for the top-k selection (0 = all cores)
    pub topk_threads: usize,
    /// full-matrix pass vs streaming top-k sink
    pub sink: SinkMode,
}

impl<S: Scorer> QueryEngine<S> {
    pub fn new(scorer: S, k: usize) -> Self {
        QueryEngine { scorer, k, topk_threads: 0, sink: SinkMode::Full }
    }

    pub fn run(&mut self, queries: &QueryGrads) -> anyhow::Result<QueryResult> {
        // One trace tree per pass: allocate a fresh trace ID unless the
        // caller (the batch server) already attached one to this thread.
        let cur = crate::telemetry::current_ctx().trace;
        let trace =
            if cur.id == 0 { crate::telemetry::TraceCtx::next_query() } else { cur };
        crate::telemetry::with_trace(trace, || self.run_traced(queries))
    }

    fn run_traced(&mut self, queries: &QueryGrads) -> anyhow::Result<QueryResult> {
        let mut root = crate::telemetry::trace::span("query");
        if let Some(s) = root.as_mut() {
            s.arg("n_query", queries.n_query);
            s.arg("k", self.k);
            s.arg_str("sink", self.sink.name());
        }
        let t0 = std::time::Instant::now();
        let report = match self.sink {
            SinkMode::Full => self.scorer.score(queries)?,
            SinkMode::TopK => self.scorer.score_sink(queries, SinkSpec::TopK(self.k))?,
        };
        let latency = LatencyBreakdown::from_report(&report, t0.elapsed());
        crate::telemetry::current_registry().query_latency.observe_secs(latency.wall_s);
        log::info!(
            "{}: scored {} queries x {} train in {:.3}s wall ({:.3}s CPU), {} sink ({})",
            self.scorer.name(),
            report.n_query(),
            report.n_train,
            latency.wall_s,
            latency.total_s,
            self.sink.name(),
            report.timer.summary()
        );
        match self.sink {
            SinkMode::Full => {
                let topk = super::parallel::topk(report.scores(), self.k, self.topk_threads);
                Ok(QueryResult { scores: Some(report.into_scores()), topk, latency })
            }
            SinkMode::TopK => {
                Ok(QueryResult { scores: None, topk: report.topk(self.k), latency })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::PhaseTimer;

    struct FakeScorer;
    impl Scorer for FakeScorer {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn index_bytes(&self) -> u64 {
            42
        }
        fn score(&mut self, q: &QueryGrads) -> anyhow::Result<ScoreReport> {
            // FAKE phase times, far larger than the instant the call
            // actually takes: a parallel shard pass reports summed CPU
            // seconds the same way (no sleeping here — the wall-clock
            // regression test depends on real elapsed << phase sum)
            let mut timer = PhaseTimer::new();
            timer.add("load", std::time::Duration::from_secs(3));
            timer.add("compute", std::time::Duration::from_secs(1));
            let mut scores = Mat::zeros(q.n_query, 5);
            for i in 0..5 {
                *scores.at_mut(0, i) = i as f32;
            }
            Ok(ScoreReport::full(scores, timer, 42))
        }
    }

    #[test]
    fn json_fields_carry_the_whole_breakdown() {
        let lat = LatencyBreakdown {
            load_s: 1.5,
            compute_s: 0.5,
            precondition_s: 0.25,
            total_s: 2.25,
            wall_s: 0.75,
            bytes_read: 1024,
            bytes_skipped: 4096,
            cache_hits: 3,
            cache_misses: 1,
            bytes_from_cache: 512,
        };
        let v = crate::util::json::obj(lat.json_fields());
        assert_eq!(v.get("load_s").and_then(Value::as_f64), Some(1.5));
        assert_eq!(v.get("wall_s").and_then(Value::as_f64), Some(0.75));
        assert_eq!(v.get("bytes_read").and_then(Value::as_usize), Some(1024));
        assert_eq!(v.get("bytes_skipped").and_then(Value::as_usize), Some(4096));
        assert_eq!(v.get("cache_hits").and_then(Value::as_usize), Some(3));
        assert_eq!(v.get("bytes_from_cache").and_then(Value::as_usize), Some(512));
        assert_eq!(v.get("total_s").and_then(Value::as_f64), Some(2.25));
    }

    #[test]
    fn engine_topk_and_breakdown() {
        let mut e = QueryEngine::new(FakeScorer, 3);
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let r = e.run(&q).unwrap();
        assert_eq!(r.topk[0], vec![4, 3, 2]);
        assert!(r.scores.is_some());
        assert!((r.latency.io_fraction() - 0.75).abs() < 0.05);
        assert_eq!(r.latency.bytes_read, 42);
    }

    #[test]
    fn wall_clock_is_measured_not_summed() {
        // regression: FakeScorer reports 4s of phase time without
        // sleeping, as a parallel shard pass does (phase times sum CPU
        // seconds across workers).  wall_s must reflect the actual
        // elapsed time, not the phase sum — the 4s margin cannot be
        // crossed by scheduler noise on a loaded CI machine.
        let mut e = QueryEngine::new(FakeScorer, 3);
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let r = e.run(&q).unwrap();
        assert!((r.latency.total_s - 4.0).abs() < 1e-9, "phase sum is 4s");
        assert!(
            r.latency.wall_s < r.latency.total_s,
            "wall {} should be far below the 4s phase sum",
            r.latency.wall_s
        );
        assert!(r.latency.wall_s >= 0.0);
    }

    #[test]
    fn engine_streaming_sink_drops_matrix_keeps_topk() {
        let mut e = QueryEngine::new(FakeScorer, 3);
        e.sink = SinkMode::TopK;
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let r = e.run(&q).unwrap();
        assert_eq!(r.topk[0], vec![4, 3, 2]);
        assert!(r.scores.is_none(), "streaming sink must not materialize the matrix");
        assert_eq!(r.latency.bytes_read, 42);
    }

    fn breakdown(load: f64, compute: f64, pre: f64, wall: f64, bytes: u64) -> LatencyBreakdown {
        LatencyBreakdown {
            load_s: load,
            compute_s: compute,
            precondition_s: pre,
            total_s: load + compute + pre,
            wall_s: wall,
            bytes_read: bytes,
            bytes_skipped: 0,
            cache_hits: 0,
            cache_misses: 0,
            bytes_from_cache: 0,
        }
    }

    #[test]
    fn breakdown_merge_sums_batches_and_tracks_wall_separately() {
        // three sequential batches aggregate field-wise; the wall clock
        // is its own field — on a parallel pass it is SMALLER than the
        // phase sum (CPU seconds across workers), and merging keeps the
        // two separate instead of conflating them
        let mut total = breakdown(0.0, 0.0, 0.0, 0.0, 0);
        for b in [
            breakdown(0.3, 0.1, 0.05, 0.2, 1000),
            breakdown(0.2, 0.2, 0.0, 0.15, 2000),
            breakdown(0.5, 0.1, 0.05, 0.25, 3000),
        ] {
            total.merge(&b);
        }
        assert!((total.load_s - 1.0).abs() < 1e-12);
        assert!((total.compute_s - 0.4).abs() < 1e-12);
        assert!((total.precondition_s - 0.1).abs() < 1e-12);
        assert!((total.total_s - 1.5).abs() < 1e-12);
        assert!((total.wall_s - 0.6).abs() < 1e-12, "wall merges independently");
        assert!(total.wall_s < total.total_s, "parallel shards: wall < CPU sum");
        assert_eq!(total.bytes_read, 6000);
        assert!((total.io_fraction() - 1.0 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn breakdown_merge_sums_cache_counters() {
        let mut a = breakdown(0.1, 0.1, 0.0, 0.1, 500);
        a.cache_hits = 3;
        a.cache_misses = 1;
        a.bytes_from_cache = 300;
        let mut b = breakdown(0.1, 0.1, 0.0, 0.1, 500);
        b.cache_hits = 2;
        b.bytes_from_cache = 200;
        a.merge(&b);
        assert_eq!(a.cache_hits, 5);
        assert_eq!(a.cache_misses, 1);
        assert_eq!(a.bytes_from_cache, 500);
    }

    #[test]
    fn distributed_merge_takes_max_wall_and_sums_ledgers() {
        // three nodes ran CONCURRENTLY: CPU phase times and byte
        // ledgers sum (the work happened on every node), but the gather
        // finishes when the slowest node does — wall is max + overhead,
        // NOT the sequential-batch sum
        let mut a = breakdown(0.3, 0.1, 0.05, 0.50, 1000);
        a.bytes_skipped = 200;
        a.cache_hits = 2;
        let mut b = breakdown(0.2, 0.2, 0.00, 0.90, 2000);
        b.bytes_skipped = 100;
        b.bytes_from_cache = 64;
        let c = breakdown(0.5, 0.1, 0.05, 0.40, 3000);
        let m = LatencyBreakdown::merge_distributed(&[a, b, c], 0.03);
        assert!((m.load_s - 1.0).abs() < 1e-12);
        assert!((m.compute_s - 0.4).abs() < 1e-12);
        assert!((m.precondition_s - 0.1).abs() < 1e-12);
        assert!((m.total_s - 1.5).abs() < 1e-12);
        assert!((m.wall_s - 0.93).abs() < 1e-12, "max(0.5, 0.9, 0.4) + 0.03");
        assert!(m.wall_s < 0.5 + 0.9 + 0.4, "must not sum walls CPU-style");
        // the full-scan ledger reconciles summed over nodes
        assert_eq!(m.bytes_read, 6000);
        assert_eq!(m.bytes_skipped, 300);
        assert_eq!(m.bytes_read + m.bytes_skipped, 6300);
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.bytes_from_cache, 64);
        // degenerate: no nodes -> pure coordinator overhead
        let empty = LatencyBreakdown::merge_distributed(&[], 0.01);
        assert!((empty.wall_s - 0.01).abs() < 1e-12);
        assert_eq!(empty.bytes_read, 0);
    }

    #[test]
    fn io_fraction_zero_total_is_zero() {
        let b = breakdown(0.0, 0.0, 0.0, 0.0, 0);
        assert_eq!(b.io_fraction(), 0.0);
        // a merge of empty breakdowns stays well-defined
        let mut m = breakdown(0.0, 0.0, 0.0, 0.0, 0);
        m.merge(&b);
        assert_eq!(m.io_fraction(), 0.0);
    }
}

//! Query engine: runs a scorer over a query batch and packages scores,
//! top-k proponents, and the latency breakdown (Fig 3 / Tables 1–2).
//!
//! The engine's `sink` selects between the classic full-matrix pass
//! (eval and the figure benches need every score) and the streaming
//! top-k sink, which never materializes the `(n_query, n_train)`
//! matrix — O(Nq·k) score memory regardless of the store size.

use crate::attribution::{QueryGrads, ScoreReport, Scorer, SinkMode, SinkSpec};
use crate::linalg::Mat;

#[derive(Debug, Clone)]
pub struct LatencyBreakdown {
    pub load_s: f64,
    pub compute_s: f64,
    pub precondition_s: f64,
    pub total_s: f64,
    pub bytes_read: u64,
    /// store bytes the chunk pruner seeked past (`crate::sketch`);
    /// `bytes_read + bytes_skipped` = the full-scan byte count
    pub bytes_skipped: u64,
}

impl LatencyBreakdown {
    pub fn from_report(r: &ScoreReport) -> LatencyBreakdown {
        let load = r.timer.get("load").as_secs_f64();
        let compute = r.timer.get("compute").as_secs_f64();
        let pre = r.timer.get("precondition").as_secs_f64()
            + r.timer.get("recompute").as_secs_f64();
        LatencyBreakdown {
            load_s: load,
            compute_s: compute,
            precondition_s: pre,
            total_s: load + compute + pre,
            bytes_read: r.bytes_read,
            bytes_skipped: r.bytes_skipped,
        }
    }

    /// Field-wise aggregation utility for rolling up breakdowns (e.g.
    /// per-shard or per-batch figures in reporting code).  The scorers'
    /// own shard aggregation happens earlier, at the `PhaseTimer` level
    /// in `query::parallel::merge_scores`.
    pub fn merge(&mut self, other: &LatencyBreakdown) {
        self.load_s += other.load_s;
        self.compute_s += other.compute_s;
        self.precondition_s += other.precondition_s;
        self.total_s += other.total_s;
        self.bytes_read += other.bytes_read;
        self.bytes_skipped += other.bytes_skipped;
    }

    pub fn io_fraction(&self) -> f64 {
        if self.total_s <= 0.0 {
            0.0
        } else {
            self.load_s / self.total_s
        }
    }
}

pub struct QueryResult {
    /// Full `(n_query, n_train)` matrix; `None` when the engine ran
    /// with the streaming top-k sink (only `topk` is materialized).
    pub scores: Option<Mat>,
    pub topk: Vec<Vec<usize>>,
    pub latency: LatencyBreakdown,
}

pub struct QueryEngine<S: Scorer> {
    pub scorer: S,
    pub k: usize,
    /// worker threads for the top-k selection (0 = all cores)
    pub topk_threads: usize,
    /// full-matrix pass vs streaming top-k sink
    pub sink: SinkMode,
}

impl<S: Scorer> QueryEngine<S> {
    pub fn new(scorer: S, k: usize) -> Self {
        QueryEngine { scorer, k, topk_threads: 0, sink: SinkMode::Full }
    }

    pub fn run(&mut self, queries: &QueryGrads) -> anyhow::Result<QueryResult> {
        let report = match self.sink {
            SinkMode::Full => self.scorer.score(queries)?,
            SinkMode::TopK => self.scorer.score_sink(queries, SinkSpec::TopK(self.k))?,
        };
        let latency = LatencyBreakdown::from_report(&report);
        log::info!(
            "{}: scored {} queries x {} train in {:.3}s, {} sink ({})",
            self.scorer.name(),
            report.n_query(),
            report.n_train,
            latency.total_s,
            self.sink.name(),
            report.timer.summary()
        );
        match self.sink {
            SinkMode::Full => {
                let topk = super::parallel::topk(report.scores(), self.k, self.topk_threads);
                Ok(QueryResult { scores: Some(report.into_scores()), topk, latency })
            }
            SinkMode::TopK => {
                Ok(QueryResult { scores: None, topk: report.topk(self.k), latency })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::PhaseTimer;

    struct FakeScorer;
    impl Scorer for FakeScorer {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn index_bytes(&self) -> u64 {
            42
        }
        fn score(&mut self, q: &QueryGrads) -> anyhow::Result<ScoreReport> {
            let mut timer = PhaseTimer::new();
            timer.add("load", std::time::Duration::from_millis(30));
            timer.add("compute", std::time::Duration::from_millis(10));
            let mut scores = Mat::zeros(q.n_query, 5);
            for i in 0..5 {
                *scores.at_mut(0, i) = i as f32;
            }
            Ok(ScoreReport::full(scores, timer, 42))
        }
    }

    #[test]
    fn engine_topk_and_breakdown() {
        let mut e = QueryEngine::new(FakeScorer, 3);
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let r = e.run(&q).unwrap();
        assert_eq!(r.topk[0], vec![4, 3, 2]);
        assert!(r.scores.is_some());
        assert!((r.latency.io_fraction() - 0.75).abs() < 0.05);
        assert_eq!(r.latency.bytes_read, 42);
    }

    #[test]
    fn engine_streaming_sink_drops_matrix_keeps_topk() {
        let mut e = QueryEngine::new(FakeScorer, 3);
        e.sink = SinkMode::TopK;
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let r = e.run(&q).unwrap();
        assert_eq!(r.topk[0], vec![4, 3, 2]);
        assert!(r.scores.is_none(), "streaming sink must not materialize the matrix");
        assert_eq!(r.latency.bytes_read, 42);
    }

    fn breakdown(load: f64, compute: f64, pre: f64, bytes: u64) -> LatencyBreakdown {
        LatencyBreakdown {
            load_s: load,
            compute_s: compute,
            precondition_s: pre,
            total_s: load + compute + pre,
            bytes_read: bytes,
            bytes_skipped: 0,
        }
    }

    #[test]
    fn breakdown_merge_sums_shards() {
        // three shards' worth of latency aggregates field-wise
        let mut total = breakdown(0.0, 0.0, 0.0, 0);
        for b in [
            breakdown(0.3, 0.1, 0.05, 1000),
            breakdown(0.2, 0.2, 0.0, 2000),
            breakdown(0.5, 0.1, 0.05, 3000),
        ] {
            total.merge(&b);
        }
        assert!((total.load_s - 1.0).abs() < 1e-12);
        assert!((total.compute_s - 0.4).abs() < 1e-12);
        assert!((total.precondition_s - 0.1).abs() < 1e-12);
        assert!((total.total_s - 1.5).abs() < 1e-12);
        assert_eq!(total.bytes_read, 6000);
        assert!((total.io_fraction() - 1.0 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn io_fraction_zero_total_is_zero() {
        let b = breakdown(0.0, 0.0, 0.0, 0);
        assert_eq!(b.io_fraction(), 0.0);
        // a merge of empty breakdowns stays well-defined
        let mut m = breakdown(0.0, 0.0, 0.0, 0);
        m.merge(&b);
        assert_eq!(m.io_fraction(), 0.0);
    }
}

//! The shard execution plane: where a query batch's scores come from.
//!
//! The serving pipeline (`query::server`) used to be welded to one
//! in-process scorer pool.  The plane seam splits "how a batch is
//! scored" from "how the service admits, batches, and answers":
//!
//!   * [`LocalPlane`] wraps a `Scorer` over an in-process `ShardSet` —
//!     the classic single-machine path, behavior-identical to calling
//!     `score_sink(SinkSpec::TopK(k))` directly.
//!   * `RemotePlane` (`query::coordinator`) scatters the batch to shard
//!     nodes over the line protocol and merges their heaps with the
//!     same `merge_topk` reduction the local executor uses, so the two
//!     planes are bit-for-bit interchangeable.
//!
//! The seam is the batch payload, [`PlaneBatch`]: a local plane wants
//! EXTRACTED gradients (the batcher runs `GradSource::extract`), while
//! a remote plane forwards the RAW validated token rows — each node
//! re-extracts deterministically, which is what makes the distributed
//! result exact rather than a lossy gradient serialization.  A plane
//! declares which payload it consumes via
//! [`ShardPlane::wants_grads`], and the server's batcher builds the
//! matching variant.

use std::time::Instant;

use super::engine::LatencyBreakdown;
use super::parallel::TopK;
use crate::attribution::{QueryGrads, ScoreOutput, Scorer, SinkSpec};

/// One batch handed to a plane: extracted gradients (local) or the raw
/// zero-padded token rows (remote; `tokens.len() == n * seq_len`).
pub enum PlaneBatch {
    Grads(QueryGrads),
    Tokens { tokens: Vec<i32>, n: usize, seq_len: usize },
}

impl PlaneBatch {
    pub fn n_queries(&self) -> usize {
        match self {
            PlaneBatch::Grads(q) => q.n_query,
            PlaneBatch::Tokens { n, .. } => *n,
        }
    }
}

/// Per-node accounting of one scattered batch, surfaced in the
/// coordinator's reply (`"nodes": [...]`) next to the merged scores.
#[derive(Clone, Debug)]
pub struct NodeStat {
    /// address that ANSWERED (the replica's after a failover)
    pub addr: String,
    /// manifest shards this node covered
    pub shards: Vec<usize>,
    /// wall seconds for this node's whole scatter+gather round trip
    pub wall_s: f64,
    /// scatter attempts beyond the first (primary retries + failover)
    pub retries: usize,
    /// whether the answer came from the configured replica
    pub failover: bool,
    /// replica was chosen BEFORE any attempt because the health probe
    /// had already marked the primary down (no io-timeout was paid)
    pub proactive: bool,
}

impl NodeStat {
    /// The canonical JSON shape of one node's scatter accounting — the
    /// SAME object appears in coordinator replies (`"nodes": [...]`)
    /// and in slow-query-log entries, so the two can never drift.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::{obj, Value};
        obj([
            ("addr", self.addr.as_str().into()),
            ("shards", Value::Arr(self.shards.iter().map(|&s| s.into()).collect())),
            ("wall_s", self.wall_s.into()),
            ("retries", self.retries.into()),
            ("failover", self.failover.into()),
            ("proactive", self.proactive.into()),
        ])
    }
}

/// What a plane returns for one batch: per-query top-k heaps in
/// ORIGINAL example coordinates (ready for `merge_topk`-style
/// consumption), the aggregated latency/byte ledger, and — on the
/// remote plane — per-node stats.
pub struct PlaneReply {
    pub topk: Vec<TopK>,
    pub latency: LatencyBreakdown,
    pub nodes: Vec<NodeStat>,
}

/// A transport for scoring one batch against the sharded store.
pub trait ShardPlane: Send {
    fn name(&self) -> &'static str;

    /// Whether this plane consumes [`PlaneBatch::Grads`] (the batcher
    /// must run gradient extraction) or [`PlaneBatch::Tokens`].
    fn wants_grads(&self) -> bool;

    /// Score one batch, returning per-query top-k heaps.
    fn score_topk(&mut self, batch: &PlaneBatch, k: usize) -> anyhow::Result<PlaneReply>;
}

/// The in-process plane: one scorer over a local (possibly
/// subset-opened) `ShardSet`.  Exactly today's serving path — the heaps
/// come straight out of the streaming top-k sink.
pub struct LocalPlane {
    pub scorer: Box<dyn Scorer + Send>,
}

impl ShardPlane for LocalPlane {
    fn name(&self) -> &'static str {
        "local"
    }

    fn wants_grads(&self) -> bool {
        true
    }

    fn score_topk(&mut self, batch: &PlaneBatch, k: usize) -> anyhow::Result<PlaneReply> {
        let PlaneBatch::Grads(queries) = batch else {
            anyhow::bail!("local plane needs extracted gradients, got raw tokens");
        };
        let t0 = Instant::now();
        let report = self.scorer.score_sink(queries, SinkSpec::TopK(k))?;
        let latency = LatencyBreakdown::from_report(&report, t0.elapsed());
        let topk = match report.output {
            ScoreOutput::TopK(heaps) => heaps,
            // a scorer without a streaming sink answered with the full
            // matrix: reduce it with the same ordered pushes (ties
            // toward the lower index) the sink would have applied
            ScoreOutput::Full(m) => (0..m.rows)
                .map(|q| {
                    let mut h = TopK::new(k);
                    for (i, &s) in m.row(q).iter().enumerate() {
                        h.push(i, s);
                    }
                    h
                })
                .collect(),
        };
        Ok(PlaneReply { topk, latency, nodes: Vec::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attribution::ScoreReport;
    use crate::linalg::Mat;
    use crate::util::timer::PhaseTimer;

    struct FakeScorer;
    impl Scorer for FakeScorer {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn index_bytes(&self) -> u64 {
            0
        }
        fn score(&mut self, q: &QueryGrads) -> anyhow::Result<ScoreReport> {
            let mut scores = Mat::zeros(q.n_query, 6);
            for i in 0..6 {
                *scores.at_mut(0, i) = [3.0, 1.0, 3.0, 7.0, 0.5, 7.0][i];
            }
            Ok(ScoreReport::full(scores, PhaseTimer::new(), 64))
        }
    }

    #[test]
    fn local_plane_reduces_like_the_streaming_sink() {
        let mut plane = LocalPlane { scorer: Box::new(FakeScorer) };
        assert!(plane.wants_grads());
        let q = QueryGrads { n_query: 1, c: 1, proj_dims: vec![], layers: vec![] };
        let rep = plane.score_topk(&PlaneBatch::Grads(q), 4).unwrap();
        assert_eq!(rep.topk.len(), 1);
        // ties break toward the LOWER original index: 7@3 before 7@5,
        // 3@0 before 3@2
        assert_eq!(rep.topk[0].entries(), &[(7.0, 3), (7.0, 5), (3.0, 0), (3.0, 2)]);
        assert!(rep.nodes.is_empty());
        assert_eq!(rep.latency.bytes_read, 64);
        // a token batch is a contract violation, not a panic
        let t = PlaneBatch::Tokens { tokens: vec![0; 8], n: 1, seq_len: 8 };
        assert!(plane.score_topk(&t, 4).is_err());
    }

    #[test]
    fn node_stat_json_has_the_documented_fields() {
        use crate::util::json::Value;
        let ns = NodeStat {
            addr: "127.0.0.1:7001".into(),
            shards: vec![0, 2],
            wall_s: 0.125,
            retries: 1,
            failover: true,
            proactive: false,
        };
        let v = ns.to_json();
        assert_eq!(v.get("addr").and_then(Value::as_str), Some("127.0.0.1:7001"));
        let shards: Vec<usize> = v
            .get("shards")
            .and_then(Value::as_arr)
            .unwrap()
            .iter()
            .filter_map(Value::as_usize)
            .collect();
        assert_eq!(shards, vec![0, 2]);
        assert_eq!(v.get("wall_s").and_then(Value::as_f64), Some(0.125));
        assert_eq!(v.get("retries").and_then(Value::as_usize), Some(1));
        assert_eq!(v.get("failover").and_then(Value::as_bool), Some(true));
        assert_eq!(v.get("proactive").and_then(Value::as_bool), Some(false));
    }

    #[test]
    fn plane_batch_counts_queries() {
        let g = PlaneBatch::Grads(QueryGrads {
            n_query: 3,
            c: 1,
            proj_dims: vec![],
            layers: vec![],
        });
        assert_eq!(g.n_queries(), 3);
        let t = PlaneBatch::Tokens { tokens: vec![0; 16], n: 2, seq_len: 8 };
        assert_eq!(t.n_queries(), 2);
    }
}

//! Minimal JSON codec (the vendored crate set has no serde).
//!
//! Parses the artifact manifest written by `python/compile/aot.py`, the
//! experiment config files, and the store manifests; serializes bench
//! reports.  Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed by any of our producers).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(s: &str) -> Result<Value, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers used by manifest/config loaders.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("field '{key}' not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow::anyhow!("field '{key}' not a number"))
    }
}

// ---- construction helpers ------------------------------------------------

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Ergonomic object builder: `obj([("a", 1.0.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Value)>>(it: I) -> Value {
    Value::Obj(it.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---- serialization ---------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf tokens; emit null like
                    // JSON.stringify does (NaN scores can reach the
                    // serving path since ranking is total_cmp-based)
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---- parser ----------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, ch: u8) -> Result<(), ParseError> {
        if self.peek() == Some(ch) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", ch as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // JSON has no NaN/inf tokens; a corrupted score reaching the
        // serving path must not emit an unparseable response line
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = obj([("score", Value::Num(x))]);
            let text = doc.to_string();
            assert_eq!(text, r#"{"score":null}"#);
            assert!(Value::parse(&text).is_ok(), "round-trip: {text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":3}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        let v = Value::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Value::Str("Aé".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn manifest_like_document() {
        let doc = r#"{"version": 2, "graphs": [{"name": "g", "inputs":
            [{"dtype": "float32", "shape": [8, 64]}]}]}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 2);
        let g = &v.get("graphs").unwrap().as_arr().unwrap()[0];
        assert_eq!(g.req_str("name").unwrap(), "g");
    }
}

//! bfloat16 encode/decode for the gradient store.
//!
//! The paper stores projected gradients and rank-c factors in 16-bit
//! formats; we use bf16 (same exponent range as f32, 8-bit mantissa) with
//! round-to-nearest-even, matching what XLA's `Bf16` type does.  The
//! store reader decodes shards back to f32 on the query hot path, so both
//! directions are written to be auto-vectorizable.

/// Convert one f32 to bf16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest even: add 0x7fff + lsb of the truncated result
    let round_bit = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7fff + round_bit)) >> 16) as u16
}

/// Convert bf16 bits back to f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Encode a slice of f32 into bf16 bytes (little-endian u16s).
pub fn encode_slice(src: &[f32], dst: &mut Vec<u8>) {
    dst.reserve(src.len() * 2);
    for &x in src {
        let b = f32_to_bf16(x);
        dst.extend_from_slice(&b.to_le_bytes());
    }
}

/// Decode bf16 bytes into an f32 buffer. `dst` is resized to fit.
pub fn decode_slice(src: &[u8], dst: &mut Vec<f32>) {
    assert!(src.len() % 2 == 0, "bf16 byte stream must have even length");
    let n = src.len() / 2;
    dst.clear();
    dst.reserve(n);
    // chunks_exact lets LLVM vectorize the widening shift
    for ch in src.chunks_exact(2) {
        let b = u16::from_le_bytes([ch[0], ch[1]]);
        dst.push(bf16_to_f32(b));
    }
}

/// Decode into a pre-sized slice (no allocation on the hot path).
pub fn decode_into(src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len() * 2, "bf16 src/dst length mismatch");
    for (ch, d) in src.chunks_exact(2).zip(dst.iter_mut()) {
        *d = bf16_to_f32(u16::from_le_bytes([ch[0], ch[1]]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        // values with <= 8 mantissa bits are exact in bf16
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1.5, 3.0, 256.0] {
            assert_eq!(bf16_to_f32(f32_to_bf16(x)), x, "{x}");
        }
    }

    #[test]
    fn rounding_error_bounded() {
        // relative error of bf16 rounding is <= 2^-8
        let mut x = -10.0f32;
        while x < 10.0 {
            let y = bf16_to_f32(f32_to_bf16(x));
            if x != 0.0 {
                assert!(((y - x) / x).abs() <= 1.0 / 256.0 + 1e-7, "{x} -> {y}");
            }
            x += 0.0137;
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between bf16(1.0) and the next
        // representable; must round to even (1.0)
        let x = 1.0f32 + f32::powi(2.0, -9);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0);
        // 1.0 + 3*2^-9 is halfway above 1.0+2^-8 -> rounds up to 1.0+2^-7
        let x = 1.0f32 + 3.0 * f32::powi(2.0, -9);
        assert_eq!(bf16_to_f32(f32_to_bf16(x)), 1.0 + f32::powi(2.0, -7));
    }

    #[test]
    fn slice_roundtrip() {
        let src: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.01).collect();
        let mut bytes = Vec::new();
        encode_slice(&src, &mut bytes);
        assert_eq!(bytes.len(), 2000);
        let mut back = Vec::new();
        decode_slice(&bytes, &mut back);
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() / 256.0 + 1e-6);
        }
        let mut fixed = vec![0.0f32; src.len()];
        decode_into(&bytes, &mut fixed);
        assert_eq!(back, fixed);
    }
}

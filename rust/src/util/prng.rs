//! Deterministic PRNG for the whole Rust side (no `rand` crate offline).
//!
//! SplitMix64 core with Box–Muller normals.  Every consumer derives its
//! own stream from a (seed, label) pair so experiments are reproducible
//! and independent components never share a stream.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller normal
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream from a string label (fnv-1a mix).
    pub fn labeled(seed: u64, label: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325 ^ seed;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire rejection-free-enough for non-crypto use
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with N(0, sigma^2) f32 values.
    pub fn fill_normal(&mut self, dst: &mut [f32], sigma: f32) {
        for d in dst.iter_mut() {
            *d = self.normal() as f32 * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn labeled_streams_differ() {
        let mut a = Rng::labeled(7, "proj_in");
        let mut b = Rng::labeled(7, "proj_out");
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(6);
        let s = r.sample_indices(100, 40);
        assert_eq!(s.len(), 40);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 100));
    }
}

//! Minimal scoped worker pool (std-only, no extra dependencies) for the
//! parallel shard-scoring path.
//!
//! Jobs are claimed from a shared atomic counter, so uneven shard costs
//! balance across workers; results come back in job order.  Borrowed
//! captures are fine — workers run inside `std::thread::scope`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a requested worker count: 0 means "all available cores".
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Run `jobs` closures on up to `threads` workers (0 = auto), returning
/// results in job order.  The first job error stops further jobs from
/// being claimed (in-flight ones finish) and is propagated; a panicking
/// job propagates the panic.
pub fn run<T, F>(threads: usize, jobs: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> anyhow::Result<T> + Sync,
{
    if jobs == 0 {
        return Ok(Vec::new());
    }
    let workers = effective_threads(threads).min(jobs);
    if workers <= 1 {
        return (0..jobs).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<anyhow::Result<T>>>> =
        (0..jobs).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs {
                    break;
                }
                let out = f(i);
                if out.is_err() {
                    failed.store(true, Ordering::Relaxed);
                }
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    // claims are sequential, so filled slots form a prefix; the first
    // non-Ok entry in order is the error to report
    let mut out = Vec::with_capacity(jobs);
    for m in slots {
        match m.into_inner().unwrap() {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            None => {
                return Err(anyhow::anyhow!("worker pool aborted after an earlier job failed"))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_in_job_order() {
        let out = run(4, 17, |i| Ok(i * i)).unwrap();
        assert_eq!(out.len(), 17);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let hits = AtomicUsize::new(0);
        let out = run(3, 25, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            Ok(i)
        })
        .unwrap();
        assert_eq!(out.len(), 25);
        assert_eq!(hits.load(Ordering::Relaxed), 25);
    }

    #[test]
    fn propagates_job_errors() {
        let r: anyhow::Result<Vec<usize>> = run(2, 8, |i| {
            if i == 5 {
                anyhow::bail!("job {i} failed");
            }
            Ok(i)
        });
        assert!(r.is_err());
    }

    #[test]
    fn zero_jobs_and_single_thread() {
        assert!(run(0, 0, |i| Ok(i)).unwrap().is_empty());
        assert_eq!(run(1, 3, |i| Ok(i + 1)).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(7), 7);
    }
}

//! Minimal scoped worker pool (std-only, no extra dependencies) for the
//! parallel shard-scoring path.
//!
//! Jobs are claimed from a shared atomic counter, so uneven shard costs
//! balance across workers; results come back in job order.  Borrowed
//! captures are fine — workers run inside `std::thread::scope`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resolve a requested worker count: 0 means "all available cores".
pub fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Best-effort text of a panic payload (`&str` / `String` payloads,
/// which is what `panic!` produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run `jobs` closures on up to `threads` workers (0 = auto), returning
/// results in job order.  The first job error stops further jobs from
/// being claimed (in-flight ones finish) and is propagated.  A
/// PANICKING job is caught on its worker and surfaces as that job's
/// error, carrying the original panic message — it must not escape the
/// worker thread, where `std::thread::scope` would replace it with an
/// opaque "a scoped thread panicked" double panic; and the result slots
/// recover from mutex poisoning instead of compounding one failure with
/// a `PoisonError` unwrap in the collector.
pub fn run<T, F>(threads: usize, jobs: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> anyhow::Result<T> + Sync,
{
    if jobs == 0 {
        return Ok(Vec::new());
    }
    let workers = effective_threads(threads).min(jobs);
    // Capture the caller's telemetry scope (registry override + trace
    // track) and re-install it inside every job, so a query's metrics
    // and trace spans follow the fan-out across worker threads.
    let ctx = crate::telemetry::current_ctx();
    let reg = ctx.registry.clone().unwrap_or_else(crate::telemetry::global);
    let tracked = |i: usize| {
        reg.pool_jobs.inc();
        crate::telemetry::with_ctx(ctx.clone(), || f(i))
    };
    if workers <= 1 {
        return (0..jobs)
            .map(|i| {
                let r = tracked(i);
                if r.is_err() {
                    reg.pool_job_errors.inc();
                }
                r
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let failed = AtomicBool::new(false);
    let slots: Vec<Mutex<Option<anyhow::Result<T>>>> =
        (0..jobs).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                if failed.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs {
                    break;
                }
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| tracked(i)))
                    .unwrap_or_else(|payload| {
                        Err(anyhow::anyhow!(
                            "worker job {i} panicked: {}",
                            panic_message(payload.as_ref())
                        ))
                    });
                if out.is_err() {
                    reg.pool_job_errors.inc();
                    failed.store(true, Ordering::Relaxed);
                }
                // a poisoned slot just means some other access panicked
                // mid-write; the data is a plain Option we are about to
                // overwrite, so recover it rather than panicking again
                match slots[i].lock() {
                    Ok(mut slot) => *slot = Some(out),
                    Err(poisoned) => *poisoned.into_inner() = Some(out),
                }
            });
        }
    });
    // claims are sequential, so filled slots form a prefix; the first
    // non-Ok entry in order is the error to report
    let mut out = Vec::with_capacity(jobs);
    for m in slots {
        match m.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner()) {
            Some(Ok(v)) => out.push(v),
            Some(Err(e)) => return Err(e),
            None => {
                return Err(anyhow::anyhow!("worker pool aborted after an earlier job failed"))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_in_job_order() {
        let out = run(4, 17, |i| Ok(i * i)).unwrap();
        assert_eq!(out.len(), 17);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let hits = AtomicUsize::new(0);
        let out = run(3, 25, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            Ok(i)
        })
        .unwrap();
        assert_eq!(out.len(), 25);
        assert_eq!(hits.load(Ordering::Relaxed), 25);
    }

    #[test]
    fn propagates_job_errors() {
        let r: anyhow::Result<Vec<usize>> = run(2, 8, |i| {
            if i == 5 {
                anyhow::bail!("job {i} failed");
            }
            Ok(i)
        });
        assert!(r.is_err());
    }

    #[test]
    fn panicking_job_surfaces_as_an_error_with_its_message() {
        // regression: a worker panic used to unwind through
        // thread::scope, which re-panics with an opaque "a scoped
        // thread panicked" and (via the poisoned result slot) turned
        // the collector's unwrap into a second panic.  The original
        // message must reach the caller as an ordinary error.
        let r: anyhow::Result<Vec<usize>> = run(3, 8, |i| {
            if i == 4 {
                panic!("boom in job {i}");
            }
            Ok(i)
        });
        let err = r.unwrap_err();
        let text = format!("{err}");
        assert!(text.contains("panicked"), "{text}");
        assert!(text.contains("boom in job 4"), "{text}");
    }

    #[test]
    fn zero_jobs_and_single_thread() {
        assert!(run(0, 0, |i| Ok(i)).unwrap().is_empty());
        assert_eq!(run(1, 3, |i| Ok(i + 1)).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(7), 7);
    }

    #[test]
    fn telemetry_scope_propagates_into_workers() {
        use std::sync::Arc;
        let reg = Arc::new(crate::telemetry::Registry::new());
        let out = crate::telemetry::with_registry(reg.clone(), || {
            run(4, 20, |i| {
                // each worker job must see the caller's registry override
                let seen = crate::telemetry::current_registry();
                anyhow::ensure!(Arc::ptr_eq(&seen, &reg), "scope lost in worker");
                if i == 13 {
                    anyhow::bail!("planned failure");
                }
                Ok(i)
            })
        });
        assert!(out.is_err());
        // every claimed job was counted into the injected registry, and
        // exactly one error (later claims stop after the failure)
        let jobs = reg.pool_jobs.get();
        assert!(jobs >= 1 && jobs <= 20, "{jobs}");
        assert_eq!(reg.pool_job_errors.get(), 1);
    }
}

//! Shared substrates: bf16 codec, PRNG, JSON, logging, phase timers.

pub mod bf16;
pub mod json;
pub mod logging;
pub mod prng;
pub mod timer;

//! Shared substrates: bf16 codec, PRNG, JSON, logging, phase timers,
//! and the scoped worker pool behind the parallel query path.

pub mod bf16;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prng;
pub mod timer;

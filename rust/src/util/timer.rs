//! Phase timers for the latency breakdowns (Fig 3) and preprocessing
//! tables (Tables 5–7).
//!
//! A `PhaseTimer` accumulates wall time into named phases; the query
//! engine uses one to separate "loading gradients" from "computation",
//! which is exactly the split the paper's Figure 3 reports.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, Duration>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a named phase.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.acc.entry(phase).or_default() += d;
    }

    pub fn get(&self, phase: &str) -> Duration {
        self.acc.get(phase).copied().unwrap_or_default()
    }

    pub fn total(&self) -> Duration {
        self.acc.values().sum()
    }

    /// Merge another timer's phases into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.acc {
            *self.acc.entry(k).or_default() += *v;
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.acc.iter().map(|(k, v)| (*k, *v))
    }

    /// "load 1.23s (82%) | score 0.27s (18%)" style summary.
    pub fn summary(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        self.acc
            .iter()
            .map(|(k, v)| {
                let s = v.as_secs_f64();
                format!("{k} {s:.3}s ({:.0}%)", 100.0 * s / total)
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// RAII scope timer: adds elapsed time to the phase on drop.
pub struct Scoped<'a> {
    timer: &'a mut PhaseTimer,
    phase: &'static str,
    start: Instant,
}

impl<'a> Scoped<'a> {
    pub fn new(timer: &'a mut PhaseTimer, phase: &'static str) -> Self {
        Scoped { timer, phase, start: Instant::now() }
    }
}

impl Drop for Scoped<'_> {
    fn drop(&mut self) {
        self.timer.add(self.phase, self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_phases() {
        let mut t = PhaseTimer::new();
        t.time("a", || std::thread::sleep(Duration::from_millis(5)));
        t.time("a", || std::thread::sleep(Duration::from_millis(5)));
        t.time("b", || ());
        assert!(t.get("a") >= Duration::from_millis(10));
        assert!(t.total() >= t.get("a"));
        assert!(t.summary().contains("a "));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(3));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.get("x"), Duration::from_millis(7));
    }

    #[test]
    fn scoped_records_on_drop() {
        let mut t = PhaseTimer::new();
        {
            let _s = Scoped::new(&mut t, "scope");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(t.get("scope") >= Duration::from_millis(2));
    }
}

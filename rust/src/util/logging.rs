//! Tiny stderr logger wired to the `log` facade.
//!
//! Level comes from `LORIF_LOG` (off|error|warn|info|debug|trace,
//! default info).  An unrecognized value falls back to `info` with a
//! one-line stderr warning naming the bad value — a typo'd `LORIF_LOG`
//! must not silently change what gets logged.  Timestamps are monotonic
//! seconds since logger init — good enough for correlating pipeline
//! stages in experiment logs.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, meta: &log::Metadata) -> bool {
        meta.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Resolve a `LORIF_LOG` value (`None` = unset) to a level filter.
/// Returns the filter plus, for an unrecognized value, the warning line
/// to print — split out so both outcomes are unit-testable without
/// touching process environment or the global logger.
fn parse_level(raw: Option<&str>) -> (log::LevelFilter, Option<String>) {
    match raw {
        None => (log::LevelFilter::Info, None),
        Some("off") => (log::LevelFilter::Off, None),
        Some("error") => (log::LevelFilter::Error, None),
        Some("warn") => (log::LevelFilter::Warn, None),
        Some("info") => (log::LevelFilter::Info, None),
        Some("debug") => (log::LevelFilter::Debug, None),
        Some("trace") => (log::LevelFilter::Trace, None),
        Some(other) => (
            log::LevelFilter::Info,
            Some(format!(
                "lorif: unknown LORIF_LOG level {other:?} — falling back to \"info\" \
                 (expected off|error|warn|info|debug|trace)"
            )),
        ),
    }
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent).
pub fn init() {
    // parse (and warn about a bad LORIF_LOG) only on the first init:
    // later calls must not re-print the warning line
    let logger = LOGGER.get_or_init(|| {
        let raw = std::env::var("LORIF_LOG").ok();
        let (level, warning) = parse_level(raw.as_deref());
        if let Some(w) = warning {
            eprintln!("{w}");
        }
        StderrLogger { start: Instant::now(), level }
    });
    // set_logger fails if already set (e.g. by a second init call) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }

    #[test]
    fn known_levels_parse_without_warning() {
        for (raw, want) in [
            (None, log::LevelFilter::Info),
            (Some("off"), log::LevelFilter::Off),
            (Some("error"), log::LevelFilter::Error),
            (Some("warn"), log::LevelFilter::Warn),
            (Some("info"), log::LevelFilter::Info),
            (Some("debug"), log::LevelFilter::Debug),
            (Some("trace"), log::LevelFilter::Trace),
        ] {
            let (level, warning) = parse_level(raw);
            assert_eq!(level, want, "{raw:?}");
            assert!(warning.is_none(), "{raw:?} should not warn");
        }
    }

    #[test]
    fn unknown_level_warns_naming_the_value_and_falls_back_to_info() {
        let (level, warning) = parse_level(Some("verbose"));
        assert_eq!(level, log::LevelFilter::Info);
        let w = warning.expect("unknown level must produce a warning");
        assert!(w.contains("\"verbose\""), "{w}");
        assert!(w.contains("LORIF_LOG"), "{w}");
    }
}

//! Streaming randomized SVD (Halko et al.) over a row-chunked gradient
//! matrix — paper §3.2, stage 2 of preprocessing.
//!
//! The gradient matrix `G in R^{N x D}` never materializes: chunks of
//! rows are reconstructed on the fly from the rank-c factor store (or
//! read from the dense store for the baselines) and streamed through the
//! sketch.  Matches App. B.2: oversampling p = 10, a configurable number
//! of power iterations (default 3), and the damping rule
//! `lambda = 0.1 * mean(top r+p eigenvalues)`.

use super::mat::{gemm_tn_acc, Mat};
use super::{eigh, qr};

/// A source of row chunks of the (N, D) gradient matrix.  `for_each_chunk`
/// must yield chunks in row order covering all N rows; it may be called
/// multiple times (once per streaming pass).
pub trait RowChunkSource {
    fn n_rows(&self) -> usize;
    fn dim(&self) -> usize;
    fn for_each_chunk(&mut self, f: &mut dyn FnMut(usize, &Mat)) -> anyhow::Result<()>;
}

/// In-memory source (tests, small benches).
pub struct MatSource<'a> {
    pub mat: &'a Mat,
    pub chunk: usize,
}

impl RowChunkSource for MatSource<'_> {
    fn n_rows(&self) -> usize {
        self.mat.rows
    }
    fn dim(&self) -> usize {
        self.mat.cols
    }
    fn for_each_chunk(&mut self, f: &mut dyn FnMut(usize, &Mat)) -> anyhow::Result<()> {
        let mut row = 0;
        while row < self.mat.rows {
            let take = self.chunk.min(self.mat.rows - row);
            let idx: Vec<usize> = (row..row + take).collect();
            let m = self.mat.select_rows(&idx);
            f(row, &m);
            row += take;
        }
        Ok(())
    }
}

/// Result of the truncated SVD: `G ~= U_r diag(sigma) V_r^T`.
pub struct TruncatedSvd {
    /// top-r singular values, descending
    pub sigma: Vec<f32>,
    /// right singular vectors, (D, r)
    pub v: Mat,
    /// left singular vectors scaled by sigma, (N, r): row i = sigma * U[i]
    /// = V_r^T g_i — the curvature-subspace projections of the training
    /// gradients, free by-product of the decomposition.
    pub train_proj: Mat,
}

/// Streaming randomized SVD with `q` power iterations.
///
/// Passes over the source: 1 (sketch) + 2q (power) + 1 (project) = 2q+2.
pub fn rsvd(
    src: &mut dyn RowChunkSource,
    r: usize,
    oversample: usize,
    power_iters: usize,
    seed: u64,
) -> anyhow::Result<TruncatedSvd> {
    let n = src.n_rows();
    let d = src.dim();
    let k = (r + oversample).min(n).min(d);
    anyhow::ensure!(r > 0 && r <= k, "rank {r} out of range (k={k})");

    // Omega: (D, k) gaussian test matrix
    let mut rng = crate::util::prng::Rng::labeled(seed, "rsvd-omega");
    let omega = Mat::random_normal(d, k, 1.0, &mut rng);

    // Y = G Omega  (N, k)
    let mut y = Mat::zeros(n, k);
    src.for_each_chunk(&mut |row0, chunk| {
        let yc = chunk.matmul(&omega);
        for (i, src_row) in (0..yc.rows).enumerate() {
            y.row_mut(row0 + i).copy_from_slice(yc.row(src_row));
        }
    })?;

    // power iterations: Y <- G (G^T Q_y), re-orthonormalizing each half-step
    for _ in 0..power_iters {
        let qy = qr::orthonormalize(&y); // (N, k)
        let mut z = Mat::zeros(d, k);
        src.for_each_chunk(&mut |row0, chunk| {
            // Z += chunk^T Q_y[rows]
            let idx: Vec<usize> = (row0..row0 + chunk.rows).collect();
            let qrows = qy.select_rows(&idx);
            gemm_tn_acc(&mut z, chunk, &qrows, 1.0);
        })?;
        let qz = qr::orthonormalize(&z); // (D, k)
        let mut y2 = Mat::zeros(n, k);
        src.for_each_chunk(&mut |row0, chunk| {
            let yc = chunk.matmul(&qz);
            for i in 0..yc.rows {
                y2.row_mut(row0 + i).copy_from_slice(yc.row(i));
            }
        })?;
        y = y2;
    }

    // Q = orth(Y)  (N, k);  B = Q^T G  (k, D)
    let q = qr::orthonormalize(&y);
    let mut b = Mat::zeros(k, d);
    src.for_each_chunk(&mut |row0, chunk| {
        let idx: Vec<usize> = (row0..row0 + chunk.rows).collect();
        let qrows = q.select_rows(&idx);
        gemm_tn_acc(&mut b, &qrows, chunk, 1.0);
    })?;

    // small SVD of B via eigh(B B^T): B = W diag(s) V^T
    let gram = b.matmul_nt(&b); // (k, k)
    let (vals, vecs) = eigh::eigh(&gram);
    // top-r, descending
    let mut sigma = Vec::with_capacity(r);
    let mut w = Mat::zeros(k, r); // left vectors of B
    for i in 0..r {
        let srcc = k - 1 - i;
        sigma.push(vals[srcc].max(0.0).sqrt());
        for row in 0..k {
            *w.at_mut(row, i) = vecs.at(row, srcc);
        }
    }
    // V = B^T W / sigma  (D, r)
    let btw = b.matmul_tn(&w); // (D, r): B^T (k,D)^T x ... => (D, r)
    let mut v = Mat::zeros(d, r);
    for i in 0..r {
        let inv = if sigma[i] > 1e-12 { 1.0 / sigma[i] } else { 0.0 };
        for row in 0..d {
            *v.at_mut(row, i) = btw.at(row, i) * inv;
        }
    }
    // train projections: V_r^T g_i for every row  = (Q W) diag(sigma) rows
    let qw = q.matmul(&w); // (N, r) = U_r
    let mut train_proj = qw;
    for row in 0..n {
        let rrow = train_proj.row_mut(row);
        for i in 0..r {
            rrow[i] *= sigma[i];
        }
    }

    Ok(TruncatedSvd { sigma, v, train_proj })
}

impl TruncatedSvd {
    /// Damping per App. B.2: lambda = 0.1 * mean(top r+p eigenvalues of H),
    /// approximated here with the retained spectrum (sigma_i^2).
    pub fn damping(&self, factor: f32) -> f32 {
        let mean: f32 =
            self.sigma.iter().map(|s| s * s).sum::<f32>() / self.sigma.len().max(1) as f32;
        (factor * mean).max(1e-12)
    }

    /// Woodbury weights w_i = sigma_i^2 / (lambda (lambda + sigma_i^2)).
    pub fn woodbury_weights(&self, lambda: f32) -> Vec<f32> {
        self.sigma
            .iter()
            .map(|&s| {
                let s2 = s * s;
                s2 / (lambda * (lambda + s2))
            })
            .collect()
    }

    /// Cumulative explained-variance ratio EVR(r') for r' = 1..=r
    /// relative to the *retained* spectrum (Fig 6 / Table 10 use the
    /// full spectrum from `svd_small` on diagnostics-sized problems).
    pub fn evr_curve(&self) -> Vec<f32> {
        let total: f32 = self.sigma.iter().map(|s| s * s).sum();
        let mut acc = 0.0;
        self.sigma
            .iter()
            .map(|s| {
                acc += s * s;
                if total > 0.0 { acc / total } else { 0.0 }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn low_rank_matrix(n: usize, d: usize, rank: usize, rng: &mut Rng) -> Mat {
        let a = Mat::random_normal(n, rank, 1.0, rng);
        let b = Mat::random_normal(rank, d, 1.0, rng);
        a.matmul(&b)
    }

    #[test]
    fn recovers_exact_low_rank() {
        let mut rng = Rng::new(1);
        let g = low_rank_matrix(60, 40, 5, &mut rng);
        let mut src = MatSource { mat: &g, chunk: 17 };
        let svd = rsvd(&mut src, 5, 5, 2, 0).unwrap();
        // reconstruct: G ~= train_proj @ V^T  (since train_proj = U Sigma)
        let rec = svd.train_proj.matmul_nt(&svd.v);
        let err = {
            let mut e = 0.0f32;
            for (x, y) in g.data.iter().zip(&rec.data) {
                e += (x - y) * (x - y);
            }
            e.sqrt() / g.frob_norm()
        };
        assert!(err < 1e-2, "rel err {err}");
    }

    #[test]
    fn sigma_descending_and_matches_svd() {
        let mut rng = Rng::new(2);
        let g = Mat::random_normal(50, 30, 1.0, &mut rng);
        let mut src = MatSource { mat: &g, chunk: 16 };
        let svd = rsvd(&mut src, 8, 10, 3, 0).unwrap();
        assert!(svd.sigma.windows(2).all(|w| w[0] >= w[1] - 1e-4));
        let (_, s_true, _) = eigh::svd_small(&g);
        for i in 0..8 {
            assert!(
                (svd.sigma[i] - s_true[i]).abs() < 0.05 * s_true[0],
                "sigma[{i}]: {} vs {}",
                svd.sigma[i],
                s_true[i]
            );
        }
    }

    #[test]
    fn v_columns_orthonormal() {
        let mut rng = Rng::new(3);
        let g = Mat::random_normal(40, 25, 1.0, &mut rng);
        let mut src = MatSource { mat: &g, chunk: 9 };
        let svd = rsvd(&mut src, 6, 8, 2, 0).unwrap();
        let vtv = svd.v.matmul_tn(&svd.v);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn train_proj_equals_vt_g() {
        let mut rng = Rng::new(4);
        let g = low_rank_matrix(30, 20, 4, &mut rng);
        let mut src = MatSource { mat: &g, chunk: 7 };
        let svd = rsvd(&mut src, 4, 6, 3, 0).unwrap();
        let want = g.matmul(&svd.v); // (N, r) = rows V^T g_i
        for (x, y) in svd.train_proj.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 2e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn woodbury_weights_match_formula() {
        let svd = TruncatedSvd {
            sigma: vec![2.0, 1.0],
            v: Mat::eye(2),
            train_proj: Mat::zeros(1, 2),
        };
        let w = svd.woodbury_weights(0.5);
        assert!((w[0] - 4.0 / (0.5 * 4.5)).abs() < 1e-6);
        assert!((w[1] - 1.0 / (0.5 * 1.5)).abs() < 1e-6);
        let lam = svd.damping(0.1);
        assert!((lam - 0.1 * 2.5).abs() < 1e-6);
    }

    #[test]
    fn evr_curve_monotone_to_one() {
        let mut rng = Rng::new(5);
        let g = Mat::random_normal(30, 20, 1.0, &mut rng);
        let mut src = MatSource { mat: &g, chunk: 30 };
        let svd = rsvd(&mut src, 10, 5, 2, 0).unwrap();
        let evr = svd.evr_curve();
        assert!(evr.windows(2).all(|w| w[1] >= w[0] - 1e-6));
        assert!((evr.last().unwrap() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn chunk_size_invariance() {
        let mut rng = Rng::new(6);
        let g = low_rank_matrix(40, 24, 3, &mut rng);
        let mut s1 = MatSource { mat: &g, chunk: 40 };
        let mut s2 = MatSource { mat: &g, chunk: 7 };
        let a = rsvd(&mut s1, 3, 5, 2, 9).unwrap();
        let b = rsvd(&mut s2, 3, 5, 2, 9).unwrap();
        for i in 0..3 {
            assert!((a.sigma[i] - b.sigma[i]).abs() < 1e-2 * (1.0 + a.sigma[i]));
        }
    }
}

//! Thin Householder QR, used to orthonormalize the randomized-SVD range
//! basis (paper App. B.2: randomized SVD with power iterations).
//!
//! Shapes here are tall-skinny: (N, r+p) with N up to the corpus size and
//! r+p a few hundred, so the O(2 m n^2) Householder cost is fine.

use super::mat::{axpy, dot, Mat};

/// Thin QR: A (m, n) with m >= n -> (Q (m, n) with orthonormal columns,
/// R (n, n) upper triangular) such that A = Q R.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr_thin expects tall matrices ({m}x{n})");
    // Work on the transpose so each Householder vector is contiguous.
    let mut at = a.transpose(); // (n, m): row k = column k of A
    let mut r = Mat::zeros(n, n);
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);

    for k in 0..n {
        // apply to column k: the stored reflectors
        // column k currently lives in at.row(k)
        // (reflectors were already applied in-place below)
        let colk = at.row(k).to_vec();
        // build Householder v from colk[k..]
        let x = &colk[k..];
        let alpha = -x[0].signum() * dot(x, x).sqrt();
        let mut v = x.to_vec();
        v[0] -= alpha;
        let vnorm2 = dot(&v, &v);
        r.data[k * n + k] = alpha;
        if vnorm2 > 0.0 {
            // apply reflector to remaining columns (rows of at)
            for j in (k + 1)..n {
                let rowj = &mut at.row_mut(j)[k..];
                let beta = 2.0 * dot(rowj, &v) / vnorm2;
                axpy(-beta, &v, rowj);
            }
        }
        // record R entries for this column from already-applied state
        for j in (k + 1)..n {
            r.data[k * n + j] = at.at(j, k);
        }
        vs.push(v);
    }

    // Build Q explicitly by applying reflectors to the identity columns.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        // e_j, then apply H_k ... H_0 in reverse
        let mut col = vec![0.0f32; m];
        col[j] = 1.0;
        for k in (0..=j.min(n - 1)).rev() {
            let v = &vs[k];
            let vnorm2 = dot(v, v);
            if vnorm2 > 0.0 {
                let seg = &mut col[k..];
                let beta = 2.0 * dot(seg, v) / vnorm2;
                axpy(-beta, v, seg);
            }
        }
        for i in 0..m {
            q.data[i * n + j] = col[i];
        }
    }
    (q, r)
}

/// Orthonormalize the columns of A in place-ish (returns Q of the thin QR).
pub fn orthonormalize(a: &Mat) -> Mat {
    qr_thin(a).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn reconstruct(q: &Mat, r: &Mat) -> Mat {
        q.matmul(r)
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(1);
        for (m, n) in [(10, 4), (50, 13), (7, 7)] {
            let a = Mat::random_normal(m, n, 1.0, &mut rng);
            let (q, r) = qr_thin(&a);
            let rec = reconstruct(&q, &r);
            for (x, y) in a.data.iter().zip(&rec.data) {
                assert!((x - y).abs() < 1e-3, "{m}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(2);
        let a = Mat::random_normal(40, 9, 1.0, &mut rng);
        let (q, _) = qr_thin(&a);
        let qtq = q.matmul_tn(&q);
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(3);
        let a = Mat::random_normal(20, 6, 1.0, &mut rng);
        let (_, r) = qr_thin(&a);
        for i in 0..6 {
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn handles_rank_deficient() {
        // duplicate columns: QR must still produce finite output
        let mut rng = Rng::new(4);
        let base = Mat::random_normal(15, 1, 1.0, &mut rng);
        let mut a = Mat::zeros(15, 3);
        for i in 0..15 {
            for j in 0..3 {
                *a.at_mut(i, j) = base.data[i];
            }
        }
        let (q, r) = qr_thin(&a);
        assert!(q.data.iter().all(|x| x.is_finite()));
        let rec = reconstruct(&q, &r);
        for (x, y) in a.data.iter().zip(&rec.data) {
            assert!((x - y).abs() < 1e-3);
        }
    }
}

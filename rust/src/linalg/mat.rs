//! Dense row-major f32 matrix + the blocked GEMM the whole repo runs on.
//!
//! This replaces cuBLAS for everything the paper's pipeline does on the
//! CPU side: curvature assembly, Woodbury projections, randomized-SVD
//! passes, and the Rust-native scoring fallback.  The GEMM uses an
//! i-k-j loop order with a contiguous inner axpy so LLVM auto-vectorizes
//! it; the §Perf pass tunes the blocking (see EXPERIMENTS.md).

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn random_normal(rows: usize, cols: usize, sigma: f32, rng: &mut crate::util::prng::Rng) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn scale(&mut self, a: f32) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// Select a subset of rows (used by LDS subset training / ablations).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    // -- products -----------------------------------------------------------

    /// self @ other.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut c = Mat::zeros(self.rows, other.cols);
        gemm_acc(&mut c, self, other, 1.0);
        c
    }

    /// self^T @ other.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn dims");
        let mut c = Mat::zeros(self.cols, other.cols);
        gemm_tn_acc(&mut c, self, other, 1.0);
        c
    }

    /// self @ other^T.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, other.rows);
        matmul_nt_acc(&mut c, self, other, 1.0);
        c
    }

    /// self @ v for a vector v.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }

    /// self^T @ v.
    pub fn matvec_t(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            axpy(v[r], self.row(r), &mut out);
        }
        out
    }
}

/// c[i] += a * b[i] — the vectorized inner kernel.
#[inline]
pub fn axpy(a: f32, b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(b.len(), c.len());
    for (ci, bi) in c.iter_mut().zip(b.iter()) {
        *ci += a * *bi;
    }
}

/// 8-wide blocked dot product — the inner kernel of every `score_chunk`
/// hot loop.  With the (non-default, nightly-only) `simd` feature the
/// blocked part is an explicit `std::simd::f32x8` loop; the default
/// build keeps eight scalar accumulators, which LLVM auto-vectorizes to
/// the same shape.  Within one build the sum order is fixed, so the
/// quantized bf16 fast path (`store::codec::quant`), which reuses this
/// kernel, stays bit-identical to the decoded path.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / 8 * 8;
    let mut s = dot8_blocks(&a[..blocks], &b[..blocks]);
    for i in blocks..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Σ aᵢ² with the same blocking and association order as [`dot`], so
/// the decoded and quantized trackstar norm paths agree bit-for-bit on
/// bf16 stores.
#[inline]
pub fn sumsq(a: &[f32]) -> f32 {
    dot(a, a)
}

#[cfg(feature = "simd")]
#[inline]
fn dot8_blocks(a: &[f32], b: &[f32]) -> f32 {
    use std::simd::f32x8;
    let mut acc = f32x8::splat(0.0);
    for (x, y) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        acc += f32x8::from_slice(x) * f32x8::from_slice(y);
    }
    let v = acc.to_array();
    ((v[0] + v[4]) + (v[1] + v[5])) + ((v[2] + v[6]) + (v[3] + v[7]))
}

#[cfg(not(feature = "simd"))]
#[inline]
fn dot8_blocks(a: &[f32], b: &[f32]) -> f32 {
    // 8 independent accumulators keep the FMA pipes full; the final
    // reduction pairs lanes the way the simd build's horizontal sum does
    let mut acc = [0.0f32; 8];
    for (x, y) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += x[l] * y[l];
        }
    }
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// C += alpha * A @ B^T, cache-tiled over rows of A × rows of B.  Each
/// output element receives exactly one full-length [`dot`] (the k axis
/// is never split), so the f32 result is independent of the tile sizes.
/// All `score_chunk` hot loops accumulate through this instead of
/// materializing a fresh `(B, Nq)` temporary per layer per chunk and
/// copying it element-wise.
pub fn matmul_nt_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f32) {
    assert_eq!(a.cols, b.cols, "matmul_nt_acc k dims");
    assert_eq!(c.rows, a.rows, "matmul_nt_acc rows");
    assert_eq!(c.cols, b.rows, "matmul_nt_acc cols");
    // rows per tile: a 32×32 tile of B rows stays resident in L1/L2
    // across the A rows it meets, so each B row is streamed from memory
    // once per tile column instead of once per A row
    const TILE: usize = 32;
    let nq = b.rows;
    for i0 in (0..a.rows).step_by(TILE) {
        let i1 = (i0 + TILE).min(a.rows);
        for j0 in (0..nq).step_by(TILE) {
            let j1 = (j0 + TILE).min(nq);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = &mut c.data[i * nq..(i + 1) * nq];
                for (j, cj) in crow[j0..j1].iter_mut().enumerate() {
                    *cj += alpha * dot(arow, b.row(j0 + j));
                }
            }
        }
    }
}

/// C += alpha * A @ B (row-major, i-k-j order: contiguous axpy on C rows).
pub fn gemm_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f32) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            let s = alpha * aik;
            if s != 0.0 {
                axpy(s, &b.data[k * n..(k + 1) * n], crow);
            }
        }
    }
}

/// C += alpha * A^T @ B where A is (m, ka) and B is (m, n): C is (ka, n).
pub fn gemm_tn_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f32) {
    assert_eq!(a.rows, b.rows);
    assert_eq!(c.rows, a.cols);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    for r in 0..a.rows {
        let arow = a.row(r);
        let brow = b.row(r);
        for (k, &ark) in arow.iter().enumerate() {
            let s = alpha * ark;
            if s != 0.0 {
                axpy(s, brow, &mut c.data[k * n..(k + 1) * n]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        let a = Mat::random_normal(17, 23, 1.0, &mut rng);
        let b = Mat::random_normal(23, 11, 1.0, &mut rng);
        assert_close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-4);
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(2);
        let a = Mat::random_normal(19, 7, 1.0, &mut rng);
        let b = Mat::random_normal(19, 13, 1.0, &mut rng);
        assert_close(&a.matmul_tn(&b), &a.transpose().matmul(&b), 1e-4);
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let mut rng = Rng::new(3);
        let a = Mat::random_normal(9, 21, 1.0, &mut rng);
        let b = Mat::random_normal(14, 21, 1.0, &mut rng);
        assert_close(&a.matmul_nt(&b), &a.matmul(&b.transpose()), 1e-4);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Rng::new(4);
        let a = Mat::random_normal(8, 12, 1.0, &mut rng);
        let v = Mat::random_normal(12, 1, 1.0, &mut rng);
        let mv = a.matvec(&v.data);
        let mm = a.matmul(&v);
        for i in 0..8 {
            assert!((mv[i] - mm.data[i]).abs() < 1e-4);
        }
        let vt = Mat::random_normal(8, 1, 1.0, &mut rng);
        let mvt = a.matvec_t(&vt.data);
        let mmt = a.transpose().matmul(&vt);
        for i in 0..12 {
            assert!((mvt[i] - mmt.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn identity_and_transpose() {
        let mut rng = Rng::new(5);
        let a = Mat::random_normal(6, 6, 1.0, &mut rng);
        assert_close(&a.matmul(&Mat::eye(6)), &a, 1e-6);
        assert_close(&a.transpose().transpose(), &a, 0.0);
    }

    #[test]
    fn select_rows_picks() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn dot_matches_scalar_loop() {
        let mut rng = Rng::new(6);
        // lengths straddling the 8-wide block boundary exercise both the
        // blocked kernel and the scalar remainder
        for n in [0usize, 1, 7, 8, 9, 16, 23, 103] {
            let a = Mat::random_normal(1, n.max(1), 1.0, &mut rng);
            let b = Mat::random_normal(1, n.max(1), 1.0, &mut rng);
            let a = &a.data[..n];
            let b = &b.data[..n];
            let want: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            assert!((dot(a, b) - want).abs() < 1e-3, "n={n}");
            let want_sq: f32 = a.iter().map(|x| x * x).sum();
            assert!((sumsq(a) - want_sq).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn matmul_nt_acc_accumulates_with_alpha() {
        let mut rng = Rng::new(7);
        // sizes larger than one 32-row tile in both directions
        let a = Mat::random_normal(37, 21, 1.0, &mut rng);
        let b = Mat::random_normal(41, 21, 1.0, &mut rng);
        let seed = Mat::random_normal(37, 41, 1.0, &mut rng);
        let mut c = seed.clone();
        matmul_nt_acc(&mut c, &a, &b, -2.0);
        let mut want = seed;
        let prod = a.matmul(&b.transpose());
        for (w, p) in want.data.iter_mut().zip(&prod.data) {
            *w -= 2.0 * p;
        }
        assert_close(&c, &want, 1e-4);
        // alpha = 1.0 into zeros is exactly matmul_nt
        let mut z = Mat::zeros(37, 41);
        matmul_nt_acc(&mut z, &a, &b, 1.0);
        assert_eq!(z.data, a.matmul_nt(&b).data, "tiled acc diverged from matmul_nt");
    }
}

//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used for: the small Gram matrix inside the randomized SVD
//! ((r+p) x (r+p)), the EK-FAC per-layer covariance eigenbases
//! (<= O_max x O_max), and exactness tests.  Jacobi is O(n^3) per sweep
//! but unconditionally stable and dependency-free; all our inputs are a
//! few hundred wide.

use super::mat::Mat;

/// Eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues ascending, eigenvectors as columns of `vecs`).
pub fn eigh(a: &Mat) -> (Vec<f32>, Mat) {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += (m.at(i, j) as f64).powi(2);
            }
        }
        if off.sqrt() < 1e-9 * (1.0 + frob(&m) as f64) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.at(p, q);
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                let theta = 0.5 * (aqq - app) as f64 / apq as f64;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                let (c, s) = (c as f32, s as f32);
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    *m.at_mut(k, p) = c * mkp - s * mkq;
                    *m.at_mut(k, q) = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    *m.at_mut(p, k) = c * mpk - s * mqk;
                    *m.at_mut(q, k) = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    *v.at_mut(k, p) = c * vkp - s * vkq;
                    *v.at_mut(k, q) = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort ascending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m.at(i, i).partial_cmp(&m.at(j, j)).unwrap());
    let vals: Vec<f32> = order.iter().map(|&i| m.at(i, i)).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            *vecs.at_mut(r, new_c) = v.at(r, old_c);
        }
    }
    (vals, vecs)
}

fn frob(m: &Mat) -> f32 {
    m.frob_norm()
}

/// Small dense SVD via eigh of the Gram matrix (for tests & diagnostics).
/// A (m, n) -> (U (m, k), sigma desc (k), V (n, k)) with k = min(m, n).
pub fn svd_small(a: &Mat) -> (Mat, Vec<f32>, Mat) {
    let (m, n) = (a.rows, a.cols);
    if m >= n {
        let gram = a.matmul_tn(a); // (n, n) = A^T A
        let (vals, vecs) = eigh(&gram);
        // descending
        let k = n;
        let mut sigma = vec![0.0f32; k];
        let mut v = Mat::zeros(n, k);
        for i in 0..k {
            let src = k - 1 - i;
            sigma[i] = vals[src].max(0.0).sqrt();
            for r in 0..n {
                *v.at_mut(r, i) = vecs.at(r, src);
            }
        }
        // U = A V / sigma
        let av = a.matmul(&v);
        let mut u = Mat::zeros(m, k);
        for i in 0..k {
            let s = if sigma[i] > 1e-12 { 1.0 / sigma[i] } else { 0.0 };
            for r in 0..m {
                *u.at_mut(r, i) = av.at(r, i) * s;
            }
        }
        (u, sigma, v)
    } else {
        let (v, sigma, u) = svd_small(&a.transpose());
        (u, sigma, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::random_normal(n, n, 1.0, rng);
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                *s.at_mut(i, j) = 0.5 * (a.at(i, j) + a.at(j, i));
            }
        }
        s
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [2, 5, 17, 40] {
            let a = random_symmetric(n, &mut rng);
            let (vals, vecs) = eigh(&a);
            // A V = V diag(vals)
            let av = a.matmul(&vecs);
            for i in 0..n {
                for j in 0..n {
                    let want = vecs.at(i, j) * vals[j];
                    assert!((av.at(i, j) - want).abs() < 1e-3, "n={n}");
                }
            }
        }
    }

    #[test]
    fn eigh_orthonormal_vectors() {
        let mut rng = Rng::new(2);
        let a = random_symmetric(12, &mut rng);
        let (_, vecs) = eigh(&a);
        let vtv = vecs.matmul_tn(&vecs);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn eigh_known_values() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn eigh_diagonal_fast_path() {
        let a = Mat::from_vec(3, 3, vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]);
        let (vals, _) = eigh(&a);
        assert_eq!(vals.len(), 3);
        assert!((vals[0] - 1.0).abs() < 1e-6);
        assert!((vals[2] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn svd_small_reconstructs() {
        let mut rng = Rng::new(3);
        for (m, n) in [(10, 6), (6, 10), (8, 8)] {
            let a = Mat::random_normal(m, n, 1.0, &mut rng);
            let (u, s, v) = svd_small(&a);
            // A = U diag(s) V^T
            let mut us = u.clone();
            for i in 0..us.rows {
                for j in 0..s.len() {
                    *us.at_mut(i, j) *= s[j];
                }
            }
            let rec = us.matmul_nt(&v);
            for (x, y) in a.data.iter().zip(&rec.data) {
                assert!((x - y).abs() < 2e-3, "{m}x{n}");
            }
            // descending singular values
            assert!(s.windows(2).all(|w| w[0] >= w[1] - 1e-5));
        }
    }
}

//! Cholesky factorization + SPD solves.
//!
//! The LoGRA/TrackStar baselines need `K = (G^T G + lambda I)^{-1}`
//! applied to query gradients (paper Eq. 3).  We never form the explicit
//! inverse: we factor the damped Gram matrix once per layer and solve
//! per query — the same numerics at a third of the flops, and the §Perf
//! baseline for the dense-curvature path.

use super::mat::{dot, Mat};

#[derive(Debug)]
pub struct NotSpd(pub usize);

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite at pivot {}", self.0)
    }
}

impl std::error::Error for NotSpd {}

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Chol {
    l: Mat,
}

impl Chol {
    pub fn factor(a: &Mat) -> Result<Chol, NotSpd> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // contiguous row prefixes: rows of L
                let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
                if i == j {
                    let d = a.at(i, i) - s;
                    if d <= 0.0 || !d.is_finite() {
                        return Err(NotSpd(i));
                    }
                    *l.at_mut(i, j) = d.sqrt();
                } else {
                    *l.at_mut(i, j) = (a.at(i, j) - s) / l.at(j, j);
                }
            }
        }
        Ok(Chol { l })
    }

    pub fn dim(&self) -> usize {
        self.l.rows
    }

    /// Solve A x = b in place.
    pub fn solve_in_place(&self, b: &mut [f32]) {
        let n = self.dim();
        assert_eq!(b.len(), n);
        // forward: L y = b
        for i in 0..n {
            let s = dot(&self.l.row(i)[..i], &b[..i]);
            b[i] = (b[i] - s) / self.l.at(i, i);
        }
        // backward: L^T x = y
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in (i + 1)..n {
                s -= self.l.at(k, i) * b[k];
            }
            b[i] = s / self.l.at(i, i);
        }
    }

    pub fn solve(&self, b: &[f32]) -> Vec<f32> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve for each row of B (treated as separate right-hand sides).
    pub fn solve_rows(&self, b: &Mat) -> Mat {
        let mut out = b.clone();
        for r in 0..out.rows {
            let row = out.row_mut(r);
            // rows are rhs vectors of length n
            let mut tmp = row.to_vec();
            self.solve_in_place(&mut tmp);
            row.copy_from_slice(&tmp);
        }
        out
    }
}

/// Log-determinant of A from its Cholesky factor (2 * sum log diag L).
impl Chol {
    pub fn logdet(&self) -> f64 {
        (0..self.dim()).map(|i| 2.0 * (self.l.at(i, i) as f64).ln()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::random_normal(n, n, 1.0, rng);
        let mut g = a.matmul_tn(&a); // A^T A is PSD
        for i in 0..n {
            *g.at_mut(i, i) += 0.5; // damp to SPD
        }
        g
    }

    #[test]
    fn solve_recovers_rhs() {
        let mut rng = Rng::new(1);
        for n in [1, 3, 10, 64] {
            let a = random_spd(n, &mut rng);
            let x_true = Mat::random_normal(n, 1, 1.0, &mut rng);
            let b = a.matvec(&x_true.data);
            let ch = Chol::factor(&a).unwrap();
            let x = ch.solve(&b);
            for i in 0..n {
                assert!((x[i] - x_true.data[i]).abs() < 5e-2, "n={n}");
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(Chol::factor(&a).is_err());
    }

    #[test]
    fn solve_rows_matches_individual() {
        let mut rng = Rng::new(2);
        let a = random_spd(7, &mut rng);
        let b = Mat::random_normal(4, 7, 1.0, &mut rng);
        let ch = Chol::factor(&a).unwrap();
        let xs = ch.solve_rows(&b);
        for r in 0..4 {
            let x = ch.solve(b.row(r));
            for i in 0..7 {
                assert!((x[i] - xs.at(r, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn logdet_matches_identity() {
        let ch = Chol::factor(&Mat::eye(5)).unwrap();
        assert!(ch.logdet().abs() < 1e-6);
    }
}

//! Dense linear algebra substrate (the repo's cuBLAS/cuSOLVER stand-in):
//! row-major matrices + GEMM, Householder QR, Jacobi eigendecomposition,
//! Cholesky SPD solves, and the streaming randomized SVD of paper §3.2.

pub mod chol;
pub mod eigh;
pub mod mat;
pub mod qr;
pub mod rsvd;

pub use chol::Chol;
pub use mat::{dot, matmul_nt_acc, sumsq, Mat};
pub use rsvd::{rsvd, RowChunkSource, TruncatedSvd};
